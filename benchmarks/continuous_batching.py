"""Continuous batching vs static batching under staggered arrivals,
batched vs single-block prefill ticks, and overload resilience
(deadline goodput with and without graceful effort degradation).

The static engine's pathologies under a request stream are structural:

  * head-of-line batching — a round starts with whatever has arrived
    and everyone else waits for the full round to finish;
  * lockstep decode — the round runs to the LONGEST request's max_new,
    so finished rows burn decode FLOPs producing nothing;
  * right-padding — short prompts pay the longest prompt's prefill.

The continuous-batching scheduler admits each request into a freed KV
slot on the next tick, so slots never idle while work is queued. On
top of that, the batched prefill path (`prefill_blocks`) advances one
128-token block of up to P distinct requests per tick in ONE jitted
call, instead of PR-1's one-block-of-one-request tick — under a
backlog, prefill wall-clock per block drops and TTFT with it.

Emits ``name,value,derived`` CSV rows (harness contract) and writes
the machine-readable ``results/BENCH_prefill.json`` sections
``serving`` (tok/s, TTFT p50/p99, continuous-vs-static and
batched-vs-single-prefill ratios, measured FastForward-vs-dense
speedup), ``kv_memory`` (slot vs paged KV pool at equal device
bytes: peak concurrent requests, peak pages, stranded tokens at the
occupancy peak, preemptions), ``prefix_sharing`` (refcounted
prefix cache OFF vs ON on the same paged heap: hit rate, prefill
blocks skipped, sustained concurrency and TTFT p50 both ways,
bit-identity of greedy outputs), ``kv_tiering`` (int8-quantized page
heap vs f32 at equal device bytes: sustained concurrency; host
swap-out vs preempt-and-recompute on the same undersized heap:
re-prefilled blocks, TTFT p99, bit-identity) and ``overload`` (goodput = fraction of
requests finishing ok within deadline at 1x/2x/4x the sustainable
arrival rate, degrade-on vs degrade-off) so the perf trajectory is
tracked PR-over-PR.

The overload section runs on a SIMULATED clock: scheduling decisions
are real (the actual scheduler, admission controller, and jitted model
calls run), but time advances by an analytical per-tick cost model
priced from each plan's FFN FLOP fraction — like the repo's
`analytical` sections, this isolates the policy effect (shedding FLOPs
instead of requests) from CPU wall-clock noise, so the degrade-on vs
degrade-off goodput comparison is deterministic and meaningful on a
shared CI machine.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax

from benchmarks.common import write_bench_json
from repro.configs import get_config
from repro.core.fastforward import resolve_plan
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (AdmissionConfig, AdmissionController,
                           ContinuousBatchingScheduler, Request,
                           StaticEngine, drive_stream)
from repro.serving.runtime import make_runtime

SLOTS = 8                     # lockstep waste grows with round width
PREFILL_BATCH = 8             # P: blocks of distinct requests per tick
REQUESTS = 32
PROMPT_RANGE = (128, 288)      # tokens: 4-9 FastForward blocks (reduced
                              # block_size=32) -> compute-bound prefill
                              # dominates, the paper's regime; the
                              # sparse gather hot path runs on every
                              # interior block
MAX_NEW_RANGE = (4, 96)       # varied -> lockstep decode waste
BURST = 8                     # requests arriving together: a burst
                              # fills the admission queue, so several
                              # requests prefill SIMULTANEOUSLY — the
                              # regime batched prefill is built for
GAP_S = 0.08                  # gap between bursts


def _workload(cfg, seed=0, requests=REQUESTS):
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(0, cfg.vocab,
                                 rng.integers(*PROMPT_RANGE)))
               for _ in range(requests)]
    max_news = [int(v) for v in rng.integers(*MAX_NEW_RANGE,
                                             size=requests)]
    # bursts with per-request jitter: a burst lands together (deep
    # prefill backlog) but not perfectly aligned — the static engine's
    # rounds start with whoever has arrived, stragglers wait a full
    # round (head-of-line), while the continuous scheduler admits them
    # on the next tick
    arrivals = np.repeat(
        np.cumsum(rng.exponential(GAP_S, size=-(-requests // BURST))),
        BURST)[:requests] + rng.exponential(GAP_S / 4, size=requests)
    # jitter makes raw arrivals non-monotonic; _run_static serves FIFO
    # by index, so sort to keep "request i arrives i-th" true for both
    # engines (drive_stream sorts internally — the comparison must too)
    return prompts, max_news, np.sort(arrivals)


def _run_static(cfg, params, prompts, max_news, arrivals):
    """FIFO rounds of exactly SLOTS rows (short rounds padded with a
    dummy request — the shape-stable static server); a round decodes to
    the max max_new in the round (lockstep), counting only requested
    tokens as useful. Requests can only join between rounds.

    Shapes (batch, pad_to, cache_len) are pinned so every round after
    warmup reuses one jit executable: the measured gap is scheduling
    efficiency, NOT recompilation overhead."""
    eng = StaticEngine(cfg, params)
    N = cfg.ff.block_size
    pad_to = -(-max(len(p) for p in prompts) // N) * N
    cache_len = pad_to + max(max_news)
    # warm with the exact serving shapes
    eng.generate([prompts[0]] * SLOTS, max_new=2, pad_to=pad_to,
                 cache_len=cache_len)
    t0 = time.perf_counter()
    done = 0
    useful = 0
    ttfts = []
    while done < len(prompts):
        now = time.perf_counter() - t0
        ready = [i for i in range(done, len(prompts)) if arrivals[i] <= now]
        if not ready:
            time.sleep(max(0.0, arrivals[done] - now))
            continue
        batch = list(range(done, done + min(len(ready), SLOTS)))
        rows = [prompts[i] for i in batch]
        while len(rows) < SLOTS:                  # shape-stable padding
            rows.append(prompts[batch[0]])
        t_round0 = time.perf_counter() - t0
        res = eng.generate(rows, max_new=max(max_news[i] for i in batch),
                           pad_to=pad_to, cache_len=cache_len)
        # first token of the round lands after its prefill, NOT after
        # the full lockstep decode — charge TTFT fairly
        t_first = t_round0 + res.prefill_seconds
        for i in batch:
            useful += max_news[i]
            ttfts.append(t_first - arrivals[i])
        done = batch[-1] + 1
    wall = time.perf_counter() - t0
    return useful, wall, np.array(ttfts)


def _run_continuous(cfg, params, prompts, max_news, arrivals,
                    prefill_batch=PREFILL_BATCH):
    runtime = make_runtime(cfg, params)
    N = runtime.block_size
    cache_len = (-(-max(len(p) for p in prompts) // N) * N
                 + max(max_news))
    sched = ContinuousBatchingScheduler(runtime, n_slots=SLOTS,
                                        cache_len=cache_len,
                                        prefill_batch=prefill_batch)
    counts0 = sched.warmup()

    requests = [Request(rid=i, prompt=prompts[i], max_new=max_news[i],
                        arrival_time=arrivals[i])
                for i in range(len(prompts))]
    wall = drive_stream(sched, requests)
    compiles_flat = None
    if None not in counts0.values():
        compiles_flat = runtime.compile_counts() == counts0
        assert compiles_flat, "recompiled mid-stream"
    outs = sched.finished
    useful = sum(len(o.tokens) for o in outs.values())
    ttfts = np.array([o.ttft_seconds for o in outs.values()])
    return useful, wall, ttfts, sched, compiles_flat


def _stats(tok, wall, ttft):
    return {
        "tokens_per_s": round(tok / wall, 1),
        "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 2),
        "ttft_p99_ms": round(float(np.percentile(ttft, 99)) * 1e3, 2),
    }


# ------------------------------------------------- kv memory (paged pool)

KV_SLOTS = 4                  # slot-pool capacity the byte budget buys
KV_PAGE = 16                  # tokens per page (divides block_size 32)
KV_PROMPT_RANGE = (48, 112)   # short-heavy: the fragmentation regime —
                              # every slot strands cache_len - need
KV_MAX_NEW_RANGE = (4, 32)
KV_REQUESTS = 20


def _kv_memory_workload(cfg, seed=2):
    """One deep burst of short-heavy requests: everyone arrives at once,
    so concurrency is limited ONLY by the KV pool — exactly the
    capacity question the slot-vs-paged comparison asks."""
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(0, cfg.vocab,
                                 rng.integers(*KV_PROMPT_RANGE)))
               for _ in range(KV_REQUESTS)]
    max_news = [int(v) for v in rng.integers(*KV_MAX_NEW_RANGE,
                                             size=KV_REQUESTS)]
    arrivals = np.sort(rng.exponential(0.002, size=KV_REQUESTS))
    return prompts, max_news, arrivals


def _run_kv_memory(cfg, params):
    """Slot vs paged pool at EQUAL device pool bytes.

    The byte budget is KV_SLOTS full-length slots. The slot engine can
    therefore hold at most KV_SLOTS requests in flight however short
    they are; the paged engine spends the same bytes as a page heap
    ((n_pages - 1) * page_size == KV_SLOTS * cache_len tokens; the
    reserved null page is paid on top honestly) across up to 4x as many
    table slots, so in-flight concurrency tracks the LIVE footprint.
    Writes the `kv_memory` section: peak concurrency, peak pages,
    stranded (allocated-but-dead) tokens at the occupancy peak, and
    throughput for both layouts."""
    prompts, max_news, arrivals = _kv_memory_workload(cfg)
    N = cfg.ff.block_size
    cache_len = -(-max(len(p) for p in prompts) // N) * N + max(max_news)
    cache_len = -(-cache_len // KV_PAGE) * KV_PAGE       # page-aligned
    pool_tokens = KV_SLOTS * cache_len
    requests = [Request(rid=i, prompt=prompts[i], max_new=max_news[i],
                        arrival_time=arrivals[i])
                for i in range(len(prompts))]

    def drive(cfg_run, n_slots, n_pages=None):
        runtime = make_runtime(cfg_run, params)
        sched = ContinuousBatchingScheduler(
            runtime, n_slots=n_slots, cache_len=cache_len,
            prefill_batch=PREFILL_BATCH, page_size=KV_PAGE,
            n_pages=n_pages)
        sched.warmup()
        wall = drive_stream(sched, requests)
        outs = sched.finished
        assert len(outs) == len(requests)
        gen = sum(len(o.tokens) for o in outs.values())
        return sched, wall, gen

    s_sched, s_wall, s_gen = drive(cfg, KV_SLOTS)
    p_sched, p_wall, p_gen = drive(
        cfg.with_(kv_layout="paged"), n_slots=4 * KV_SLOTS,
        n_pages=pool_tokens // KV_PAGE + 1)

    pool = p_sched.pool
    section = {
        "config": {"pool_tokens": pool_tokens, "cache_len": cache_len,
                   "page_size": KV_PAGE, "slot_n_slots": KV_SLOTS,
                   "paged_n_slots": 4 * KV_SLOTS,
                   "paged_usable_pages": pool.n_pages - 1,
                   "requests": len(requests),
                   "prompt_range": list(KV_PROMPT_RANGE),
                   "max_new_range": list(KV_MAX_NEW_RANGE)},
        "slot": {
            "max_concurrent_requests": s_sched.pool.max_in_use,
            "stranded_tokens_at_peak": s_sched.pool.stranded_tokens_at_peak,
            "tokens_per_s": round(s_gen / s_wall, 1),
        },
        "paged": {
            "max_concurrent_requests": pool.max_in_use,
            "peak_pages_in_use": pool.max_pages_in_use,
            "stranded_tokens_at_peak": pool.stranded_tokens_at_peak,
            "page_allocs": pool.total_page_allocs,
            "page_frees": pool.total_page_frees,
            "preemptions": p_sched.n_preemptions,
            "tokens_per_s": round(p_gen / p_wall, 1),
        },
        # acceptance: block-granular allocation must buy strictly more
        # in-flight requests from the same device bytes
        "paged_more_concurrent":
            bool(pool.max_in_use > s_sched.pool.max_in_use),
        "note": (
            "capacity comparison at equal pool bytes; paged tokens_per_s "
            "on CPU pays the gather-based page-table attention copy and "
            "the 4x wider decode batch — the TPU side of that path is "
            "the kernels/paged_attention Pallas kernel"),
    }
    write_bench_json("kv_memory", section)
    return section


# ---------------------------------------- prefix sharing (refcounted)

PS_PAGE = 16                  # tokens per page (npb = 2)
PS_GROUPS = 2                 # distinct "system prompts"
PS_GROUP_SIZE = 6             # requests per group (1 leader + 5)
PS_PREFIX_BLOCKS = 4          # shared prefix: 128 tok = 8 pages
PS_TAIL_BLOCKS = 1            # unique tail: 32 tok
PS_MAX_NEW = 24               # decode dwell: followers stay in flight
                              # long enough for concurrency to mean
                              # something
PS_POOL_PAGES = 40            # usable heap pages, BOTH runs: at 12
                              # pages/request full footprint, sharing
                              # off sustains ~3 in flight; sharing on
                              # charges only the ~4 unshared pages


def _prefix_sharing_workload(cfg, seed=7):
    """Shared-system-prompt traffic: PS_GROUPS families, each one
    leader then a simultaneous burst of followers with identical
    128-token prefixes and unique 32-token tails. Leaders get ~0.4 s
    of air so their prefix blocks are published before the follower
    burst asks for them — the steady state a production prefix cache
    serves. The burst lands together so sustained concurrency is
    limited ONLY by what the heap admits."""
    rng = np.random.default_rng(seed)
    N = cfg.ff.block_size
    prompts, arrivals = [], []
    for g in range(PS_GROUPS):
        grng = np.random.default_rng((seed, 100 + g))
        prefix = grng.integers(0, cfg.vocab,
                               PS_PREFIX_BLOCKS * N).tolist()
        t0 = g * 0.9
        for j in range(PS_GROUP_SIZE):
            tail = rng.integers(0, cfg.vocab, PS_TAIL_BLOCKS * N).tolist()
            prompts.append(prefix + tail)
            arrivals.append(t0 if j == 0 else t0 + 0.4)
    order = np.argsort(arrivals, kind="stable")
    return ([prompts[i] for i in order],
            [PS_MAX_NEW] * len(prompts),
            np.array([arrivals[i] for i in order]))


def _run_prefix_sharing(cfg, params):
    """Refcounted prefix sharing OFF vs ON on the SAME paged heap (equal
    pool bytes, equal workload). Off: every admission charges its full
    12-page footprint, so the 40-page heap sustains ~3 requests and
    followers queue behind strangers' prefill. On: followers map the
    leader's published prefix read-only, charge only the ~4 unshared
    pages, and start prefill at the first unshared block. Writes the
    `prefix_sharing` section: hit rate, blocks skipped, pages saved at
    peak, TTFT p50 and sustained concurrency both ways, plus the
    bit-identity and compile-flatness acceptance booleans."""
    cfg = cfg.with_(kv_layout="paged")
    prompts, max_news, arrivals = _prefix_sharing_workload(cfg)
    N = cfg.ff.block_size
    cache_len = -(-max(len(p) for p in prompts) // N) * N + PS_MAX_NEW
    requests = [Request(rid=i, prompt=prompts[i], max_new=max_news[i],
                        arrival_time=arrivals[i])
                for i in range(len(prompts))]

    def drive(prefix_cache):
        runtime = make_runtime(cfg, params)
        sched = ContinuousBatchingScheduler(
            runtime, n_slots=len(requests), cache_len=cache_len,
            prefill_batch=PREFILL_BATCH, page_size=PS_PAGE,
            n_pages=PS_POOL_PAGES + 1, prefix_cache=prefix_cache)
        counts0 = sched.warmup()
        wall = drive_stream(sched, requests)
        flat = None
        if None not in counts0.values():
            flat = runtime.compile_counts() == counts0
        outs = sched.finished
        assert len(outs) == len(requests)
        gen = sum(len(o.tokens) for o in outs.values())
        ttfts = np.array([outs[r.rid].ttft_seconds for r in requests])
        return sched, wall, gen, ttfts, flat

    off_sched, off_wall, off_gen, off_ttft, off_flat = drive(False)
    on_sched, on_wall, on_gen, on_ttft, on_flat = drive(True)

    identical = all(
        off_sched.finished[r.rid].tokens == on_sched.finished[r.rid].tokens
        for r in requests)
    ps = on_sched.prefix_stats()
    pool_on, pool_off = on_sched.pool, off_sched.pool
    section = {
        "config": {"page_size": PS_PAGE, "usable_pages": PS_POOL_PAGES,
                   "cache_len": cache_len, "groups": PS_GROUPS,
                   "group_size": PS_GROUP_SIZE,
                   "prefix_tokens": PS_PREFIX_BLOCKS * N,
                   "tail_tokens": PS_TAIL_BLOCKS * N,
                   "max_new": PS_MAX_NEW, "requests": len(requests)},
        "sharing_off": {
            "max_concurrent_requests": pool_off.max_in_use,
            "peak_pages_in_use": pool_off.max_pages_in_use,
            "ttft_p50_ms": round(float(np.percentile(off_ttft, 50)) * 1e3,
                                 2),
            "tokens_per_s": round(off_gen / off_wall, 1),
            "prefill_blocks": off_sched.n_prefill_blocks,
        },
        "sharing_on": {
            "max_concurrent_requests": pool_on.max_in_use,
            "peak_pages_in_use": pool_on.max_pages_in_use,
            "ttft_p50_ms": round(float(np.percentile(on_ttft, 50)) * 1e3,
                                 2),
            "tokens_per_s": round(on_gen / on_wall, 1),
            "prefill_blocks": on_sched.n_prefill_blocks,
            "hit_rate": round(ps["hit_rate"], 3),
            "hits": ps["hits"], "lookups": ps["lookups"],
            "requests_hit": ps["requests_hit"],
            "blocks_skipped": ps["blocks_skipped"],
            "pages_shared": ps["pages_shared"],
            "pages_published": ps["pages_published"],
            "cow_pages": ps["cow_pages"],
            "evictions": ps["evictions"],
        },
        # acceptance: from the SAME heap bytes, sharing must buy
        # strictly more sustained concurrency and a lower TTFT p50
        # while keeping greedy outputs bit-identical and the jit cache
        # flat after warmup
        "sharing_more_concurrent":
            bool(pool_on.max_in_use > pool_off.max_in_use),
        "sharing_lower_ttft_p50": bool(
            np.percentile(on_ttft, 50) < np.percentile(off_ttft, 50)),
        "hit_rate_nonzero": bool(ps["hit_rate"] > 0),
        "outputs_bit_identical": bool(identical),
        "compile_counts_flat": (None if off_flat is None or on_flat is None
                                else bool(off_flat and on_flat)),
        "note": ("equal-pool-bytes A/B on the refcounted paged heap; "
                 "followers map the leader's published prefix read-only "
                 "(copy-on-write only at a misaligned tail), so pages "
                 "saved = prefix pages x (group size - 1) at peak"),
    }
    write_bench_json("prefix_sharing", section)
    return section


# ------------------------------------- kv tiering (int8 quant + swap)

KT_PAGE = 16                  # tokens per page (divides block_size 32)
KT_F32_PAGES = 12             # usable f32 pages: the device byte budget
                              # both quant arms must fit in
KT_SLOTS = 16                 # slot table generous in every arm so the
                              # page heap is the ONLY capacity limit
KT_SWAP_PAGES = 64            # host tier pages for the swap arm
KT_HEAP_PAGES = 16            # usable device pages for the swap-vs-
                              # preempt A/B: long decodes overflow it


def _kv_page_bytes(cfg, page_size):
    """Device bytes of one (layer, K-or-V) page in each storage mode.

    f32 stores page_size x n_kv_heads x head_dim floats; int8 stores the
    same elements as one byte each plus a per-(page, kv-head) f32 scale
    — the 4 * n_kv_heads scale bytes are charged honestly, so the
    equal-byte page ratio lands just under 4x."""
    elems = page_size * cfg.n_kv_heads * cfg.head_dim
    return 4 * elems, elems + 4 * cfg.n_kv_heads


def _run_kv_tiering(cfg, params):
    """The `kv_tiering` section: two A/Bs on the paged heap.

    (a) int8 quantization at EQUAL device bytes — the byte budget is
    KT_F32_PAGES f32 pages; the quant arm spends the same bytes as
    ~3.97x as many int8 pages (scales charged), so under a deep burst
    it must sustain >= 2x the concurrent requests. Outputs are allclose
    (not bit-identical) with quant on — tested at logits level in
    tests/test_kv_quant.py — so this A/B is a capacity claim only.

    (b) swap-out vs preempt-and-recompute on the SAME undersized heap —
    the long-decode trace (benchmarks/traces/sample_longdecode.jsonl)
    overflows KT_HEAP_PAGES via decode growth; with swap_pages=0 the
    only valve is youngest-first preemption which re-runs finished
    prefill, with a host tier the victim's pages move device->host and
    back. Greedy outputs must be bit-identical, the swap arm must
    re-prefill strictly fewer blocks, and TTFT p99 should drop (the
    blocks count is the deterministic acceptance gate; p99 wall-clock
    is recorded but noisy on shared CPU)."""
    from repro.serving.trace import load_trace
    cfg = cfg.with_(kv_layout="paged")
    f32_pb, i8_pb = _kv_page_bytes(cfg, KT_PAGE)
    quant_pages = KT_F32_PAGES * f32_pb // i8_pb

    # --- (a) quant concurrency at equal device bytes: one deep burst,
    # concurrency limited only by the heap (KT_SLOTS slots both arms)
    prompts, max_news, arrivals = _kv_memory_workload(cfg, seed=3)
    N = cfg.ff.block_size
    cache_len = -(-max(len(p) for p in prompts) // N) * N + max(max_news)
    cache_len = -(-cache_len // KT_PAGE) * KT_PAGE
    requests = [Request(rid=i, prompt=prompts[i], max_new=max_news[i],
                        arrival_time=arrivals[i])
                for i in range(len(prompts))]

    def drive(cfg_run, n_pages, reqs, clen, swap_pages=0):
        runtime = make_runtime(cfg_run, params)
        sched = ContinuousBatchingScheduler(
            runtime, n_slots=KT_SLOTS, cache_len=clen,
            prefill_batch=PREFILL_BATCH, page_size=KT_PAGE,
            n_pages=n_pages, swap_pages=swap_pages)
        counts0 = sched.warmup()
        wall = drive_stream(sched, reqs)
        flat = None
        if None not in counts0.values():
            flat = runtime.compile_counts() == counts0
        outs = sched.finished
        assert len(outs) == len(reqs)
        gen = sum(len(o.tokens) for o in outs.values())
        ttfts = np.array([outs[r.rid].ttft_seconds for r in reqs])
        return sched, wall, gen, ttfts, flat

    f_sched, f_wall, f_gen, _, f_flat = drive(
        cfg, KT_F32_PAGES + 1, requests, cache_len)
    q_sched, q_wall, q_gen, _, q_flat = drive(
        cfg.with_(kv_quant=True), quant_pages + 1, requests, cache_len)

    # --- (b) swap vs preempt: identical heap, identical long-decode
    # trace, the ONLY knob is the host tier
    import os
    trace = os.path.join(os.path.dirname(__file__), "traces",
                         "sample_longdecode.jsonl")
    t_reqs = load_trace(trace, vocab=cfg.vocab)
    t_cache = (-(-max(len(r.prompt) for r in t_reqs) // N) * N
               + max(r.max_new for r in t_reqs))
    t_cache = -(-t_cache // KT_PAGE) * KT_PAGE
    p_sched, p_wall, p_gen, p_ttft, p_flat = drive(
        cfg, KT_HEAP_PAGES + 1, t_reqs, t_cache)
    s_sched, s_wall, s_gen, s_ttft, s_flat = drive(
        cfg, KT_HEAP_PAGES + 1, t_reqs, t_cache,
        swap_pages=KT_SWAP_PAGES)

    identical = all(
        p_sched.finished[r.rid].tokens == s_sched.finished[r.rid].tokens
        for r in t_reqs)
    ts = s_sched.tier_stats()
    flats = [f_flat, q_flat, p_flat, s_flat]
    section = {
        "config": {
            "page_size": KT_PAGE, "slots": KT_SLOTS,
            "f32_page_bytes": f32_pb, "int8_page_bytes": i8_pb,
            "device_bytes_budget": KT_F32_PAGES * f32_pb,
            "f32_usable_pages": KT_F32_PAGES,
            "int8_usable_pages": quant_pages,
            "burst_requests": len(requests),
            "swap_heap_pages": KT_HEAP_PAGES,
            "swap_host_pages": KT_SWAP_PAGES,
            "trace": "benchmarks/traces/sample_longdecode.jsonl",
            "trace_requests": len(t_reqs),
        },
        "quant_off": {
            "max_concurrent_requests": f_sched.pool.max_in_use,
            "peak_pages_in_use": f_sched.pool.max_pages_in_use,
            "preemptions": f_sched.n_preemptions,
            "tokens_per_s": round(f_gen / f_wall, 1),
        },
        "quant_on": {
            "max_concurrent_requests": q_sched.pool.max_in_use,
            "peak_pages_in_use": q_sched.pool.max_pages_in_use,
            "preemptions": q_sched.n_preemptions,
            "tokens_per_s": round(q_gen / q_wall, 1),
        },
        "preempt": {
            "preemptions": p_sched.n_preemptions,
            "prefill_blocks": p_sched.n_prefill_blocks,
            "ttft_p99_ms": round(float(np.percentile(p_ttft, 99)) * 1e3,
                                 2),
            "tokens_per_s": round(p_gen / p_wall, 1),
        },
        "swap": {
            "preemptions": s_sched.n_preemptions,
            "prefill_blocks": s_sched.n_prefill_blocks,
            "swap_outs": ts["swap_outs"], "swap_ins": ts["swap_ins"],
            "pages_swapped_out": ts["pages_swapped_out"],
            "pages_swapped_in": ts["pages_swapped_in"],
            "peak_host_pages_used": ts["peak_used"],
            "ttft_p99_ms": round(float(np.percentile(s_ttft, 99)) * 1e3,
                                 2),
            "tokens_per_s": round(s_gen / s_wall, 1),
        },
        # acceptance: equal device bytes must buy >= 2x sustained
        # concurrency with int8 pages, and the host tier must beat
        # preemption on re-prefilled blocks with bit-identical output
        "quant_2x_concurrent": bool(
            q_sched.pool.max_in_use >= 2 * f_sched.pool.max_in_use),
        "swap_fewer_prefill_blocks": bool(
            s_sched.n_prefill_blocks < p_sched.n_prefill_blocks),
        "swap_fewer_preemptions": bool(
            s_sched.n_preemptions < p_sched.n_preemptions),
        "swap_lower_ttft_p99": bool(
            np.percentile(s_ttft, 99) < np.percentile(p_ttft, 99)),
        "swap_outputs_bit_identical": bool(identical),
        "compile_counts_flat": (None if any(f is None for f in flats)
                                else bool(all(flats))),
        "note": (
            "quant A/B is a capacity comparison at equal device bytes "
            "(int8 outputs allclose, not bit-identical — see "
            "tests/test_kv_quant.py for the tolerance); swap A/B is "
            "deterministic on prefill blocks and preemptions, "
            "ttft_p99_ms is single-run wall-clock and noisy on a "
            "shared CPU"),
    }
    write_bench_json("kv_tiering", section)
    return section


# --------------------------------------------- overload (degrade A/B)

OV_REQUESTS = 40
OV_SLOTS = 4
OV_PREFILL_BATCH = 4
OV_PROMPT_BLOCKS = 4          # 4 blocks x 32 tok (reduced block size)
OV_MAX_NEW = 8
OV_DEADLINE_MS = 1200.0
OV_BASE_GAP_S = 0.05          # 1x offered rate: one request / 50 ms
# cost model: sim seconds a tick costs, priced from the plan mix of the
# work it actually did. ALPHA is the non-FFN fraction of block time
# (attention, norms, dispatch) that sparsity cannot remove.
OV_TICK_S = 0.002
OV_BLOCK_S = 0.012
OV_TOKEN_S = 0.002
OV_ALPHA = 0.3


def _run_overload(cfg, params):
    """Deadline goodput at 1x/2x/4x offered load, degrade-on vs
    degrade-off, on a simulated clock (see module docstring). The
    scheduler, admission controller, deadline/shed machinery, and
    jitted model calls are all real; only elapsed time is modeled, with
    each plan's block/token cost scaled by ALPHA + (1-ALPHA) *
    flop_frac — degrading to a sparser tier makes ticks cheaper exactly
    as the analytical speedup sections say it should. Acceptance: at
    >= 2x overload, degrade-on achieves STRICTLY higher goodput."""
    plans = tuple(
        dataclasses.replace(resolve_plan(cfg, effort=e), name=e)
        for e in ("dense", "balanced", "turbo"))
    runtime = make_runtime(cfg, params, plans=plans)
    fracs = np.array([p.flop_frac() for p in plans])
    eff = OV_ALPHA + (1 - OV_ALPHA) * fracs
    N = runtime.block_size
    prompt_len = OV_PROMPT_BLOCKS * N
    cache_len = prompt_len + OV_MAX_NEW
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, prompt_len).tolist()
               for _ in range(OV_REQUESTS)]

    def one_run(rate_x, degrade):
        clk = [0.0]
        admission = AdmissionController(plans, AdmissionConfig(
            queue_high=4, queue_low=1, dwell_ticks=2,
            degrade=degrade))
        sched = ContinuousBatchingScheduler(
            runtime, n_slots=OV_SLOTS, cache_len=cache_len,
            prefill_batch=OV_PREFILL_BATCH, admission=admission,
            clock=lambda: clk[0],
            sleep=lambda dt: clk.__setitem__(0, clk[0] + dt))
        sched.warmup()
        prev_pb = sched.plan_prefill_blocks.copy()
        prev_dt = sched.plan_decode_tokens.copy()

        def advance(s):
            # price the tick by the work it did, per plan
            dpb = s.plan_prefill_blocks - prev_pb
            ddt = s.plan_decode_tokens - prev_dt
            prev_pb[:] = s.plan_prefill_blocks
            prev_dt[:] = s.plan_decode_tokens
            clk[0] += (OV_TICK_S + float((dpb * eff).sum()) * OV_BLOCK_S
                       + float((ddt * eff).sum()) * OV_TOKEN_S)

        gap = OV_BASE_GAP_S / rate_x
        requests = [Request(rid=i, prompt=prompts[i], max_new=OV_MAX_NEW,
                            arrival_time=i * gap,
                            deadline_ms=OV_DEADLINE_MS)
                    for i in range(OV_REQUESTS)]
        sim_s = drive_stream(sched, requests, after_tick=advance)
        outs = sched.finished
        assert len(outs) == OV_REQUESTS
        met = sum(o.status == "ok"
                  and o.finish_seconds <= OV_DEADLINE_MS / 1e3
                  for o in outs.values())
        return {
            "goodput": round(met / OV_REQUESTS, 3),
            "n_ok": sum(o.status == "ok" for o in outs.values()),
            "n_shed": sched.n_shed,
            "n_timed_out": sched.n_timed_out,
            "n_degraded": sched.n_degraded,
            "peak_degradation_level": admission.peak_level,
            "sim_seconds": round(sim_s, 3),
        }

    runs = {}
    for rate_x in (1, 2, 4):
        runs[f"{rate_x}x"] = {
            "degrade_on": one_run(rate_x, degrade=True),
            "degrade_off": one_run(rate_x, degrade=False),
        }
    strictly_better = all(
        runs[k]["degrade_on"]["goodput"] > runs[k]["degrade_off"]["goodput"]
        for k in ("2x", "4x"))
    section = {
        "config": {
            "requests": OV_REQUESTS, "slots": OV_SLOTS,
            "prefill_batch": OV_PREFILL_BATCH,
            "prompt_len": prompt_len, "max_new": OV_MAX_NEW,
            "deadline_ms": OV_DEADLINE_MS,
            "base_rate_req_s": round(1 / OV_BASE_GAP_S, 1),
            "cost_model": {"tick_s": OV_TICK_S, "block_s": OV_BLOCK_S,
                           "token_s": OV_TOKEN_S, "non_ffn_alpha": OV_ALPHA,
                           "plan_flop_fracs": [round(float(f), 3)
                                               for f in fracs]},
        },
        "runs": runs,
        # acceptance: under overload, shedding FLOPs (graceful
        # degradation to sparser pre-compiled tiers) must beat shedding
        # requests/deadlines outright
        "degrade_strictly_better_at_overload": bool(strictly_better),
        "note": ("simulated-clock cost model (see module docstring): "
                 "real scheduler + admission decisions, analytical "
                 "per-plan tick pricing — deterministic, so degrade-on "
                 "vs degrade-off is a policy comparison, not CPU noise"),
    }
    write_bench_json("overload", section)
    return section


def run(csv=True, requests=REQUESTS):
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    prompts, max_news, arrivals = _workload(cfg, requests=requests)

    s_tok, s_wall, s_ttft = _run_static(cfg, params, prompts, max_news,
                                        arrivals)
    c1_tok, c1_wall, c1_ttft, _, _ = _run_continuous(
        cfg, params, prompts, max_news, arrivals, prefill_batch=1)
    c_tok, c_wall, c_ttft, sched, flat = _run_continuous(
        cfg, params, prompts, max_news, arrivals,
        prefill_batch=PREFILL_BATCH)
    # measured FastForward speedup: same batched scheduler, dense FFN
    d_tok, d_wall, d_ttft, _, _ = _run_continuous(
        cfg.with_ff(enabled=False), params, prompts, max_news, arrivals,
        prefill_batch=PREFILL_BATCH)

    static, single = _stats(s_tok, s_wall, s_ttft), _stats(c1_tok, c1_wall,
                                                           c1_ttft)
    batched, dense = _stats(c_tok, c_wall, c_ttft), _stats(d_tok, d_wall,
                                                           d_ttft)
    ratios = {
        "continuous_vs_static_tokens_per_s":
            round(batched["tokens_per_s"] / static["tokens_per_s"], 3),
        "batched_vs_single_tokens_per_s":
            round(batched["tokens_per_s"] / single["tokens_per_s"], 3),
        "batched_vs_single_ttft_p50":
            round(single["ttft_p50_ms"] / batched["ttft_p50_ms"], 3),
        "fastforward_vs_dense_tokens_per_s":
            round(batched["tokens_per_s"] / dense["tokens_per_s"], 3),
    }
    write_bench_json("serving", {
        "config": {"slots": SLOTS, "prefill_batch": PREFILL_BATCH,
                   "requests": len(prompts),
                   "prompt_range": list(PROMPT_RANGE),
                   "max_new_range": list(MAX_NEW_RANGE),
                   "burst": BURST, "burst_gap_s": GAP_S,
                   "arch": cfg.name, "reduced": True},
        "static": static,
        "continuous_single_prefill": single,
        "continuous_batched_prefill": dict(
            batched,
            prefill_ticks=sched.n_prefill_ticks,
            prefill_blocks=sched.n_prefill_blocks,
            blocks_per_tick=round(sched.n_prefill_blocks
                                  / max(sched.n_prefill_ticks, 1), 2)),
        "continuous_batched_dense": dense,
        "ratios": ratios,
        # the caveat the CSV output prints, carried into the artifact:
        # single-run wall-clock on a shared CPU swings this ratio well
        # below/above 1.0 run-to-run (PR-over-PR values 0.9-1.2x are
        # machine noise, not regressions)
        "ratios_note": (
            "fastforward_vs_dense_tokens_per_s is overhead-bound and "
            "noisy on the reduced CPU config; the compute-bound speedup "
            "is the analytical_speedup_vs_dense section"),
        "compile_counts_flat": flat,
    })

    kv = _run_kv_memory(cfg, params)
    px = _run_prefix_sharing(cfg, params)
    kt = _run_kv_tiering(cfg, params)
    ov = _run_overload(cfg, params)

    rows = [
        ("static_tokens_per_s", f"{static['tokens_per_s']:.1f}",
         f"{len(prompts)} reqs, {SLOTS}-wide rounds, lockstep decode"),
        ("static_ttft_p50_ms", f"{static['ttft_p50_ms']:.1f}", ""),
        ("static_ttft_p99_ms", f"{static['ttft_p99_ms']:.1f}", ""),
        ("continuous_single_tokens_per_s", f"{single['tokens_per_s']:.1f}",
         "PR-1 one-block-per-tick prefill"),
        ("continuous_single_ttft_p50_ms", f"{single['ttft_p50_ms']:.1f}",
         ""),
        ("continuous_tokens_per_s", f"{batched['tokens_per_s']:.1f}",
         f"{SLOTS} KV slots, P={PREFILL_BATCH} batched prefill, "
         f"{sched.pool.total_acquires} acquires, "
         f"{sched.n_prefill_blocks} prefill blocks in "
         f"{sched.n_prefill_ticks} prefill ticks, "
         f"{sched.n_decode_steps} decode steps"),
        ("continuous_ttft_p50_ms", f"{batched['ttft_p50_ms']:.1f}", ""),
        ("continuous_ttft_p99_ms", f"{batched['ttft_p99_ms']:.1f}", ""),
        ("throughput_ratio", f"{ratios['continuous_vs_static_tokens_per_s']:.2f}",
         "continuous/static tokens-per-sec (target >= 1.3x)"),
        ("batched_prefill_ratio",
         f"{ratios['batched_vs_single_tokens_per_s']:.2f}",
         "batched/single-prefill tokens-per-sec (target > 1.0)"),
        ("batched_ttft_ratio",
         f"{ratios['batched_vs_single_ttft_p50']:.2f}",
         "single/batched TTFT p50 (target > 1.0)"),
        ("fastforward_vs_dense_ratio",
         f"{ratios['fastforward_vs_dense_tokens_per_s']:.2f}",
         "sparse/dense tok/s, batched serving path (noisy on the "
         "overhead-bound CPU reduced config; the compute-bound "
         "speedup is the analytical_speedup_vs_dense section)"),
        ("kv_slot_max_concurrent",
         f"{kv['slot']['max_concurrent_requests']}",
         f"{kv['config']['pool_tokens']}-token pool as "
         f"{kv['config']['slot_n_slots']} full-length slots; "
         f"stranded@peak {kv['slot']['stranded_tokens_at_peak']} tok"),
        ("kv_paged_max_concurrent",
         f"{kv['paged']['max_concurrent_requests']}",
         f"same bytes as {kv['config']['paged_usable_pages']} x "
         f"{kv['config']['page_size']}-token pages; peak "
         f"{kv['paged']['peak_pages_in_use']} pages, stranded@peak "
         f"{kv['paged']['stranded_tokens_at_peak']} tok, "
         f"{kv['paged']['preemptions']} preemptions "
         f"(target: > slot concurrency)"),
        ("prefix_hit_rate",
         f"{px['sharing_on']['hit_rate']:.2f}",
         f"{px['sharing_on']['hits']}/{px['sharing_on']['lookups']} "
         f"admissions mapped a cached prefix, "
         f"{px['sharing_on']['blocks_skipped']} prefill blocks skipped "
         f"({px['sharing_off']['prefill_blocks']} -> "
         f"{px['sharing_on']['prefill_blocks']})"),
        ("prefix_max_concurrent_on",
         f"{px['sharing_on']['max_concurrent_requests']}",
         f"vs {px['sharing_off']['max_concurrent_requests']} sharing "
         f"off at the same {px['config']['usable_pages']}-page heap; "
         f"peak pages {px['sharing_on']['peak_pages_in_use']} vs "
         f"{px['sharing_off']['peak_pages_in_use']} "
         f"(target: strictly more requests in flight)"),
        ("prefix_ttft_p50_ms_on",
         f"{px['sharing_on']['ttft_p50_ms']:.1f}",
         f"vs {px['sharing_off']['ttft_p50_ms']:.1f} sharing off "
         f"(target: lower — followers skip the shared prefill)"),
        ("prefix_outputs_bit_identical",
         f"{px['outputs_bit_identical']}",
         "acceptance: greedy outputs identical sharing on vs off"),
        ("kv_quant_max_concurrent",
         f"{kt['quant_on']['max_concurrent_requests']}",
         f"vs {kt['quant_off']['max_concurrent_requests']} f32 at the "
         f"same {kt['config']['device_bytes_budget']} device bytes "
         f"({kt['config']['int8_usable_pages']} int8 vs "
         f"{kt['config']['f32_usable_pages']} f32 pages; "
         f"target: >= 2x)"),
        ("kv_swap_prefill_blocks",
         f"{kt['swap']['prefill_blocks']}",
         f"vs {kt['preempt']['prefill_blocks']} preempt-only on the "
         f"same {kt['config']['swap_heap_pages']}-page heap "
         f"({kt['swap']['swap_outs']} swap outs / "
         f"{kt['swap']['swap_ins']} ins, "
         f"{kt['preempt']['preemptions']} -> "
         f"{kt['swap']['preemptions']} preemptions; target: fewer — "
         f"swapped requests resume instead of re-prefilling)"),
        ("kv_swap_ttft_p99_ms",
         f"{kt['swap']['ttft_p99_ms']:.1f}",
         f"vs {kt['preempt']['ttft_p99_ms']:.1f} preempt-only "
         f"(wall-clock, noisy on shared CPU)"),
        ("kv_swap_outputs_bit_identical",
         f"{kt['swap_outputs_bit_identical']}",
         "acceptance: greedy outputs identical swap on vs off"),
        ("overload_goodput_2x_degrade_on",
         f"{ov['runs']['2x']['degrade_on']['goodput']:.3f}",
         f"deadline-met fraction at 2x offered rate, "
         f"{ov['runs']['2x']['degrade_on']['n_degraded']} degraded, "
         f"{ov['runs']['2x']['degrade_on']['n_timed_out']} timed out "
         f"(simulated clock)"),
        ("overload_goodput_2x_degrade_off",
         f"{ov['runs']['2x']['degrade_off']['goodput']:.3f}",
         f"{ov['runs']['2x']['degrade_off']['n_timed_out']} timed out, "
         f"{ov['runs']['2x']['degrade_off']['n_shed']} shed"),
        ("overload_goodput_4x_degrade_on",
         f"{ov['runs']['4x']['degrade_on']['goodput']:.3f}", ""),
        ("overload_goodput_4x_degrade_off",
         f"{ov['runs']['4x']['degrade_off']['goodput']:.3f}", ""),
        ("overload_degrade_strictly_better",
         f"{ov['degrade_strictly_better_at_overload']}",
         "acceptance: degrade-on goodput strictly higher at >= 2x"),
    ]
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=REQUESTS,
                   help="stream length (CI smoke uses a reduced count)")
    args = p.parse_args()
    run(requests=args.requests)
