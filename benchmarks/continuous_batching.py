"""Continuous batching vs static batching under staggered arrivals.

The static engine's pathologies under a request stream are structural:

  * head-of-line batching — a round starts with whatever has arrived
    and everyone else waits for the full round to finish;
  * lockstep decode — the round runs to the LONGEST request's max_new,
    so finished rows burn decode FLOPs producing nothing;
  * right-padding — short prompts pay the longest prompt's prefill.

The continuous-batching scheduler admits each request into a freed KV
slot on the next tick, so slots never idle while work is queued.

Emits ``name,value,derived`` CSV rows (harness contract), including the
static vs continuous tokens/sec ratio at matched sparsity (acceptance
target: >= 1.3x on the reduced config with staggered arrivals).
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, Request,
                           StaticEngine, drive_stream)
from repro.serving.runtime import make_runtime

SLOTS = 8                     # lockstep waste grows with round width
REQUESTS = 32
PROMPT_RANGE = (24, 64)       # tokens
MAX_NEW_RANGE = (4, 96)       # varied -> lockstep decode waste
GAP_S = 0.006                 # mean arrival gap (staggered stream)


def _workload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(0, cfg.vocab,
                                 rng.integers(*PROMPT_RANGE)))
               for _ in range(REQUESTS)]
    max_news = [int(v) for v in rng.integers(*MAX_NEW_RANGE,
                                             size=REQUESTS)]
    arrivals = np.cumsum(rng.exponential(GAP_S, size=REQUESTS))
    return prompts, max_news, arrivals


def _run_static(cfg, params, prompts, max_news, arrivals):
    """FIFO rounds of exactly SLOTS rows (short rounds padded with a
    dummy request — the shape-stable static server); a round decodes to
    the max max_new in the round (lockstep), counting only requested
    tokens as useful. Requests can only join between rounds.

    Shapes (batch, pad_to, cache_len) are pinned so every round after
    warmup reuses one jit executable: the measured gap is scheduling
    efficiency, NOT recompilation overhead."""
    eng = StaticEngine(cfg, params)
    N = cfg.ff.block_size
    pad_to = -(-max(len(p) for p in prompts) // N) * N
    cache_len = pad_to + max(max_news)
    # warm with the exact serving shapes
    eng.generate([prompts[0]] * SLOTS, max_new=2, pad_to=pad_to,
                 cache_len=cache_len)
    t0 = time.perf_counter()
    done = 0
    useful = 0
    ttfts = []
    while done < REQUESTS:
        now = time.perf_counter() - t0
        ready = [i for i in range(done, REQUESTS) if arrivals[i] <= now]
        if not ready:
            time.sleep(max(0.0, arrivals[done] - now))
            continue
        batch = list(range(done, done + min(len(ready), SLOTS)))
        rows = [prompts[i] for i in batch]
        while len(rows) < SLOTS:                  # shape-stable padding
            rows.append(prompts[batch[0]])
        t_round0 = time.perf_counter() - t0
        res = eng.generate(rows, max_new=max(max_news[i] for i in batch),
                           pad_to=pad_to, cache_len=cache_len)
        # first token of the round lands after its prefill, NOT after
        # the full lockstep decode — charge TTFT fairly
        t_first = t_round0 + res.prefill_seconds
        for i in batch:
            useful += max_news[i]
            ttfts.append(t_first - arrivals[i])
        done = batch[-1] + 1
    wall = time.perf_counter() - t0
    return useful, wall, np.array(ttfts)


def _run_continuous(cfg, params, prompts, max_news, arrivals):
    runtime = make_runtime(cfg, params)
    N = runtime.block_size
    cache_len = (-(-max(len(p) for p in prompts) // N) * N
                 + max(max_news))
    sched = ContinuousBatchingScheduler(runtime, n_slots=SLOTS,
                                        cache_len=cache_len)
    counts0 = sched.warmup()

    requests = [Request(rid=i, prompt=prompts[i], max_new=max_news[i],
                        arrival_time=arrivals[i])
                for i in range(REQUESTS)]
    wall = drive_stream(sched, requests)
    if None not in counts0.values():
        assert runtime.compile_counts() == counts0, "recompiled mid-stream"
    outs = sched.finished
    useful = sum(len(o.tokens) for o in outs.values())
    ttfts = np.array([o.ttft_seconds for o in outs.values()])
    return useful, wall, ttfts, sched


def run(csv=True):
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    prompts, max_news, arrivals = _workload(cfg)

    s_tok, s_wall, s_ttft = _run_static(cfg, params, prompts, max_news,
                                        arrivals)
    c_tok, c_wall, c_ttft, sched = _run_continuous(cfg, params, prompts,
                                                   max_news, arrivals)
    s_tps = s_tok / s_wall
    c_tps = c_tok / c_wall
    rows = [
        ("static_tokens_per_s", f"{s_tps:.1f}",
         f"{REQUESTS} reqs, {SLOTS}-wide rounds, lockstep decode"),
        ("static_ttft_p50_ms", f"{np.percentile(s_ttft, 50)*1e3:.1f}", ""),
        ("static_ttft_p99_ms", f"{np.percentile(s_ttft, 99)*1e3:.1f}", ""),
        ("continuous_tokens_per_s", f"{c_tps:.1f}",
         f"{SLOTS} KV slots, {sched.pool.total_acquires} acquires "
         f"(x{sched.pool.total_acquires - SLOTS} slot reuse), "
         f"{sched.n_prefill_blocks} prefill blocks interleaved with "
         f"{sched.n_decode_steps} decode steps"),
        ("continuous_ttft_p50_ms", f"{np.percentile(c_ttft, 50)*1e3:.1f}",
         ""),
        ("continuous_ttft_p99_ms", f"{np.percentile(c_ttft, 99)*1e3:.1f}",
         ""),
        ("throughput_ratio", f"{c_tps / s_tps:.2f}",
         "continuous/static tokens-per-sec (target >= 1.3x)"),
    ]
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    run()
