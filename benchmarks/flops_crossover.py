"""Paper §2.3 / Fig. 1-2: FFN vs attention FLOPs crossover.

Analytic per-layer prefill FLOPs from the model geometry; validates the
paper's claims that FFN dominates until ~16K tokens (Llama-3.2-1B) and
~28K tokens (Llama-3.1-8B).
"""
from __future__ import annotations

import numpy as np

# (name, d_model, d_ff, n_layers) — Llama-3 family geometries (paper)
GEOMETRIES = {
    "llama-1b": (2048, 8192, 16),
    "llama-3b": (3072, 8192, 28),
    "llama-8b": (4096, 14336, 32),
}


def layer_flops(d_model, d_ff, T, gated=True):
    """Prefill FLOPs for one layer at context length T."""
    proj = 2 * T * d_model * d_model * 4          # q,k,v,o (upper bound)
    attn = 2 * 2 * T * T * d_model                # QK^T and AV
    n_mats = 3 if gated else 2
    ffn = 2 * T * d_model * d_ff * n_mats
    return {"attn": proj + attn, "attn_quad": attn, "ffn": ffn}


def crossover_T(d_model, d_ff, gated=True):
    """Context length where quadratic attention cost passes FFN cost."""
    # 4*T^2*d == 6*T*d*d_ff  ->  T = 1.5 * d_ff
    lo, hi = 128, 1 << 22
    while hi - lo > 1:
        mid = (lo + hi) // 2
        f = layer_flops(d_model, d_ff, mid, gated)
        if f["attn_quad"] > f["ffn"]:
            hi = mid
        else:
            lo = mid
    return hi


def ffn_fraction(d_model, d_ff, T):
    f = layer_flops(d_model, d_ff, T)
    return f["ffn"] / (f["ffn"] + f["attn"])


def run(csv=True):
    rows = []
    for name, (d, dff, L) in GEOMETRIES.items():
        cross = crossover_T(d, dff)
        rows.append((f"crossover_{name}", cross,
                     f"ffn_frac@4k={ffn_fraction(d, dff, 4096):.3f}"))
    if csv:
        for r in rows:
            print(f"{r[0]},{r[1]},{r[2]}")
    # paper-claim validation (EXPERIMENTS.md §Claims)
    c8 = crossover_T(*GEOMETRIES["llama-8b"][:2])
    c1 = crossover_T(*GEOMETRIES["llama-1b"][:2])
    assert 20000 < c8 < 32000, f"8B crossover {c8} outside paper's ~28K"
    assert 10000 < c1 < 20000, f"1B crossover {c1} outside paper's ~16K"
    return rows


if __name__ == "__main__":
    run()
