"""Paper Fig. 1 analog: measured wall-clock TTFT, dense vs FastForward,
through the real serving engine (reduced model, CPU).

On CPU the gather path does fewer FLOPs exactly like the TPU kernel, so
wall-time improves when the FFN dominates. Also measures the
sparse-FFN-only sublayer time (Fig. 6 analog) through the XLA path.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import build_fixture
# StaticEngine keeps the seed measurement semantics (one batched
# blockwise prefill); the continuous engine's prefill_seconds means
# last-request TTFT under chunked scheduling — a different metric.
from repro.serving.engine import StaticEngine
from repro.core import sparse_ffn as S
from repro.core import fastforward as FF


def time_fn(fn, *args, iters=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def ffn_sublayer_times(cfg, params, T=512):
    lp = jax.tree.map(lambda a: a[0], params["layers"])["ffn"]
    x = jax.random.normal(jax.random.key(0), (T, cfg.d_model))
    N = cfg.ff.block_size
    xb = x.reshape(T // N, N, cfg.d_model)
    k = FF.k_tiles_for(cfg)
    ids = jnp.tile(jnp.arange(k, dtype=jnp.int32)[None], (T // N, 1))
    t_dense = time_fn(jax.jit(lambda a: S.ffn_dense(lp, a, cfg.act)), xb)
    t_sparse = time_fn(jax.jit(
        lambda a, i: S.ffn_sparse_batched(lp, a, i, cfg.ff.tile, cfg.act)),
        xb, ids)
    return t_dense, t_sparse


def run(csv=True):
    cfg, params, _ = build_fixture()
    rows = []
    td, ts = ffn_sublayer_times(cfg, params)
    rows.append(("ffn_sublayer_dense", f"{td*1e6:.1f}", "us"))
    rows.append(("ffn_sublayer_sparse50", f"{ts*1e6:.1f}",
                 f"wallclock={td/ts:.2f}x (CPU XLA gather-bound; the "
                 f"TPU Pallas kernel is DMA-redirected)"))
    rows.append(("ffn_sublayer_flop_ratio", "2.00",
                 "compute-bound speedup at 50% sparsity (Fig. 6 analog)"))

    rng = np.random.default_rng(0)
    for L in (256, 512):
        prompts = [rng.integers(0, cfg.vocab, L).tolist() for _ in range(2)]
        for tag, c in [("dense", cfg.with_ff(enabled=False)),
                       ("sparse50", cfg)]:
            eng = StaticEngine(c, params)
            eng.generate(prompts, max_new=1)           # warm the jit
            res = eng.generate(prompts, max_new=1)
            rows.append((f"ttft_{tag}_L{L}",
                         f"{res.prefill_seconds*1e3:.1f}", "ms"))
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    run()
