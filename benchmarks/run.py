"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,derived`` CSV rows per benchmark (harness contract).

  flops_crossover   paper §2.3, Fig. 1-2 (FFN/attention crossover)
  prefill_speedup   paper Fig. 6-7 (compute-bound speedup)
  ttft              paper Fig. 1 (measured TTFT, dense vs sparse)
  fidelity_proxy    paper Table 2-3 (quality vs sparsity)
  ablations         paper Tables 4-7 (schedule/blocks/comp/predictor)
  roofline          ours: dry-run roofline summary (§Roofline)
  continuous_batching  ours: continuous vs static batching under
                       staggered arrivals (serving runtime)
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (flops_crossover, prefill_speedup, ttft,
                            fidelity_proxy, ablations, roofline,
                            continuous_batching)
    suites = [
        ("flops_crossover", flops_crossover),
        ("prefill_speedup", prefill_speedup),
        ("ttft", ttft),
        ("fidelity_proxy", fidelity_proxy),
        ("ablations", ablations),
        ("roofline", roofline),
        ("continuous_batching", continuous_batching),
    ]
    failures = 0
    for name, mod in suites:
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            mod.run(csv=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"# {name} FAILED", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
