"""Shared benchmark fixture: a small trained LM + distilled FastForward
(predictor + compensator per layer) + calibrated layer importance.

Built once and cached under results/bench_cache (deterministic); every
accuracy-proxy benchmark (Tables 2/4/5/6/7 analogs) reads from here.
"""
from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, FastForwardConfig
from repro.models import dense as D
from repro.nn import layers as L
from repro.nn import attention as A
from repro.nn.param import init_params
from repro.core import distill as DI
from repro.core import scheduler as SCHED
from repro.training.train import make_train_step
from repro.training.checkpoint import save_checkpoint, load_checkpoint
from repro.data.synthetic import batches

CACHE = os.path.join(os.path.dirname(__file__), "..", "results",
                     "bench_cache")

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "results",
                          "BENCH_prefill.json")


def write_bench_json(section: str, payload: dict, path: str = None) -> str:
    """Merge `payload` under `section` of results/BENCH_prefill.json —
    the machine-readable perf artifact tracked PR-over-PR (checked into
    results/ and uploaded by CI). Each benchmark owns one section, so
    partial runs never clobber the others'."""
    path = path or BENCH_JSON
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[section] = payload
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path

# Low-entropy corpus + FFN-dominant geometry: the model trains to a
# meaningful perplexity in ~400 CPU steps and the FFN is ~6x the
# attention cost, so sparsity effects are visible in both quality and
# wall-clock numbers.
BENCH_CFG = ModelConfig(
    name="bench-lm", arch="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=1024, vocab=256, remat=False,
    ff=FastForwardConfig(enabled=True, block_size=32, tile=128),
    param_dtype="float32")

DATA_KW = dict(branch=8, alpha=1.5)


def capture_ffn_inputs(params, cfg: ModelConfig, tokens):
    """Forward pass collecting per-layer FFN inputs and attention probs.

    Returns (ffn_inputs [L,B,T,D], attn_probs [L,B,H,T,T])."""
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    ffn_in, probs_all = [], []
    n_layers = cfg.n_layers
    for i in range(n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        xn = D.apply_norm(cfg, lp["ln1"], x)
        q = A.project_q(lp["attn"], xn, pos, cfg.rope_theta)
        k, v = A.project_kv(lp["attn"], xn, pos, cfg.rope_theta)
        mask = A.causal_mask(T, T)
        Kv = k.shape[2]
        rep = q.shape[2] // Kv
        qg = q.reshape(B, T, Kv, rep, -1)
        s = jnp.einsum("btgrk,bsgk->bgrts", qg, k) / np.sqrt(q.shape[-1])
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)                  # [B,Kv,rep,T,T]
        probs_all.append(p.reshape(B, -1, T, T))
        o = jnp.einsum("bgrts,bsgk->btgrk", p.astype(v.dtype), v)
        o = o.reshape(B, T, q.shape[2], -1)
        x = x + A.output_proj(lp["attn"], o)
        xn2 = D.apply_norm(cfg, lp["ln2"], x)
        ffn_in.append(xn2)
        from repro.core import fastforward as FF
        x = x + FF.ff_dense(lp["ffn"], cfg, xn2)
    return jnp.stack(ffn_in), jnp.stack(probs_all)


def build_fixture(train_steps=400, distill_steps=200, force=False):
    ck = os.path.join(CACHE, "model")
    if os.path.exists(os.path.join(ck, "manifest.msgpack")) and not force:
        params, meta = load_checkpoint(ck)
        importance = np.asarray(meta["importance"])
        return BENCH_CFG, params, importance

    cfg = BENCH_CFG
    params = init_params(D.specs(cfg), jax.random.key(0))
    init_state, train_step = make_train_step(cfg, lr=3e-3)
    state = init_state(params)
    step_fn = jax.jit(train_step, donate_argnums=0)
    data = batches(cfg.vocab, 8, 128, seed=0, **DATA_KW)
    for i in range(train_steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step_fn(state, b)
    params = state["params"]

    # distill predictor + compensator per layer on harvested FFN inputs
    cap_toks = jnp.asarray(
        next(batches(cfg.vocab, 8, 128, seed=0, stream=7009,
                     **DATA_KW))["tokens"])
    ffn_in, probs = capture_ffn_inputs(params, cfg, cap_toks)
    data2 = batches(cfg.vocab, 4, 128, seed=0, stream=7100, **DATA_KW)
    layers = []
    for li in range(cfg.n_layers):
        def gen(li=li):
            while True:
                b = {k: jnp.asarray(v) for k, v in next(data2).items()}
                fi, _ = capture_ffn_inputs(params, cfg, b["tokens"])
                xb = fi[li]                              # [B,T,D]
                B, T, Dm = xb.shape
                N = cfg.ff.block_size
                yield xb.reshape(B * (T // N), N, Dm)

        lp = jax.tree.map(lambda a: a[li], params["layers"])
        tp, _ = DI.train_fastforward_layer(
            lp["ffn"], gen(), cfg, jax.random.key(100 + li),
            steps=distill_steps, lr=2e-3)
        layers.append(tp)

    # write distilled pred/comp back into the stacked layer params
    new_layers = dict(params["layers"])
    new_ffn = dict(new_layers["ffn"])
    new_ffn["pred"] = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[l["pred"] for l in layers])
    new_ffn["comp"] = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[l["comp"] for l in layers])
    new_layers["ffn"] = new_ffn
    params = dict(params, layers=new_layers)

    # layer importance (Eq. 23) from calibration attention mass
    imp = [float(SCHED.nonsink_attention_mass(probs[li], cfg.ff.block_size))
           for li in range(cfg.n_layers)]
    save_checkpoint(ck, params, {"importance": [float(x) for x in imp]})
    return cfg, params, np.asarray(imp)


def perplexity(cfg, params, budgets=None, n_batches=4, enabled=True,
               stream=9933):
    """Held-out LM perplexity (same language as training — seed 0 —
    but a fresh sampling stream) through the mask-path forward."""
    from repro.training.train import cross_entropy
    use_cfg = cfg if enabled else cfg.with_ff(enabled=False)
    data = batches(cfg.vocab, 8, 128, seed=0, stream=stream, **DATA_KW)

    @jax.jit
    def ce(tokens, labels):
        logits, _ = D.forward(params, use_cfg, {"tokens": tokens},
                              budgets=budgets)
        return cross_entropy(logits, labels)

    tot = 0.0
    for _ in range(n_batches):
        b = next(data)
        tot += float(ce(jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])))
    return float(np.exp(tot / n_batches))
