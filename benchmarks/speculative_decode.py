"""Self-speculative decode A/B: sparse-draft / dense-verify vs plain
one-token-per-tick decode on a generation-heavy stream.

The draft model is the SAME weights under a sparser registered
SparsityPlan (the turbo tier), so speculation costs zero extra
parameters and zero extra compiles beyond the two chunk entries
(`draft_steps` / `verify_chunk`). Each speculative decode tick drafts
k tokens per active row under the draft plan, verifies all k+1
positions in ONE chunk-scored call under each request's own plan, and
emits the longest agreeing prefix plus the verifier's bonus token —
greedy output is BIT-identical to speculation off (asserted here), the
draft plan buys latency only.

Writes the ``speculative_decode`` section of
``results/BENCH_prefill.json``: per-verify-tier acceptance rate and
emitted tokens per speculated row-tick, decode ticks and wall-clock
both ways, and the acceptance booleans (bit-identity; tokens per
decode tick strictly above the non-speculative baseline — i.e.
strictly fewer decode ticks for the same emitted tokens).

Wall-clock on the reduced CPU config is dispatch-overhead-bound and
noisy (each speculative tick runs 2 jitted calls instead of 1, and the
chunk scan serializes k+1 tiny steps); the structural win is the tick
count, which is deterministic. The analytical framing: a speculative
tick costs 1 draft pass (k steps at the draft tier's FLOP fraction)
plus 1 verify chunk (k+1 steps at the verify tier) and advances
~(1 + k * acceptance) tokens — on accelerators where per-tick launch
overhead dominates small-batch decode, fewer ticks is the win.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax

from benchmarks.common import write_bench_json
from repro.configs import get_config
from repro.core.fastforward import resolve_plan
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, Request,
                           SpeculativeConfig, drive_stream)
from repro.serving.runtime import make_runtime

SLOTS = 4
PREFILL_BATCH = 4
REQUESTS = 12
PROMPT_RANGE = (24, 64)       # short prompts ...
MAX_NEW_RANGE = (32, 56)      # ... long generations: decode dominates
SPEC_K = 4
DRAFT_TIER = "turbo"
EFFORTS = ("balanced", "turbo")   # verify-tier mix across the stream


def _workload(cfg, seed=11, requests=REQUESTS):
    """Generation-heavy burst: everyone arrives at ~t=0, so decode runs
    with full rows and the per-tick comparison is about speculation,
    not admission timing."""
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(0, cfg.vocab,
                                 rng.integers(*PROMPT_RANGE)))
               for _ in range(requests)]
    max_news = [int(v) for v in rng.integers(*MAX_NEW_RANGE,
                                             size=requests)]
    arrivals = np.sort(rng.exponential(0.001, size=requests))
    return [Request(rid=i, prompt=prompts[i], max_new=max_news[i],
                    arrival_time=arrivals[i],
                    effort=EFFORTS[i % len(EFFORTS)])
            for i in range(requests)]


def _drive(runtime, requests, cache_len, speculative):
    sched = ContinuousBatchingScheduler(
        runtime, n_slots=SLOTS, cache_len=cache_len,
        prefill_batch=PREFILL_BATCH, speculative=speculative)
    counts0 = sched.warmup()
    t0 = time.perf_counter()
    drive_stream(sched, requests)
    wall = time.perf_counter() - t0
    flat = None
    if None not in counts0.values():
        flat = runtime.compile_counts() == counts0
        assert flat, "recompiled mid-stream"
    outs = sched.finished
    assert len(outs) == len(requests)
    gen = sum(len(o.tokens) for o in outs.values())
    return sched, outs, gen, wall, flat


def run(csv=True, requests=REQUESTS):
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    plans = tuple(
        dataclasses.replace(resolve_plan(cfg, effort=e), name=e)
        for e in EFFORTS)
    runtime = make_runtime(cfg, params, plans=plans)
    reqs = _workload(cfg, requests=requests)
    N = runtime.block_size
    cache_len = (-(-max(len(r.prompt) for r in reqs) // N) * N
                 + max(r.max_new for r in reqs))

    spec = SpeculativeConfig(k=SPEC_K, draft=DRAFT_TIER)
    off_sched, off_outs, off_gen, off_wall, off_flat = _drive(
        runtime, reqs, cache_len, None)
    on_sched, on_outs, on_gen, on_wall, on_flat = _drive(
        runtime, reqs, cache_len, spec)

    identical = all(off_outs[r.rid].tokens == on_outs[r.rid].tokens
                    for r in reqs)
    ss = on_sched.speculative_stats()
    off_tpt = off_gen / max(off_sched.n_decode_steps, 1)
    on_tpt = on_gen / max(on_sched.n_decode_steps, 1)
    section = {
        "config": {"slots": SLOTS, "prefill_batch": PREFILL_BATCH,
                   "requests": len(reqs), "k": SPEC_K,
                   "draft_tier": DRAFT_TIER, "efforts": list(EFFORTS),
                   "prompt_range": list(PROMPT_RANGE),
                   "max_new_range": list(MAX_NEW_RANGE),
                   "arch": cfg.name, "reduced": True},
        "off": {"decode_ticks": off_sched.n_decode_steps,
                "tokens": off_gen,
                "tokens_per_decode_tick": round(off_tpt, 3),
                "wall_s": round(off_wall, 3)},
        "on": {"decode_ticks": on_sched.n_decode_steps,
               "tokens": on_gen,
               "tokens_per_decode_tick": round(on_tpt, 3),
               "wall_s": round(on_wall, 3),
               "spec_ticks": ss["spec_ticks"],
               "per_tier": [
                   {k: row[k] for k in ("name", "draft_plan", "row_ticks",
                                        "drafted", "accepted",
                                        "acceptance_rate", "emitted",
                                        "tokens_per_row_tick")}
                   for row in ss["plans"] if row["row_ticks"]]},
        "decode_tick_ratio": round(off_sched.n_decode_steps
                                   / max(on_sched.n_decode_steps, 1), 3),
        # acceptance: same emitted tokens from strictly fewer decode
        # ticks (tokens/tick strictly above the baseline), bit-identical
        # greedy outputs, flat jit cache after warmup both ways
        "outputs_bit_identical": bool(identical),
        "tokens_per_tick_above_baseline": bool(on_tpt > off_tpt),
        "compile_counts_flat": (None if off_flat is None or on_flat is None
                                else bool(off_flat and on_flat)),
        "note": ("wall-clock on the reduced CPU config is dispatch-"
                 "overhead-bound (2 jitted calls + a k+1-step scan per "
                 "speculative tick); the structural, deterministic win "
                 "is the decode-tick count"),
    }
    write_bench_json("speculative_decode", section)

    rows = [
        ("spec_decode_ticks_off", f"{off_sched.n_decode_steps}",
         f"{off_gen} tokens, {off_tpt:.2f} tok/tick"),
        ("spec_decode_ticks_on", f"{on_sched.n_decode_steps}",
         f"{on_gen} tokens, {on_tpt:.2f} tok/tick, k={SPEC_K} "
         f"draft={DRAFT_TIER}"),
        ("spec_decode_tick_ratio", f"{section['decode_tick_ratio']:.2f}",
         "off/on decode ticks (target > 1.0)"),
        ("spec_outputs_bit_identical", f"{identical}",
         "acceptance: greedy outputs identical speculation on vs off"),
        ("spec_tokens_per_tick_above_baseline",
         f"{section['tokens_per_tick_above_baseline']}",
         "acceptance: tokens per decode tick strictly above baseline"),
    ]
    for row in (ss["plans"] if ss else []):
        if not row["row_ticks"]:
            continue
        rows.append((
            f"spec_acceptance_{row['name']}",
            f"{row['acceptance_rate']}",
            f"draft={row['draft_plan']}, {row['accepted']}/"
            f"{row['drafted']} drafts accepted, "
            f"{row['tokens_per_row_tick']} tok/row-tick"))
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=REQUESTS,
                   help="stream length (CI smoke uses a reduced count)")
    args = p.parse_args()
    run(requests=args.requests)
