"""Paper Table 2/3 analog: sparse-vs-dense quality across sparsity
levels (LongBench proxy = LM perplexity relative gap on held-out
synthetic data; prefill-and-generation uses the same predictor, as in
Table 3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_fixture, perplexity


def run(csv=True):
    cfg, params, importance = build_fixture()
    p_dense = perplexity(cfg, params, enabled=False)
    rows = [("fidelity_dense", f"{p_dense:.4f}", "rel_gap=0.0%")]
    gaps = {}
    for s in (0.3, 0.4, 0.5):
        c = cfg.with_ff(sparsity=s)
        p = perplexity(c, params)
        gap = 100.0 * (p - p_dense) / p_dense
        gaps[s] = gap
        rows.append((f"fidelity_sparse_{int(s*100)}", f"{p:.4f}",
                     f"rel_gap={gap:.2f}%"))
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    # paper ordering: quality degrades monotonically-ish with sparsity,
    # and the 50% gap stays moderate (paper: <6% accuracy drop)
    assert gaps[0.3] <= gaps[0.5] + 1.0, gaps
    return rows


if __name__ == "__main__":
    run()
