"""Layer-wise vs uniform SparsityPlan at EQUAL global budget.

The paper's headline composition (§3.4): the layer-wise scheduler
(Algorithm 1) reallocates a fixed global tile budget toward important
layers. Since the SparsityPlan redesign that schedule runs on the
FLOP-reducing gather/Pallas path, so this benchmark drives the SAME
continuous-batching serving stack twice — once under a uniform plan,
once under a layer-wise plan holding the identical total tile count —
and reports tok/s, TTFT p50, and analytical FFN FLOPs per token.

On the reduced CPU config wall-clock is overhead-bound (the XLA gather
path masks invalid tiles rather than skipping them — the Pallas kernel
is the TPU side of the FLOP skip), so the load-bearing numbers are the
equal-budget accounting (`total_tiles` must match) and the analytical
FLOPs; tok/s is tracked for trend only.

Writes the ``layerwise_vs_uniform`` section of
``results/BENCH_prefill.json`` and emits ``name,value,derived`` CSV
rows (harness contract).
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import write_bench_json
from repro.configs import get_config
from repro.core.fastforward import resolve_plan
from repro.core.scheduler import SparsityPlan
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import ContinuousBatchingScheduler, Request, drive_stream
from repro.serving.runtime import make_runtime

SLOTS = 4
PREFILL_BATCH = 4
REQUESTS = 24
PROMPT_RANGE = (96, 256)       # 3-8 blocks (reduced block_size 32):
                               # interior sparse blocks dominate
MAX_NEW_RANGE = (4, 24)
RATE = 120.0                   # deep backlog: prefill-bound


def _workload(cfg, seed=0, requests=REQUESTS):
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(0, cfg.vocab,
                                 rng.integers(*PROMPT_RANGE)))
               for _ in range(requests)]
    max_news = [int(v) for v in rng.integers(*MAX_NEW_RANGE,
                                             size=requests)]
    arrivals = np.sort(np.cumsum(rng.exponential(1.0 / RATE,
                                                 size=requests)))
    return prompts, max_news, arrivals


def _ffn_flops_per_token(cfg, plan) -> float:
    """Analytical gated-FFN FLOPs/token under a plan (3 matmuls)."""
    dense = 3 * 2 * cfg.d_model * cfg.d_ff
    return dense * plan.flop_frac()


def _drive(cfg, params, plan, prompts, max_news, arrivals):
    runtime = make_runtime(cfg, params, plans=(plan,))
    N = runtime.block_size
    cache_len = (-(-max(len(p) for p in prompts) // N) * N
                 + max(max_news))
    sched = ContinuousBatchingScheduler(runtime, n_slots=SLOTS,
                                        cache_len=cache_len,
                                        prefill_batch=PREFILL_BATCH)
    counts0 = sched.warmup()
    requests = [Request(rid=i, prompt=prompts[i], max_new=max_news[i],
                        arrival_time=arrivals[i])
                for i in range(len(prompts))]
    wall = drive_stream(sched, requests)
    if None not in counts0.values():
        assert runtime.compile_counts() == counts0, "recompiled"
    outs = sched.finished
    gen = sum(len(o.tokens) for o in outs.values())
    ttfts = np.array([o.ttft_seconds for o in outs.values()])
    return {
        "tokens_per_s": round(gen / wall, 1),
        "ttft_p50_ms": round(float(np.percentile(ttfts, 50)) * 1e3, 2),
        "ttft_p99_ms": round(float(np.percentile(ttfts, 99)) * 1e3, 2),
        "ffn_flops_per_token": round(_ffn_flops_per_token(cfg, plan)),
        "ffn_flop_frac": round(plan.flop_frac(), 4),
        "total_tiles": int(sum(plan.tile_counts)),
        "tile_counts": list(plan.tile_counts),
    }


def run(csv=True, requests=REQUESTS):
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.key(0))
    prompts, max_news, arrivals = _workload(cfg, requests=requests)

    uniform = resolve_plan(cfg)                      # ceil(keep * n)
    # synthetic ramp importance (offline Algorithm 1 calibration stands
    # in for calibrate_layer_importance on the reduced config): later
    # layers matter more -> the waterfill shifts tiles toward them
    importance = np.linspace(1.0, 3.0, cfg.n_layers)
    n_tiles = cfg.d_ff // cfg.ff.tile
    layerwise = SparsityPlan.from_importance(
        importance, keep=float(np.mean(uniform.keep_fracs)),
        n_tiles=n_tiles, tile=cfg.ff.tile, name="balanced-layerwise")

    res_u = _drive(cfg, params, uniform, prompts, max_news, arrivals)
    res_l = _drive(cfg, params, layerwise, prompts, max_news, arrivals)
    # equal global budget: largest-remainder rounding pins the totals
    assert res_l["total_tiles"] == res_u["total_tiles"], (res_u, res_l)

    payload = {
        "uniform": res_u,
        "layerwise": res_l,
        "importance": [round(float(v), 3) for v in importance],
        "equal_budget_total_tiles": res_u["total_tiles"],
        "requests": len(prompts),
    }
    path = write_bench_json("layerwise_vs_uniform", payload)

    rows = [
        ("plan_uniform_tok_s", res_u["tokens_per_s"],
         f"ttft_p50={res_u['ttft_p50_ms']}ms"),
        ("plan_layerwise_tok_s", res_l["tokens_per_s"],
         f"ttft_p50={res_l['ttft_p50_ms']}ms "
         f"counts={res_l['tile_counts']}"),
        ("plan_equal_budget_tiles", res_u["total_tiles"],
         "layerwise total == uniform total"),
        ("plan_ffn_flops_per_token", res_l["ffn_flops_per_token"],
         f"uniform={res_u['ffn_flops_per_token']}"),
    ]
    if csv:
        for name, value, derived in rows:
            print(f"{name},{value},{derived}")
        print(f"# wrote {path}")
    return payload


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=REQUESTS,
                   help="reduced CI smoke uses a smaller stream")
    args = p.parse_args()
    run(requests=args.requests)
