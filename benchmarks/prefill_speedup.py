"""Paper Fig. 6-7: FFN-module and end-to-end compute-bound prefill
speedup from FastForward sparsity.

Compute-bound speedup = FLOPs(dense) / FLOPs(sparse) — the paper's Fig 7
metric ("corresponding to a 45% reduction in FLOPs at 50% sparsity").
The sparse cost honestly includes the dense first/last blocks, the
expert predictor, and the error compensator. Validates: peak e2e
speedup ~1.45x at 50% sparsity in the 2k-8k context range, decaying at
long context as quadratic attention dominates (paper Fig. 7).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_bench_json
from benchmarks.flops_crossover import GEOMETRIES, layer_flops


def predictor_flops(d_model, d_ff, T, block):
    r = max(d_model // 16, 8)
    r = 1 << (r - 1).bit_length()
    n_blocks = T // block
    per_block = 2 * block * d_model + 2 * (d_model * r + r * d_ff)
    return n_blocks * per_block


def compensator_flops(d_model, T):
    rp = d_model // 8
    return 2 * T * d_model * rp * 2


def e2e_speedup(d_model, d_ff, T, sparsity, block=128,
                dense_first_last=True, with_overheads=True):
    f = layer_flops(d_model, d_ff, T)
    dense = f["attn"] + f["ffn"]
    n_blocks = max(T // block, 1)
    dense_blocks = 2 if (dense_first_last and n_blocks > 2) else 0
    frac_sparse_tokens = (n_blocks - dense_blocks) / n_blocks
    keep = 1.0 - sparsity
    ffn_sparse = f["ffn"] * ((1 - frac_sparse_tokens)
                             + frac_sparse_tokens * keep)
    over = 0.0
    if with_overheads:
        over = predictor_flops(d_model, d_ff, T, block) \
            + compensator_flops(d_model, T)
    sparse = f["attn"] + ffn_sparse + over
    return dense / sparse


def ffn_module_speedup(d_model, d_ff, T, sparsity, block=128):
    """Fig. 6 analog: FFN sublayer only."""
    f = layer_flops(d_model, d_ff, T)["ffn"]
    n_blocks = max(T // block, 1)
    dense_blocks = min(2, n_blocks)
    frac = (n_blocks - dense_blocks) / n_blocks
    keep = 1.0 - sparsity
    sparse = f * ((1 - frac) + frac * keep) \
        + predictor_flops(d_model, d_ff, T, block)
    return f / sparse


# ------------------------- dual-budget attention (block-sparse prefill)


def attention_flop_fraction(T, a_l, attn_tiles, blk=128):
    """Analytical fraction of QUADRATIC attention FLOPs a block-sparse
    prefill keeps at context T under per-layer budget count a_l (virtual
    attn_tiles grid). Mirrors `select_kv_blocks` exactly: query block i
    sees nv = i+1 causally-valid KV blocks and keeps
    clip(ceil(a_l * nv / attn_tiles), min(2, nv), nv) of them — the
    forced sink+diagonal floor is why the realized fraction sits above
    a_l/attn_tiles at short contexts and converges to it as the causal
    ramp grows."""
    nc = max(T // blk, 1)
    nv = np.arange(1, nc + 1, dtype=np.float64)
    kept = np.ceil(a_l * nv / attn_tiles)
    kept = np.clip(kept, np.minimum(2.0, nv), nv)
    return float(kept.sum() / nv.sum())


def dual_budget_fracs(d_model, d_ff, T, sparsity, a_l, attn_tiles,
                      blk=128):
    """(ffn_only, dual) total-layer FLOP fractions vs dense at context
    T. The attention budget scales only the quadratic QK^T/AV term;
    projections and the FFN budget are shared by both plans — so the
    gap between the two IS the attention win, and it grows with T."""
    f = layer_flops(d_model, d_ff, T)
    dense = f["attn"] + f["ffn"]
    keep_ffn = 1.0 - sparsity
    af = attention_flop_fraction(T, a_l, attn_tiles, blk)
    proj = f["attn"] - f["attn_quad"]
    ffn_only = (f["attn"] + keep_ffn * f["ffn"]) / dense
    dual = (proj + af * f["attn_quad"] + keep_ffn * f["ffn"]) / dense
    return ffn_only, dual


def run_attention_sparsity(csv=True, requests=16):
    """`attention_sparsity` section: (a) the reduced serving stack
    driven twice at MATCHED FFN budget — once FFN-only, once with the
    dual-budget plan's block-sparse attention on — reporting tok/s +
    TTFT p50; (b) the analytical attention-FLOP fraction and total
    FLOP fraction vs context 1K-16K (llama-8b geometry), asserting the
    dual budget beats the FFN-only plan at 8K+."""
    import jax
    from benchmarks.sparsity_plan import _drive, _workload
    from repro.configs import get_config
    from repro.core.fastforward import resolve_plan
    from repro.models.registry import get_model
    from repro.nn.param import init_params

    cfg = get_config("tinyllama-1.1b", reduced=True)
    cfg_attn = cfg.with_ff(attn_sparsity=0.5, attn_tiles=8)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    prompts, max_news, arrivals = _workload(cfg, requests=requests)

    ffn_only = resolve_plan(cfg)
    dual = resolve_plan(cfg_attn)
    # matched global FFN budget: the attention budget rides on TOP of
    # the identical tile schedule, so the serving delta isolates it
    assert dual.tile_counts == ffn_only.tile_counts
    assert dual.has_attn and not ffn_only.has_attn

    res_f = _drive(cfg, params, ffn_only, prompts, max_news, arrivals)
    res_d = _drive(cfg_attn, params, dual, prompts, max_news, arrivals)
    res_d["attn_counts"] = list(dual.attn_counts)
    res_d["attn_block_frac"] = round(dual.attn_flop_frac(), 4)

    # analytical curve, paper geometry: balanced tier (keep 0.5) on the
    # default virtual grid
    d, dff, _ = GEOMETRIES["llama-8b"]
    attn_tiles, a_l, s = 16, 8, 0.5
    contexts = [1024, 2048, 4096, 8192, 16384]
    curve = {}
    for T in contexts:
        fo, du = dual_budget_fracs(d, dff, T, s, a_l, attn_tiles)
        curve[str(T)] = {
            "attn_flop_frac": round(
                attention_flop_fraction(T, a_l, attn_tiles), 4),
            "total_frac_ffn_only": round(fo, 4),
            "total_frac_dual": round(du, 4),
        }
    # acceptance: the dual budget's total FLOP fraction must sit below
    # the FFN-only plan's at 8K+ where quadratic attention dominates
    for T in (8192, 16384):
        c = curve[str(T)]
        assert c["total_frac_dual"] < c["total_frac_ffn_only"], c

    payload = {
        "serving_matched_ffn_budget": {
            "ffn_only": res_f, "dual_budget": res_d,
            "requests": len(prompts),
            "note": "reduced CPU config: the XLA masked path pays dense "
                    "attention bytes (the Pallas kernel is the TPU side "
                    "of the skip), so tok/s is tracked for trend; the "
                    "load-bearing numbers are the analytical fractions",
        },
        "analytical_llama8b_s50": {
            "attn_tiles": attn_tiles, "a_l": a_l, "ffn_sparsity": s,
            "per_context": curve,
        },
    }
    path = write_bench_json("attention_sparsity", payload)
    rows = [
        ("attn_ffn_only_tok_s", res_f["tokens_per_s"],
         f"ttft_p50={res_f['ttft_p50_ms']}ms"),
        ("attn_dual_budget_tok_s", res_d["tokens_per_s"],
         f"ttft_p50={res_d['ttft_p50_ms']}ms "
         f"attn_counts={res_d['attn_counts']}"),
        ("attn_total_frac_8k", curve["8192"]["total_frac_dual"],
         f"ffn_only={curve['8192']['total_frac_ffn_only']}"),
        ("attn_total_frac_16k", curve["16384"]["total_frac_dual"],
         f"ffn_only={curve['16384']['total_frac_ffn_only']}"),
    ]
    if csv:
        for name, value, derived in rows:
            print(f"{name},{value},{derived}")
        print(f"# wrote {path}")
    return payload


def run(csv=True, requests=16):
    rows = []
    contexts = [512, 1024, 2048, 4096, 8192, 16384, 32768]
    peak = {}
    for name, (d, dff, L) in GEOMETRIES.items():
        for s in (0.3, 0.4, 0.5):
            sp = [e2e_speedup(d, dff, T, s) for T in contexts]
            peak[(name, s)] = max(sp)
            rows.append((f"e2e_speedup_{name}_s{int(s*100)}",
                         f"{max(sp):.3f}",
                         ";".join(f"{T}:{v:.3f}"
                                  for T, v in zip(contexts, sp))))
        ffn_sp = ffn_module_speedup(d, dff, 4096, 0.5)
        rows.append((f"ffn_speedup_{name}_s50_4k", f"{ffn_sp:.3f}", ""))
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    # machine-readable section: compute-bound speedup vs dense (the
    # paper's Fig 6-7 metric) per geometry at 50% sparsity
    write_bench_json("analytical_speedup_vs_dense", {
        "e2e_peak_s50": {name: round(peak[(name, 0.5)], 3)
                         for name, _ in GEOMETRIES.items()},
        "ffn_module_s50_4k": {
            name: round(ffn_module_speedup(d, dff, 4096, 0.5), 3)
            for name, (d, dff, L) in GEOMETRIES.items()},
        "note": "FLOPs(dense)/FLOPs(sparse) incl. dense first/last "
                "blocks, predictor, compensator (paper Fig. 7)",
    })
    # paper-claim validation: up to ~1.45x at 50% on the 8B model,
    # peaking mid-context, decaying at 32K
    p8 = peak[("llama-8b", 0.5)]
    assert 1.30 < p8 < 1.55, f"peak 8B e2e speedup {p8} vs paper's 1.45x"
    sp_curve = [e2e_speedup(4096, 14336, T, 0.5) for T in contexts]
    t_peak = contexts[int(np.argmax(sp_curve))]
    assert 2048 <= t_peak <= 16384, f"peak at {t_peak}, paper says 2k-8k"
    assert sp_curve[-1] < max(sp_curve), "speedup must decay at 32K"
    run_attention_sparsity(csv=csv, requests=requests)
    return rows


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=16,
                   help="reduced CI smoke uses a smaller stream")
    args = p.parse_args()
    run(requests=args.requests)
