"""Paper Fig. 6-7: FFN-module and end-to-end compute-bound prefill
speedup from FastForward sparsity.

Compute-bound speedup = FLOPs(dense) / FLOPs(sparse) — the paper's Fig 7
metric ("corresponding to a 45% reduction in FLOPs at 50% sparsity").
The sparse cost honestly includes the dense first/last blocks, the
expert predictor, and the error compensator. Validates: peak e2e
speedup ~1.45x at 50% sparsity in the 2k-8k context range, decaying at
long context as quadratic attention dominates (paper Fig. 7).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_bench_json
from benchmarks.flops_crossover import GEOMETRIES, layer_flops


def predictor_flops(d_model, d_ff, T, block):
    r = max(d_model // 16, 8)
    r = 1 << (r - 1).bit_length()
    n_blocks = T // block
    per_block = 2 * block * d_model + 2 * (d_model * r + r * d_ff)
    return n_blocks * per_block


def compensator_flops(d_model, T):
    rp = d_model // 8
    return 2 * T * d_model * rp * 2


def e2e_speedup(d_model, d_ff, T, sparsity, block=128,
                dense_first_last=True, with_overheads=True):
    f = layer_flops(d_model, d_ff, T)
    dense = f["attn"] + f["ffn"]
    n_blocks = max(T // block, 1)
    dense_blocks = 2 if (dense_first_last and n_blocks > 2) else 0
    frac_sparse_tokens = (n_blocks - dense_blocks) / n_blocks
    keep = 1.0 - sparsity
    ffn_sparse = f["ffn"] * ((1 - frac_sparse_tokens)
                             + frac_sparse_tokens * keep)
    over = 0.0
    if with_overheads:
        over = predictor_flops(d_model, d_ff, T, block) \
            + compensator_flops(d_model, T)
    sparse = f["attn"] + ffn_sparse + over
    return dense / sparse


def ffn_module_speedup(d_model, d_ff, T, sparsity, block=128):
    """Fig. 6 analog: FFN sublayer only."""
    f = layer_flops(d_model, d_ff, T)["ffn"]
    n_blocks = max(T // block, 1)
    dense_blocks = min(2, n_blocks)
    frac = (n_blocks - dense_blocks) / n_blocks
    keep = 1.0 - sparsity
    sparse = f * ((1 - frac) + frac * keep) \
        + predictor_flops(d_model, d_ff, T, block)
    return f / sparse


def run(csv=True):
    rows = []
    contexts = [512, 1024, 2048, 4096, 8192, 16384, 32768]
    peak = {}
    for name, (d, dff, L) in GEOMETRIES.items():
        for s in (0.3, 0.4, 0.5):
            sp = [e2e_speedup(d, dff, T, s) for T in contexts]
            peak[(name, s)] = max(sp)
            rows.append((f"e2e_speedup_{name}_s{int(s*100)}",
                         f"{max(sp):.3f}",
                         ";".join(f"{T}:{v:.3f}"
                                  for T, v in zip(contexts, sp))))
        ffn_sp = ffn_module_speedup(d, dff, 4096, 0.5)
        rows.append((f"ffn_speedup_{name}_s50_4k", f"{ffn_sp:.3f}", ""))
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    # machine-readable section: compute-bound speedup vs dense (the
    # paper's Fig 6-7 metric) per geometry at 50% sparsity
    write_bench_json("analytical_speedup_vs_dense", {
        "e2e_peak_s50": {name: round(peak[(name, 0.5)], 3)
                         for name, _ in GEOMETRIES.items()},
        "ffn_module_s50_4k": {
            name: round(ffn_module_speedup(d, dff, 4096, 0.5), 3)
            for name, (d, dff, L) in GEOMETRIES.items()},
        "note": "FLOPs(dense)/FLOPs(sparse) incl. dense first/last "
                "blocks, predictor, compensator (paper Fig. 7)",
    })
    # paper-claim validation: up to ~1.45x at 50% on the 8B model,
    # peaking mid-context, decaying at 32K
    p8 = peak[("llama-8b", 0.5)]
    assert 1.30 < p8 < 1.55, f"peak 8B e2e speedup {p8} vs paper's 1.45x"
    sp_curve = [e2e_speedup(4096, 14336, T, 0.5) for T in contexts]
    t_peak = contexts[int(np.argmax(sp_curve))]
    assert 2048 <= t_peak <= 16384, f"peak at {t_peak}, paper says 2k-8k"
    assert sp_curve[-1] < max(sp_curve), "speedup must decay at 32K"
    return rows


if __name__ == "__main__":
    run()
