"""Paper Tables 4-7 analogs on the distilled bench fixture.

LongBench is unavailable offline; the proxy metric is LM perplexity (or
relative output fidelity) of the sparse model vs its dense self, and
the deliverable is the ORDERING the paper reports:
  Table 4: layerwise schedule >= uniform
  Table 5: dense first&last > dense first > none
  Table 6: with compensator >= without
  Table 7: oracle >= trained predictor > first-block static
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import build_fixture, perplexity, capture_ffn_inputs
from repro.core import fastforward as FF
from repro.core import distill as DI
from repro.core import sparse_ffn as S
from repro.data.synthetic import batches
from benchmarks.common import DATA_KW


def layerwise_vs_uniform(cfg, params, importance):
    uni = jnp.asarray(FF.layer_budgets(cfg.with_ff(layerwise_schedule=False)),
                      jnp.float32)
    sched = jnp.asarray(FF.layer_budgets(cfg, importance), jnp.float32)
    p_uni = perplexity(cfg, params, budgets=uni)
    p_sched = perplexity(cfg, params, budgets=sched)
    return [("ablation_uniform_50", f"{p_uni:.4f}", "ppl"),
            ("ablation_layerwise_50", f"{p_sched:.4f}",
             f"budgets={np.round(np.asarray(sched),3).tolist()}")]


def dense_blocks(cfg, params):
    rows = []
    for first, last, tag in [(False, False, "none"), (True, False, "first"),
                             (True, True, "first_last")]:
        c = cfg.with_ff(dense_first_block=first, dense_last_block=last)
        rows.append((f"ablation_dense_{tag}",
                     f"{perplexity(c, params):.4f}", "ppl"))
    return rows


def compensator(cfg, params):
    p_with = perplexity(cfg, params)
    p_without = perplexity(cfg.with_ff(use_compensator=False), params)
    return [("ablation_comp_on", f"{p_with:.4f}", "ppl"),
            ("ablation_comp_off", f"{p_without:.4f}", "ppl")]


def predictor_variants(cfg, params, n_batches=3):
    """Table 7: fidelity of FFN outputs under oracle / trained / static
    first-block masks, averaged over layers and blocks."""
    keep = 1.0 - cfg.ff.sparsity
    tile = cfg.ff.tile
    N = cfg.ff.block_size
    data = batches(cfg.vocab, 4, 128, seed=0, stream=7700, **DATA_KW)
    errs = {"oracle": [], "trained": [], "static": []}
    for _ in range(n_batches):
        toks = jnp.asarray(next(data)["tokens"])
        ffn_in, _ = capture_ffn_inputs(params, cfg, toks)
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])["ffn"]
            x = ffn_in[li]
            B, T, Dm = x.shape
            xb = x.reshape(B * (T // N), N, Dm)
            y_dense = S.ffn_dense(lp, xb, cfg.act)
            m_oracle, _ = DI.oracle_mask(lp, xb, keep, tile, cfg.act)
            m_trained = DI.predicted_mask(lp, xb, keep, tile)
            m_static = jnp.broadcast_to(m_oracle[:1], m_oracle.shape)
            for tag, m in [("oracle", m_oracle), ("trained", m_trained),
                           ("static", m_static)]:
                y = S.ffn_masked(lp, xb, m[..., None, :], cfg.act)
                errs[tag].append(float(
                    jnp.mean((y - y_dense) ** 2) / jnp.mean(y_dense ** 2)))
    rows = [(f"ablation_pred_{k}", f"{np.mean(v):.5f}", "rel_mse")
            for k, v in errs.items()]
    # NOTE: the synthetic corpus is a STATIONARY Markov chain, so the
    # first-block-static baseline (GRIFFIN) is unusually strong here —
    # there is no topic drift for the dynamic predictor to exploit. The
    # paper's Table 7 ordering (trained << static) is demonstrated on a
    # context-shifting fixture in tests/test_system.py; on this corpus
    # we assert the oracle ordering and near-parity of trained/static.
    assert np.mean(errs["oracle"]) <= np.mean(errs["trained"]) * 1.1
    assert np.mean(errs["oracle"]) < np.mean(errs["static"])
    assert np.mean(errs["trained"]) < np.mean(errs["static"]) * 1.15, \
        (np.mean(errs["trained"]), np.mean(errs["static"]))
    return rows


def run(csv=True):
    cfg, params, importance = build_fixture()
    rows = []
    rows += layerwise_vs_uniform(cfg, params, importance)
    rows += dense_blocks(cfg, params)
    rows += compensator(cfg, params)
    rows += predictor_variants(cfg, params)
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    run()
