"""Roofline table from dry-run JSONL records (launch/dryrun.py output).

Renders EXPERIMENTS.md §Roofline rows: per (arch, shape, mesh) the three
terms in seconds, the dominant bottleneck, and the useful-FLOPs ratio.
"""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def render_markdown(recs):
    out = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | bottleneck | peak GB/dev | useful FLOPs |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','')} "
                       f"| — | — | — | SKIP ({r['skipped'][:40]}…) | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','')} "
                       f"| — | — | — | ERROR | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_term_s']*1e3:.2f} | {r['memory_term_s']*1e3:.2f} "
            f"| {r['collective_term_s']*1e3:.3f} | **{r['bottleneck']}** "
            f"| {r['peak_bytes_per_device']/1e9:.1f} "
            f"| {100*r['useful_flops_ratio']:.0f}% |")
    return "\n".join(out)


def run(csv=True):
    rows = []
    for tag, fn in [("single", "dryrun_single.jsonl"),
                    ("multi", "dryrun_multi.jsonl")]:
        path = os.path.join(RESULTS, fn)
        if not os.path.exists(path):
            continue
        recs = load(path)
        ok = sum(1 for r in recs if "compute_term_s" in r)
        skip = sum(1 for r in recs if "skipped" in r)
        fail = sum(1 for r in recs if "error" in r)
        rows.append((f"dryrun_{tag}_ok", ok, f"skip={skip},fail={fail}"))
    if csv:
        for r in rows:
            print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--markdown":
        for fn in ("dryrun_single.jsonl", "dryrun_multi.jsonl"):
            p = os.path.join(RESULTS, fn)
            if os.path.exists(p):
                print(f"\n### {fn}\n")
                print(render_markdown(load(p)))
    else:
        run()
