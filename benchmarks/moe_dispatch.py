"""Dropless vs capacity routed-expert dispatch: cost + invariance.

The dropless sort-based grouped dispatch replaced capacity scatter
routing as the serving default (it is dispatch-group invariant, which
the blockwise serving equivalences require). This benchmark tracks
what that buys and costs on CPU XLA:

  * wall-clock per routed-experts call at prefill-block and full-
    sequence shapes, dropless (ragged_dot grouped path) vs capacity
    (scatter + [E, C, D] buffer einsum);
  * the dispatched-row accounting: capacity computes E*C padded rows
    (C = ceil(N*K*cf/E), so ~cf x the active rows, MORE under the
    8-row layout round-up at small dispatch groups), dropless computes
    exactly the N*K routed rows plus tile padding;
  * a dispatch-group invariance probe (full sequence vs per-block
    max-abs routed-output delta) for both modes, on an engineered-
    overflow input (identical rows all routing to the same experts, so
    the one-group capacity drops rows the per-block capacities keep) —
    capacity comes out nonzero, dropless is the number the de-xfailed
    equivalence tests pin to zero.

Emits ``name,value,derived`` CSV rows (harness contract) and writes
the ``moe_dispatch`` section of results/BENCH_prefill.json.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import write_bench_json
from repro.configs import get_config
from repro.models.moe import capacity, moe_ffn_spec, routed_experts
from repro.nn.param import init_params


def _timed(fn, *args, iters=20):
    y = jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(*args)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters


def run(arch: str = "qwen2-moe-a2.7b", seq: int = 512, block: int = 128,
        iters: int = 20, seed: int = 0):
    cfg = get_config(arch, reduced=True)
    mp = init_params(moe_ffn_spec(cfg, cfg.dtype), jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 1), (1, seq, cfg.d_model),
                          cfg.dtype)
    modes = {m: cfg.with_(moe_dispatch=m) for m in ("dropless", "capacity")}
    fns = {m: jax.jit(lambda xx, c=c: routed_experts(mp, c, xx)[0])
           for m, c in modes.items()}
    # engineered-overflow probe input: identical rows all route to the
    # same top-k experts, so the full-sequence capacity drops rows that
    # the per-block capacities keep — random input rarely overflows at
    # cf=1.25 and would report a vacuous 0.0 for capacity mode
    x_ovf = jnp.tile(
        jax.random.normal(jax.random.key(seed + 2), (1, 1, cfg.d_model),
                          cfg.dtype), (1, seq, 1))

    out = {"arch": arch, "seq": seq, "block": block,
           "n_experts": cfg.n_experts, "top_k": cfg.top_k,
           "capacity_factor": cfg.capacity_factor}
    rows = []
    for m, fn in fns.items():
        t_full = _timed(fn, x, iters=iters)
        # fn is jitted: block-shaped calls hit their own cached
        # executable, no extra wrapper needed
        t_blk = sum(
            _timed(fn, x[:, o:o + block], iters=iters)
            for o in range(0, seq, block))
        # invariance probe: full-sequence vs concatenated per-block on
        # the overflow input
        y_full = np.asarray(fn(x_ovf))
        y_blk = np.concatenate(
            [np.asarray(fn(x_ovf[:, o:o + block]))
             for o in range(0, seq, block)], axis=1)
        delta = float(np.abs(y_full - y_blk).max())
        out[m] = {"seconds_full": t_full, "seconds_blockwise": t_blk,
                  "block_vs_full_delta_max": delta}
        rows += [(f"{m}_full_ms", t_full * 1e3, ""),
                 (f"{m}_blockwise_ms", t_blk * 1e3, ""),
                 (f"{m}_block_vs_full_delta", delta, "")]

    # dispatched-row accounting (shape-level, exact)
    K = cfg.top_k
    active = seq * K
    cap_rows = cfg.n_experts * capacity(seq, cfg)
    cap_rows_blk = (seq // block) * cfg.n_experts * capacity(block, cfg)
    out["rows"] = {"active": active, "capacity_full": cap_rows,
                   "capacity_blockwise": cap_rows_blk}
    rows += [("active_rows", active, ""),
             ("capacity_padded_rows_full", cap_rows,
              f"{cap_rows / active:.2f}x active"),
             ("capacity_padded_rows_blockwise", cap_rows_blk,
              f"{cap_rows_blk / active:.2f}x active")]

    assert out["dropless"]["block_vs_full_delta_max"] == 0.0, \
        "dropless dispatch must be dispatch-group invariant"
    assert out["capacity"]["block_vs_full_delta_max"] > 0.0, \
        "overflow probe failed to trigger a capacity drop"
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
    path = write_bench_json("moe_dispatch", out)
    print(f"# wrote {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--iters", type=int, default=20)
    args = ap.parse_args()
    run(arch=args.arch, seq=args.seq, block=args.block, iters=args.iters)
