"""Serving engine: blockwise FastForward prefill + batched decode.

The request path follows the paper's deployment story:
  1. requests are batched and right-padded to a multiple of the
     128-token block size;
  2. the prompt is processed block-by-block with predictive FFN sparsity
     (dense first/last blocks, expert predictor, compensator);
  3. generation proceeds token-by-token, reusing the same predictor /
     compensator (paper Table 3), with ragged per-sequence positions.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.registry import get_model


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, max_new]
    prefill_seconds: float
    decode_seconds: float
    prompt_tokens: int
    generated_tokens: int


class Engine:
    """Single-host serving engine (dense-family models).

    greedy or temperature sampling; prompt batches are right-padded to
    the block size with per-sequence length masking.
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 2048):
        if cfg.arch not in ("dense", "vlm"):
            raise ValueError("Engine drives dense-family models; use the "
                             "model modules directly for other archs")
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.max_len = max_len
        # cfg is a static python dataclass -> close over it, don't trace it
        self._prefill = jax.jit(
            lambda params, batch, cache, lengths: self.model.prefill(
                params, cfg, batch, cache, lengths=lengths,
                collect_hidden=True))
        self._decode = jax.jit(
            lambda params, token, cache, position: self.model.decode_step(
                params, cfg, token, cache, position))
        self._logits_at = jax.jit(self._logits_at_impl)

    def _logits_at_impl(self, hidden, lengths):
        from repro.models.dense import apply_norm
        from repro.nn import layers as L
        idx = jnp.clip(lengths - 1, 0, hidden.shape[1] - 1)
        h = jnp.take_along_axis(
            hidden, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        h = apply_norm(self.cfg, self.params["ln_f"], h)
        return L.unembed(self.params["lm_head"], h)

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0
                 ) -> GenerationResult:
        cfg = self.cfg
        N = cfg.ff.block_size
        B = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        L_pad = int(-(-lens.max() // N) * N)
        toks = np.zeros((B, L_pad), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = np.asarray(p, np.int32)
        cache_len = L_pad + max_new
        cache = self.model.init_cache(cfg, B, cache_len)

        t0 = time.perf_counter()
        cache, _, hidden = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache,
            jnp.asarray(lens))
        logits = self._logits_at(hidden, jnp.asarray(lens))
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        key = jax.random.key(seed)
        out = np.zeros((B, max_new), np.int32)
        positions = jnp.asarray(lens)          # next write position
        for t in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            nxt = nxt.astype(jnp.int32)
            out[:, t] = np.asarray(nxt)
            logits, cache = self._decode(self.params, nxt, cache, positions)
            positions = positions + 1
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        return GenerationResult(
            tokens=out, prefill_seconds=t1 - t0, decode_seconds=t2 - t1,
            prompt_tokens=int(lens.sum()), generated_tokens=B * max_new)
