"""Serving engines: continuous batching (default) + legacy static batch.

`Engine` is the continuous-batching engine built on the ModelRuntime /
KVSlotPool / ContinuousBatchingScheduler stack (see those modules for
the architecture). Its `generate()` keeps the original static-batch
signature as a thin compatibility wrapper: submit every prompt at once,
run the scheduler to drain, reassemble a GenerationResult.

`StaticEngine` is the original single-shot engine — one right-padded
batch, full-batch blockwise prefill, lockstep Python decode loop. It is
kept as the baseline the continuous engine is benchmarked against
(benchmarks/continuous_batching.py) and bit-compared with in tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.registry import get_model
from repro.serving.runtime import make_runtime
from repro.serving.scheduler import ContinuousBatchingScheduler, Request


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, max_new]
    prefill_seconds: float
    decode_seconds: float
    prompt_tokens: int
    generated_tokens: int


class Engine:
    """Continuous-batching serving engine (dense family + MoE).

    generate() is the backward-compatible static-style entry point;
    streaming workloads should drive a ContinuousBatchingScheduler
    directly (see launch/serve.py --stream).
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 2048,
                 n_slots: Optional[int] = None, prefill_batch: int = 4,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None, plans=None):
        if cfg.arch not in ("dense", "vlm", "moe"):
            raise ValueError("Engine drives dense-family and MoE models; "
                             "use the model modules directly for other "
                             "archs")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.n_slots = n_slots
        self.prefill_batch = prefill_batch
        # paged KV layout knobs (cfg.kv_layout == "paged"): page_size
        # defaults to cfg.kv_page_size (then the block size), n_pages
        # to full backing — pass a smaller heap to oversubscribe
        self.page_size = page_size
        self.n_pages = n_pages
        # plans: optional tuple of SparsityPlans (effort tiers) to
        # register on the runtime; plans[0] is the default, requests
        # select others via Request.effort (see scheduler)
        self.runtime = make_runtime(cfg, params, plans=plans)

    def scheduler(self, n_slots: int, cache_len: int, seed: int = 0,
                  admission=None, faults=None, swap_pages: int = 0
                  ) -> ContinuousBatchingScheduler:
        """admission/faults: optional AdmissionController /
        FaultInjector (overload resilience; see serving/admission.py
        and serving/faults.py). swap_pages: host swap tier capacity in
        pages (paged layout; 0 = tiering off — see serving/kv_tier.py)."""
        return ContinuousBatchingScheduler(
            self.runtime, n_slots=n_slots, cache_len=cache_len, seed=seed,
            prefill_batch=self.prefill_batch, page_size=self.page_size,
            n_pages=self.n_pages, admission=admission, faults=faults,
            swap_pages=swap_pages)

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 effort: Optional[str] = None) -> GenerationResult:
        """effort: optional SparsityPlan name (registered via plans=)
        applied to every prompt of this call."""
        N = self.runtime.block_size
        B = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int64)
        if max_new < 1:      # legacy API tolerated max_new=0: no work
            return GenerationResult(
                tokens=np.zeros((B, 0), np.int32), prefill_seconds=0.0,
                decode_seconds=0.0, prompt_tokens=int(lens.sum()),
                generated_tokens=0)
        cache_len = int(-(-lens.max() // N) * N) + max_new
        n_slots = self.n_slots or B
        sched = self.scheduler(n_slots, cache_len, seed=seed)

        t0 = time.perf_counter()
        for rid, p in enumerate(prompts):
            sched.submit(Request(rid=rid, prompt=list(p), max_new=max_new,
                                 temperature=temperature, arrival_time=t0,
                                 effort=effort))
        outs = sched.run()
        t2 = time.perf_counter()

        out = np.zeros((B, max_new), np.int32)
        for rid in range(B):
            toks = outs[rid].tokens
            out[rid, :len(toks)] = toks
        last_ttft = max(o.ttft_seconds for o in outs.values())
        return GenerationResult(
            tokens=out, prefill_seconds=last_ttft,
            decode_seconds=(t2 - t0) - last_ttft,
            prompt_tokens=int(lens.sum()),
            generated_tokens=int(sum(len(o.tokens) for o in outs.values())))


class StaticEngine:
    """Legacy single-shot engine (dense family + MoE): one right-padded
    batch through full-batch blockwise prefill, then a lockstep decode
    loop. No mid-flight admission — kept as the continuous-batching
    baseline. MoE models run dropless routed dispatch, so the padded
    static batch routes each token identically to the continuous
    engine's per-request blocks (the bit-equivalence tests rely on
    this)."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 2048):
        if cfg.arch not in ("dense", "vlm", "moe"):
            raise ValueError("StaticEngine drives dense-family and MoE "
                             "models")
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.max_len = max_len
        self.runtime = make_runtime(cfg, params)
        # cfg is a static python dataclass -> close over it, don't trace it
        self._prefill = jax.jit(
            lambda params, batch, cache, lengths: self.model.prefill(
                params, cfg, batch, cache, lengths=lengths,
                collect_hidden=True))
        self._decode = jax.jit(
            lambda params, token, cache, position: self.model.decode_step(
                params, cfg, token, cache, position,
                window=cfg.sliding_window))

    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 pad_to: Optional[int] = None,
                 cache_len: Optional[int] = None) -> GenerationResult:
        """pad_to / cache_len pin the padded prompt length and KV length
        so repeated calls with varying batches hit one jit executable
        (benchmarks: compile-stable static baseline)."""
        cfg = self.cfg
        N = cfg.ff.block_size
        B = len(prompts)
        lens = np.array([len(p) for p in prompts], np.int32)
        L_pad = pad_to or int(-(-lens.max() // N) * N)
        if L_pad % N or L_pad < lens.max():
            raise ValueError(f"pad_to={L_pad} must be a block multiple "
                             f">= the longest prompt ({lens.max()})")
        toks = np.zeros((B, L_pad), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = np.asarray(p, np.int32)
        if cache_len is not None and cache_len < L_pad + max_new:
            raise ValueError(f"cache_len={cache_len} cannot hold "
                             f"{L_pad} prompt + {max_new} new tokens")
        cache_len = cache_len or (L_pad + max_new)
        cache = self.model.init_cache(cfg, B, cache_len)

        t0 = time.perf_counter()
        cache, _, hidden = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, cache,
            jnp.asarray(lens))
        logits = self.runtime.logits_at(hidden, lens)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        key = jax.random.key(seed)
        out = np.zeros((B, max_new), np.int32)
        positions = jnp.asarray(lens)          # next write position
        for t in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, -1)
            else:
                nxt = jnp.argmax(logits, -1)
            nxt = nxt.astype(jnp.int32)
            out[:, t] = np.asarray(nxt)
            logits, cache = self._decode(self.params, nxt, cache, positions)
            positions = positions + 1
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        return GenerationResult(
            tokens=out, prefill_seconds=t1 - t0, decode_seconds=t2 - t1,
            prompt_tokens=int(lens.sum()), generated_tokens=B * max_new)
