"""Paged KV cache pool: block-granular allocation over a shared page
heap (the serving memory-side counterpart of the compute-side batched
prefill — vLLM-style PagedAttention adapted to the fixed-shape jitted
runtime).

One fixed device allocation ([n_layers, n_pages, page_size, n_kv_heads,
head_dim] per K/V) backs every request: instead of reserving a
max-cache_len slot up front (KVSlotPool — a short request strands the
same memory as a 16K-token one), a request holds a PAGE TABLE — a row
of the host-side [n_slots, max_pages] int32 array — and claims pages
from the free heap lazily, one prefill block / decode token at a time.
On completion (or EOS early-stop, or preemption) its pages return to
the heap individually, so the device bytes a request pins track its
LIVE length, not its worst case.

Invariants the jitted runtime relies on:

  * page 0 is the reserved NULL page: never allocated, every
    unallocated table entry points at it, masked writes self-copy into
    it, and no attention mask ever reaches it — it is a shared write
    sink, not data;
  * a page is owned by at most one slot, so page-table-directed
    scatters from distinct live rows are write-disjoint;
  * buffer shapes ([n_pages, psz, Kv, dh] pools, [*, max_pages] tables)
    are fixed — tables/positions are traced values, so a churning
    request mix (and preemption churn) reuses one executable per entry
    point: the zero-recompilation invariant survives the paged layout.

Host-side metadata (page heap, tables, lengths, stats) lives in plain
Python/numpy; only the KV pytree is on device. `release` is idempotent
per slot (same hardening as KVSlotPool): scheduler paths that free a
request mid-tick (EOS early-stop, preemption) cannot double-count
stats or double-free pages.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np


class PagedKVPool:
    """Fixed page heap + per-slot page tables for a churning request set."""

    layout = "paged"

    def __init__(self, cache, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one usable page beyond the "
                             "reserved null page 0")
        self.cache = cache            # device pytree, page axis = 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.cache_len = max_pages * page_size
        self._free_slots = deque(range(n_slots))
        self._free_pages = deque(range(1, n_pages))   # 0 = null page
        self._held = np.zeros(n_slots, bool)
        # table entry j of slot s: page holding s's positions
        # [j*psz, (j+1)*psz); 0 (null) where unallocated
        self.page_table = np.zeros((n_slots, max_pages), np.int32)
        self.allocated = np.zeros(n_slots, np.int64)  # pages per slot
        self.lengths = np.zeros(n_slots, np.int64)    # live tokens per slot
        # stats (tests + benchmarks/continuous_batching.py kv_memory)
        self.total_acquires = 0
        self.total_releases = 0
        self.max_in_use = 0
        self.total_page_allocs = 0
        self.total_page_frees = 0
        self.max_pages_in_use = 0
        self.stranded_tokens_at_peak = 0

    @classmethod
    def create(cls, runtime, n_pages: int, page_size: int, n_slots: int,
               max_pages: int) -> "PagedKVPool":
        return cls(runtime.init_cache_paged(n_pages, page_size), n_pages,
                   page_size, n_slots, max_pages)

    # ------------------------------------------------------------ slots

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_in_use(self) -> int:
        return self.n_slots - len(self._free_slots)

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def n_pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free_pages)

    def acquire(self) -> Optional[int]:
        """Claim a free slot (its page table starts empty — admission
        gating on free PAGES is the scheduler's policy, not the
        pool's), or None when no slot is free."""
        if not self._free_slots:
            return None
        slot = self._free_slots.popleft()
        self._held[slot] = True
        self.lengths[slot] = 0
        self.total_acquires += 1
        self.max_in_use = max(self.max_in_use, self.n_in_use)
        return slot

    def release(self, slot: int) -> None:
        """Return a slot AND all its pages. Idempotent per request: a
        second release of an already-free slot is a no-op (EOS
        early-stop and preemption can both try to free mid-tick)."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        if not self._held[slot]:
            return
        self._held[slot] = False
        n = int(self.allocated[slot])
        for j in range(n):
            self._free_pages.append(int(self.page_table[slot, j]))
        self.total_page_frees += n
        self.page_table[slot, :] = 0
        self.allocated[slot] = 0
        self.lengths[slot] = 0
        self._free_slots.append(slot)
        self.total_releases += 1

    # ------------------------------------------------------------ pages

    def ensure(self, slot: int, n_total: int) -> bool:
        """Grow slot's table to cover n_total pages (lazy per-block /
        per-token allocation). Returns False — allocating NOTHING — when
        the heap cannot cover the growth (the scheduler then preempts or
        skips); True when the slot already covers n_total or after
        allocating the delta."""
        if not self._held[slot]:
            raise ValueError(f"slot {slot} is not held")
        if n_total > self.max_pages:
            raise ValueError(f"slot {slot}: {n_total} pages exceeds the "
                             f"table width {self.max_pages}")
        delta = n_total - int(self.allocated[slot])
        if delta <= 0:
            return True
        if len(self._free_pages) < delta:
            return False
        base = int(self.allocated[slot])
        for j in range(delta):
            self.page_table[slot, base + j] = self._free_pages.popleft()
        self.allocated[slot] = n_total
        self.total_page_allocs += delta
        self.max_pages_in_use = max(self.max_pages_in_use,
                                    self.n_pages_in_use)
        return True

    def covers(self, slot: int, position: int) -> bool:
        """Whether slot's table already maps token `position`."""
        return position < int(self.allocated[slot]) * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def fits(self, n_tokens: int) -> bool:
        """Whether a request needing n_tokens cache positions can ever
        be served: its table must hold them and the heap must be able
        to back them all at once (the oldest request can preempt every
        younger one, so heap capacity == worst-case guarantee)."""
        return (n_tokens <= self.cache_len
                and self.pages_for(n_tokens) <= self.n_pages - 1)

    # ----------------------------------------- fault-injection pressure

    def steal_free_pages(self, n: int) -> list:
        """Fault-injection hook (serving/faults.py): temporarily remove
        up to n FREE pages from the heap — admission gating and
        `ensure` growth see a dry heap and must skip/preempt/retry.
        Stolen pages belong to no slot (never page 0) and must come
        back via `restore_free_pages`; the injector guarantees it, so
        leak accounting stays exact."""
        taken = []
        for _ in range(min(n, len(self._free_pages))):
            taken.append(self._free_pages.popleft())
        return taken

    def restore_free_pages(self, pages: list) -> None:
        self._free_pages.extend(pages)

    # ------------------------------------------------------------ stats

    def stranded_tokens(self) -> int:
        """Allocated-but-dead token positions across held slots (the
        fragmentation the paged layout exists to shrink: a slot pool
        strands cache_len - length per request, a page pool at most
        page_size - 1 plus the lazily-unallocated tail of the current
        page)."""
        held = self._held
        return int((self.allocated[held] * self.page_size
                    - self.lengths[held]).sum())

    def note_tick(self) -> None:
        """Scheduler hook, called once per tick: refresh occupancy peaks
        and record the stranded bytes at the page-occupancy peak (the
        apples-to-apples fragmentation number the kv_memory benchmark
        compares across layouts)."""
        self.max_in_use = max(self.max_in_use, self.n_in_use)
        if self.n_pages_in_use >= self.max_pages_in_use:
            self.max_pages_in_use = self.n_pages_in_use
            self.stranded_tokens_at_peak = self.stranded_tokens()
