"""Paged KV cache pool: block-granular allocation over a shared page
heap (the serving memory-side counterpart of the compute-side batched
prefill — vLLM-style PagedAttention adapted to the fixed-shape jitted
runtime).

One fixed device allocation ([n_layers, n_pages, page_size, n_kv_heads,
head_dim] per K/V) backs every request: instead of reserving a
max-cache_len slot up front (KVSlotPool — a short request strands the
same memory as a 16K-token one), a request holds a PAGE TABLE — a row
of the host-side [n_slots, max_pages] int32 array — and claims pages
from the free heap lazily, one prefill block / decode token at a time.

Ownership is REFCOUNTED (prefix sharing, vLLM-style prefix cache): a
page may appear in several slots' tables at once when their prompts
share a prefix (serving/prefix_index.py maps token chains to pages).
`release` decrements instead of freeing; a page physically returns to
the heap only at refcount zero. A refcount-zero page that is still
CACHED (published in the prefix index) parks on a reclaimable LRU list
instead — it costs nothing until the heap runs dry, at which point the
scheduler evicts it (index subtree drop -> `uncache` -> free list).

Invariants the jitted runtime relies on:

  * page 0 is the reserved NULL page: never allocated, every
    unallocated table entry points at it, masked writes self-copy into
    it, and no attention mask ever reaches it — it is a shared write
    sink, not data;
  * a page with refcount > 1 (or refcount 1 + cached) is READ-ONLY:
    writers only ever target exclusively-owned uncached pages (fresh
    `ensure` growth or `cow` copies) or published pages of their OWN
    completed blocks they never rewrite, so page-table-directed
    scatters from distinct live rows remain write-disjoint — the old
    "one owner per page" disjointness argument survives sharing
    because shared pages are read-only until copy-on-write detaches
    them;
  * buffer shapes ([n_pages, psz, Kv, dh] pools, [*, max_pages] tables)
    are fixed — tables/positions are traced values, so a churning
    request mix (and preemption/sharing churn) reuses one executable
    per entry point: the zero-recompilation invariant survives both
    the paged layout and prefix sharing.

Host-side metadata (page heap, refcounts, tables, lengths, stats)
lives in plain Python/numpy; only the KV pytree is on device.
`release` is idempotent per slot (same hardening as KVSlotPool):
scheduler paths that free a request mid-tick (EOS early-stop,
preemption) cannot double-count stats or double-free pages.

Accounting: `total_page_allocs` counts pops off the free list into a
table (lazy `ensure` growth + `cow` copies + swap-in reallocation);
`total_page_frees` counts physical returns TO the free list
(last-reference release of an uncached page, `uncache` of an idle
cached page, or swap-out of an exclusive page whose payload moved to
the host tier). Shared mappings (`share`) touch neither — so
allocs == frees once every request has drained AND the prefix index
has been cleared, which is exactly the leak check the churn tests
assert — and it holds ACROSS tiers: a swapped-out page is one free
(device) now and one alloc (fresh device page) at swap-in, while the
host tier keeps its own put/free parity (serving/kv_tier.py).

Memory tiering (serving/kv_tier.py): a held slot may be partially
SWAPPED — its exclusively-owned uncached pages' payloads live in the
host tier and the corresponding table entries are zeroed (the parked
request keeps its slot and its shared/cached mappings; only the
scheduler moves bytes, through the fixed-width jitted runtime
entries). Swap NEVER touches shared (refcount > 1) or cached pages:
those stay resident and mapped, because other readers' tables (or the
prefix index) still point at the physical page id — swapping would
either tear their reads or silently relocate a published page.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import List, Optional, Tuple

import numpy as np


class PagedKVPool:
    """Fixed page heap + per-slot page tables for a churning request set."""

    layout = "paged"

    def __init__(self, cache, n_pages: int, page_size: int, n_slots: int,
                 max_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one usable page beyond the "
                             "reserved null page 0")
        self.cache = cache            # device pytree, page axis = 1
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.max_pages = max_pages
        self.cache_len = max_pages * page_size
        self._free_slots = deque(range(n_slots))
        self._free_pages = deque(range(1, n_pages))   # 0 = null page
        self._held = np.zeros(n_slots, bool)
        # per-page sharing state: how many slot tables map the page,
        # and whether the prefix index still holds it (cached pages at
        # refcount 0 are reclaimable, not free)
        self.refcount = np.zeros(n_pages, np.int32)
        self.cached = np.zeros(n_pages, bool)
        # refcount-0 AND cached, LRU-ordered (front = evict first)
        self._reclaimable: "OrderedDict[int, None]" = OrderedDict()
        # table entry j of slot s: page holding s's positions
        # [j*psz, (j+1)*psz); 0 (null) where unallocated
        self.page_table = np.zeros((n_slots, max_pages), np.int32)
        self.allocated = np.zeros(n_slots, np.int64)  # pages per slot
        self.lengths = np.zeros(n_slots, np.int64)    # live tokens per slot
        # stats (tests + benchmarks/continuous_batching.py kv_memory)
        self.total_acquires = 0
        self.total_releases = 0
        self.max_in_use = 0
        self.total_page_allocs = 0
        self.total_page_frees = 0
        self.max_pages_in_use = 0
        self.stranded_tokens_at_peak = 0
        # prefix-sharing stats
        self.total_page_shares = 0    # shared mappings handed out
        self.n_cow_pages = 0          # copy-on-write detaches
        # host swap tier (serving/kv_tier.py); None = tiering disabled.
        # _swap_state: slot -> {"hid": host handle, "js": zeroed table
        # indices} for slots whose exclusive pages are swapped out
        self.host_tier = None
        self._swap_state: dict = {}
        self.total_pages_swapped_out = 0
        self.total_pages_swapped_in = 0

    @classmethod
    def create(cls, runtime, n_pages: int, page_size: int, n_slots: int,
               max_pages: int) -> "PagedKVPool":
        return cls(runtime.init_cache_paged(n_pages, page_size), n_pages,
                   page_size, n_slots, max_pages)

    # ------------------------------------------------------------ slots

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_in_use(self) -> int:
        return self.n_slots - len(self._free_slots)

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def n_reclaimable(self) -> int:
        """Cached-but-unreferenced pages (evictable on demand)."""
        return len(self._reclaimable)

    @property
    def n_available_pages(self) -> int:
        """Pages admission may count on: truly free + reclaimable
        (cached idle pages surrender to eviction, so they are capacity,
        not occupancy)."""
        return len(self._free_pages) + len(self._reclaimable)

    @property
    def n_pages_in_use(self) -> int:
        """Pages pinned by live requests (cached idle pages are NOT in
        use — they are reclaimable capacity)."""
        return (self.n_pages - 1) - self.n_available_pages

    def acquire(self) -> Optional[int]:
        """Claim a free slot (its page table starts empty — admission
        gating on free PAGES is the scheduler's policy, not the
        pool's), or None when no slot is free."""
        if not self._free_slots:
            return None
        slot = self._free_slots.popleft()
        self._held[slot] = True
        self.lengths[slot] = 0
        self.total_acquires += 1
        self.max_in_use = max(self.max_in_use, self.n_in_use)
        return slot

    def release(self, slot: int) -> None:
        """Return a slot and DECREF all its pages (deepest first, so a
        released chain's tail becomes the LRU eviction victim before
        its root — evicting a mid-chain page drops the subtree below
        it, never the shared trunk). Idempotent per request: a second
        release of an already-free slot is a no-op (EOS early-stop and
        preemption can both try to free mid-tick)."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        if not self._held[slot]:
            return
        self._held[slot] = False
        n = int(self.allocated[slot])
        swapped = self._swap_state.pop(slot, None)
        skip = set(swapped["js"]) if swapped else ()
        for j in range(n - 1, -1, -1):
            if j in skip:
                continue      # swapped entry: zeroed, payload on host
            self._decref(int(self.page_table[slot, j]))
        if swapped is not None:
            # the parked owner is gone (cancel / deadline expiry):
            # release its host-tier pages too, keeping cross-tier
            # put/free parity exact
            self.host_tier.free(swapped["hid"])
        self.page_table[slot, :] = 0
        self.allocated[slot] = 0
        self.lengths[slot] = 0
        self._free_slots.append(slot)
        self.total_releases += 1

    # ------------------------------------------------------- refcounting

    def _incref(self, page: int) -> None:
        if self.refcount[page] == 0:
            # must be parked on the reclaimable list (a cached idle
            # page being re-shared); truly-free pages enter tables via
            # ensure/cow, not incref
            self._reclaimable.pop(page)
        self.refcount[page] += 1

    def _decref(self, page: int) -> None:
        self.refcount[page] -= 1
        assert self.refcount[page] >= 0, f"page {page} refcount underflow"
        if self.refcount[page] == 0:
            if self.cached[page]:
                # most-recently-released end of the LRU
                self._reclaimable[page] = None
            else:
                self._free_pages.append(page)
                self.total_page_frees += 1

    # ------------------------------------------------------------ pages

    def ensure(self, slot: int, n_total: int) -> bool:
        """Grow slot's table to cover n_total pages (lazy per-block /
        per-token allocation) with FRESH exclusively-owned pages.
        Returns False — allocating NOTHING — when the free heap cannot
        cover the growth (the scheduler then evicts cached prefixes,
        preempts, or skips); True when the slot already covers n_total
        or after allocating the delta."""
        if not self._held[slot]:
            raise ValueError(f"slot {slot} is not held")
        if n_total > self.max_pages:
            raise ValueError(f"slot {slot}: {n_total} pages exceeds the "
                             f"table width {self.max_pages}")
        delta = n_total - int(self.allocated[slot])
        if delta <= 0:
            return True
        if len(self._free_pages) < delta:
            return False
        base = int(self.allocated[slot])
        for j in range(delta):
            page = self._free_pages.popleft()
            self.page_table[slot, base + j] = page
            self.refcount[page] = 1
        self.allocated[slot] = n_total
        self.total_page_allocs += delta
        self.max_pages_in_use = max(self.max_pages_in_use,
                                    self.n_pages_in_use)
        return True

    def share(self, slot: int, pages: List[int]) -> None:
        """Map already-populated CACHED pages into slot's table (prefix
        hit at admission): appends at the table tail and increfs each —
        idle pages leave the reclaimable list, active ones just gain a
        reader. The mapped pages are read-only for this slot (its
        prefill starts after them; a partial tail is `cow`-detached by
        the scheduler before any write)."""
        if not self._held[slot]:
            raise ValueError(f"slot {slot} is not held")
        base = int(self.allocated[slot])
        if base + len(pages) > self.max_pages:
            raise ValueError(f"slot {slot}: sharing {len(pages)} pages "
                             f"overflows the table width {self.max_pages}")
        for j, page in enumerate(pages):
            assert self.cached[page], f"sharing uncached page {page}"
            self.page_table[slot, base + j] = page
            self._incref(int(page))
        self.allocated[slot] = base + len(pages)
        self.total_page_shares += len(pages)
        self.max_pages_in_use = max(self.max_pages_in_use,
                                    self.n_pages_in_use)

    def cow(self, slot: int, j: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write detach of table entry j: swap the shared page
        for a fresh exclusively-owned one and return (src, dst) for the
        device-side payload copy (runtime.copy_pages). Returns None —
        changing nothing — when the free heap is dry (caller evicts or
        falls back to unmapping the tail and re-prefilling it)."""
        if not self._held[slot]:
            raise ValueError(f"slot {slot} is not held")
        if not self._free_pages:
            return None
        src = int(self.page_table[slot, j])
        dst = self._free_pages.popleft()
        self.page_table[slot, j] = dst
        self.refcount[dst] = 1
        self.total_page_allocs += 1
        self.n_cow_pages += 1
        self._decref(src)
        self.max_pages_in_use = max(self.max_pages_in_use,
                                    self.n_pages_in_use)
        return src, dst

    def unmap_tail(self, slot: int, n: int) -> None:
        """Drop the last n table entries (decref, zero, shrink) — the
        dry-heap fallback when a partial-block tail cannot be COWed:
        the scheduler re-prefills those positions instead."""
        if not self._held[slot]:
            raise ValueError(f"slot {slot} is not held")
        base = int(self.allocated[slot])
        for j in range(base - 1, base - 1 - n, -1):
            self._decref(int(self.page_table[slot, j]))
            self.page_table[slot, j] = 0
        self.allocated[slot] = base - n

    # --------------------------------------------------- prefix caching

    def mark_cached(self, page: int) -> None:
        """Prefix-index hook: the page is now published (its payload is
        reachable by future lookups), so at refcount zero it parks on
        the reclaimable list instead of the free list."""
        assert self.refcount[page] > 0, \
            f"publishing idle page {page} (must be held by its writer)"
        self.cached[page] = True

    def uncache(self, page: int) -> None:
        """Prefix-index hook: the page left the index (eviction or
        clear). If idle it physically frees right now."""
        if not self.cached[page]:
            return
        self.cached[page] = False
        if self.refcount[page] == 0:
            self._reclaimable.pop(page)
            self._free_pages.append(page)
            self.total_page_frees += 1

    def lru_reclaimable(self) -> Optional[int]:
        """Least-recently-released cached idle page (the scheduler's
        eviction victim), or None when nothing is reclaimable."""
        if not self._reclaimable:
            return None
        return next(iter(self._reclaimable))

    def covers(self, slot: int, position: int) -> bool:
        """Whether slot's table already maps token `position`."""
        return position < int(self.allocated[slot]) * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def fits(self, n_tokens: int) -> bool:
        """Whether a request needing n_tokens cache positions can ever
        be served: its table must hold them and the heap must be able
        to back them all at once (the oldest request can preempt every
        younger one and evict every cached prefix, so heap capacity ==
        worst-case guarantee)."""
        return (n_tokens <= self.cache_len
                and self.pages_for(n_tokens) <= self.n_pages - 1)

    # ------------------------------------------------- host swap tier

    def attach_host_tier(self, tier) -> None:
        """Enable memory tiering: swap-out moves exclusive page
        payloads into `tier` (serving/kv_tier.HostKVTier) instead of
        preempt-and-recompute."""
        self.host_tier = tier

    @property
    def n_swapped_pages(self) -> int:
        return sum(len(s["js"]) for s in self._swap_state.values())

    def is_swapped(self, slot: int) -> bool:
        return slot in self._swap_state

    def swappable_pages(self, slot: int) -> List[Tuple[int, int]]:
        """(table index, page) pairs of `slot` eligible for swap-out:
        exclusively owned (refcount 1) and NOT cached. Shared and
        published pages are swap-exempt — they stay resident and
        mapped (other tables / the prefix index hold their physical
        ids). Empty for already-swapped slots."""
        if not self._held[slot]:
            raise ValueError(f"slot {slot} is not held")
        if slot in self._swap_state:
            return []
        out = []
        for j in range(int(self.allocated[slot])):
            page = int(self.page_table[slot, j])
            if self.refcount[page] == 1 and not self.cached[page]:
                out.append((j, page))
        return out

    def swap_out_commit(self, slot: int, js: List[int], hid: int) -> None:
        """Finish a swap-out AFTER the device->host copy landed: free
        the device pages (they join the free list — counted in
        total_page_frees, the cross-tier parity contract), zero the
        table entries, and remember the host handle. The caller
        (scheduler) must have copied exactly these pages' payloads into
        the tier under `hid`, in `js` order."""
        if not self._held[slot]:
            raise ValueError(f"slot {slot} is not held")
        if slot in self._swap_state:
            raise ValueError(f"slot {slot} is already swapped")
        for j in js:
            page = int(self.page_table[slot, j])
            assert self.refcount[page] == 1 and not self.cached[page], \
                f"swapping non-exclusive page {page}"
            self.refcount[page] = 0
            self._free_pages.append(page)
            self.total_page_frees += 1
            self.page_table[slot, j] = 0
        self._swap_state[slot] = {"hid": hid, "js": list(js)}
        self.total_pages_swapped_out += len(js)

    def swap_in_alloc(self, slot: int) -> Optional[Tuple[int, List[int],
                                                         List[int]]]:
        """Re-back a parked slot's swapped entries with FRESH device
        pages: returns (host handle, table indices, new page ids) for
        the scheduler's host->device copy, or None — allocating
        nothing — when the free heap cannot cover them. The physical
        ids differ from the swapped-out ones; the table-directed gather
        makes that invisible. Call `swap_in_commit` once the payload
        write landed."""
        state = self._swap_state.get(slot)
        if state is None:
            raise ValueError(f"slot {slot} is not swapped")
        js = state["js"]
        if len(self._free_pages) < len(js):
            return None
        pages = []
        for j in js:
            page = self._free_pages.popleft()
            self.page_table[slot, j] = page
            self.refcount[page] = 1
            pages.append(page)
        self.total_page_allocs += len(js)
        self.max_pages_in_use = max(self.max_pages_in_use,
                                    self.n_pages_in_use)
        return state["hid"], list(js), pages

    def swap_in_commit(self, slot: int) -> None:
        """Finish a swap-in AFTER the host->device copy landed: release
        the host-tier pages and forget the swap state."""
        state = self._swap_state.pop(slot)
        self.host_tier.free(state["hid"])
        self.total_pages_swapped_in += len(state["js"])

    # ----------------------------------------- fault-injection pressure

    def steal_free_pages(self, n: int) -> list:
        """Fault-injection hook (serving/faults.py): temporarily remove
        up to n FREE pages from the heap — admission gating and
        `ensure` growth see a dry heap and must skip/preempt/retry.
        The free list only ever holds refcount-zero uncached pages, so
        the injector can never steal a page some request still reads
        (the refcounted-ownership constraint); cached idle pages are
        immune until the scheduler actually evicts them. Stolen pages
        belong to no slot (never page 0) and must come back via
        `restore_free_pages`; the injector guarantees it, so leak
        accounting stays exact."""
        taken = []
        for _ in range(min(n, len(self._free_pages))):
            taken.append(self._free_pages.popleft())
        return taken

    def restore_free_pages(self, pages: list) -> None:
        self._free_pages.extend(pages)

    # ------------------------------------------------------------ stats

    def stranded_tokens(self) -> int:
        """Allocated-but-dead token positions across held slots (the
        fragmentation the paged layout exists to shrink: a slot pool
        strands cache_len - length per request, a page pool at most
        page_size - 1 plus the lazily-unallocated tail of the current
        page). Shared pages count once per holder — each table really
        does map those positions."""
        held = self._held
        return int((self.allocated[held] * self.page_size
                    - self.lengths[held]).sum())

    def note_tick(self) -> None:
        """Scheduler hook, called once per tick: refresh occupancy peaks
        and record the stranded bytes at the page-occupancy peak (the
        apples-to-apples fragmentation number the kv_memory benchmark
        compares across layouts)."""
        self.max_in_use = max(self.max_in_use, self.n_in_use)
        if self.n_pages_in_use >= self.max_pages_in_use:
            self.max_pages_in_use = self.n_pages_in_use
            self.stranded_tokens_at_peak = self.stranded_tokens()

    def check_consistency(self) -> None:
        """Test hook: recompute refcounts from the held tables and
        verify the free / reclaimable / referenced partition (swapped
        table entries are zeroed holes — they map no device page, so
        they are skipped, and their payloads must still be on the host
        tier). Raises AssertionError on any drift."""
        want = np.zeros(self.n_pages, np.int32)
        for slot in range(self.n_slots):
            if not self._held[slot]:
                assert int(self.allocated[slot]) == 0, \
                    f"released slot {slot} still maps pages"
                assert (self.page_table[slot] == 0).all()
                assert slot not in self._swap_state, \
                    f"released slot {slot} still has swap state"
                continue
            swapped = self._swap_state.get(slot)
            skip = set(swapped["js"]) if swapped else ()
            if swapped is not None:
                assert self.host_tier is not None
                assert (self.host_tier.pages_of(swapped["hid"])
                        == len(swapped["js"]))
            for j in range(int(self.allocated[slot])):
                if j in skip:
                    assert int(self.page_table[slot, j]) == 0, \
                        f"swapped entry ({slot}, {j}) still maps a page"
                    continue
                want[int(self.page_table[slot, j])] += 1
        if self.host_tier is not None:
            self.host_tier.check_consistency()
        assert (want == self.refcount).all(), \
            "refcounts drifted from table occupancy"
        free = set(self._free_pages)
        recl = set(self._reclaimable)
        assert not free & recl, "page on both free and reclaimable lists"
        for page in range(1, self.n_pages):
            if self.refcount[page] > 0:
                assert page not in free and page not in recl
            elif self.cached[page]:
                assert page in recl, f"idle cached page {page} not parked"
            # refcount-0 uncached pages are free OR temporarily stolen
            # by the fault injector — both are off the tables
        for page in free:
            assert not self.cached[page], f"free page {page} still cached"
