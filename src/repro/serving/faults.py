"""Deterministic fault injection for the serving scheduler.

The robustness contract of the serving stack ("no slot/page leaks,
the oldest request always progresses, surviving outputs bit-identical
to a fault-free run, compile counts flat") is only worth as much as the
adversity it survives. `FaultInjector` manufactures that adversity
DETERMINISTICALLY: every fault is drawn from one seeded
`np.random.default_rng` stream advanced once per scheduler tick, so a
fault schedule is a pure function of (seed, tick sequence) — a failing
chaos run replays exactly, in CI or locally.

Fault classes (each with an independent per-tick probability):

  forced preemption   evict the youngest active request (never the
                      oldest — the injector honours the same
                      strictly-younger rule as page-pressure
                      preemption, so liveness is preserved by
                      construction). Works on BOTH KV layouts: the
                      victim releases its slot/pages and re-prefills
                      from scratch; greedy output is unchanged.
  synthetic pressure  temporarily steal a fraction of the FREE pages
                      (paged) or FREE slots (slot layout) from the
                      pool for `pressure_hold_ticks` ticks — admission
                      and page growth see a dry heap and must cope
                      (skip, preempt, retry) without leaking. When the
                      scheduler runs a host swap tier, the same event
                      steals a fraction of ITS free capacity too (kind
                      "host_pages") — so chaos exercises the
                      swap-path's preemption fallback, not just the
                      device heap. Stolen resources are always
                      returned by a later tick or by `finalize()`, so
                      leak accounting stays exact across both tiers.
  slow ticks          advance an injected clock offset (the scheduler's
                      clock is wrapped via `wrap_clock`), simulating a
                      stalled host — this is what fires deadline
                      timeouts under test without real waiting.
  random aborts       cancel a random queued-or-active request
                      (`status="cancelled"`), as a disconnecting
                      client would. Capped by `max_aborts` so chaos
                      runs keep survivors to bit-compare.

Wiring: pass `faults=FaultInjector(seed)` to the scheduler
constructor; the scheduler calls `on_tick(self)` at the top of every
tick (warmup suspends injection) and `finalize(self)` when a run
drains. The injector never touches device state — it only drives the
scheduler's own public fault surfaces (preempt, cancel, pool
steal/restore hooks, clock), so anything a chaos run breaks is a real
scheduler bug, not an injector artifact.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


class FaultInjector:
    """Seed-driven chaos: forced preemptions, synthetic pool pressure,
    slow ticks, and random request aborts, one draw batch per tick."""

    def __init__(self, seed: int = 0, p_preempt: float = 0.05,
                 p_pressure: float = 0.05, p_slow: float = 0.05,
                 p_abort: float = 0.0, pressure_frac: float = 0.5,
                 pressure_hold_ticks: int = 4, slow_tick_s: float = 0.01,
                 max_aborts: Optional[int] = None):
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.p_preempt = p_preempt
        self.p_pressure = p_pressure
        self.p_slow = p_slow
        self.p_abort = p_abort
        self.pressure_frac = pressure_frac
        self.pressure_hold_ticks = pressure_hold_ticks
        self.slow_tick_s = slow_tick_s
        self.max_aborts = max_aborts
        self._offset = 0.0
        # (return_at_tick, kind, items): kind is "pages" or "slots"
        # (items = the stolen ids) or "host_pages" (items = a COUNT —
        # host-tier capacity is fungible, there are no page ids)
        self._stolen: List[Tuple[int, str, object]] = []
        self._tick = 0
        self.enabled = True
        # stats (chaos tests assert faults actually fired)
        self.n_forced_preempts = 0
        self.n_pressure_events = 0
        self.n_slow_ticks = 0
        self.n_aborts = 0
        self.aborted_rids: List[int] = []

    # ------------------------------------------------------------ clock

    def wrap_clock(self, clock):
        """Wrap the scheduler's clock so injected slow ticks advance
        observed time (firing deadline/timeout paths) without real
        waiting."""
        return lambda: clock() + self._offset

    # ------------------------------------------------------------- tick

    def on_tick(self, sched) -> None:
        """Scheduler hook, called at the top of every tick. Draws one
        fault batch from the seeded stream (always the same number of
        draws per tick, so the schedule is independent of scheduler
        state) and applies whichever faults fire."""
        self._tick += 1
        draws = self.rng.random(4)
        pick = self.rng.integers(0, 1 << 30)   # victim selector draw
        self._restore_due(sched.pool)
        if not self.enabled:
            return
        if draws[0] < self.p_slow:
            self._offset += self.slow_tick_s
            self.n_slow_ticks += 1
        if draws[1] < self.p_preempt:
            self._force_preempt(sched)
        if draws[2] < self.p_pressure:
            self._apply_pressure(sched)
        if draws[3] < self.p_abort and (
                self.max_aborts is None or self.n_aborts < self.max_aborts):
            self._abort_random(sched, int(pick))

    def finalize(self, sched) -> None:
        """Return every still-stolen resource (a drained run must leave
        the pools whole for leak accounting)."""
        for _, kind, items in self._stolen:
            self._restore(sched.pool, kind, items)
        self._stolen.clear()

    # ------------------------------------------------------ fault impls

    def _force_preempt(self, sched) -> None:
        """Evict the youngest active request actually holding work —
        never the oldest, and never when it is alone (the liveness
        invariant is the injector's to respect, not to test)."""
        states = sorted(sched.active.values(), key=lambda s: s.seq)
        if len(states) < 2:
            return
        victim = states[-1]
        sched._preempt(victim)
        self.n_forced_preempts += 1

    def _apply_pressure(self, sched) -> None:
        pool = sched.pool
        fired = False
        if sched.paged:
            # steals only off the FREE list, which under refcounted
            # ownership holds exactly the refcount-zero uncached pages
            # — the injector can never steal a page a request still
            # reads or a cached prefix the index still serves (those
            # must be evicted by the scheduler first)
            n = int(pool.n_free_pages * self.pressure_frac)
            items = pool.steal_free_pages(n)
            kind = "pages"
            # the same event squeezes the host swap tier (no extra RNG
            # draws — the count derives from tier state), so chaos
            # drives the swap path into its preemption fallback too.
            # Host capacity is fungible: we steal a COUNT, not ids.
            tier = getattr(sched, "host_tier", None)
            if tier is not None:
                hn = tier.steal_free_pages(
                    int(tier.n_free * self.pressure_frac))
                if hn:
                    self._stolen.append(
                        (self._tick + self.pressure_hold_ticks,
                         "host_pages", hn))
                    fired = True
        else:
            n = int(pool.n_free * self.pressure_frac)
            items = pool.steal_free_slots(n)
            kind = "slots"
        if items:
            self._stolen.append((self._tick + self.pressure_hold_ticks,
                                 kind, items))
            fired = True
        if fired:
            self.n_pressure_events += 1

    def _abort_random(self, sched, pick: int) -> None:
        # parked (swapped-out) requests are cancellable clients too —
        # their cancel must free BOTH tiers' pages
        rids = sorted([r.rid for r in sched.queue]
                      + [s.req.rid for s in sched.active.values()]
                      + [s.req.rid
                         for s in getattr(sched, "parked", {}).values()])
        if not rids:
            return
        rid = rids[pick % len(rids)]
        if sched.cancel(rid, reason=f"fault injection abort "
                                    f"(seed={self.seed})"):
            self.n_aborts += 1
            self.aborted_rids.append(rid)

    def _restore_due(self, pool) -> None:
        due = [e for e in self._stolen if e[0] <= self._tick]
        if not due:
            return
        self._stolen = [e for e in self._stolen if e[0] > self._tick]
        for _, kind, items in due:
            self._restore(pool, kind, items)

    def _restore(self, pool, kind: str, items) -> None:
        if kind == "pages":
            pool.restore_free_pages(items)
        elif kind == "host_pages":
            pool.host_tier.restore_free_pages(items)
        else:
            pool.restore_free_slots(items)

    # ------------------------------------------------------------ stats

    def stats(self) -> dict:
        return {
            "seed": self.seed,
            "forced_preempts": self.n_forced_preempts,
            "pressure_events": self.n_pressure_events,
            "slow_ticks": self.n_slow_ticks,
            "aborts": self.n_aborts,
            "aborted_rids": list(self.aborted_rids),
            "clock_offset_s": round(self._offset, 6),
            "outstanding_stolen": sum(
                i if isinstance(i, int) else len(i)
                for _, _, i in self._stolen),
        }
