"""Admission control for the continuous-batching scheduler: deadline-
aware load shedding and hysteretic graceful degradation under overload.

The scheduler today has exactly one pressure valve — youngest-first
preemption — which trades LATENCY for memory. Under sustained overload
(arrival rate above service rate) that is the wrong valve: the queue
grows without bound and every request eventually misses its deadline.
This module adds the two valves a production server actually turns:

  * SHED — reject a request outright when it provably cannot meet its
    deadline (its deadline already expired, or its own prefill-block
    count times an observed LOWER BOUND on per-tick service time
    already exceeds the time remaining). A shed request costs zero
    device work and returns `status="shed"` with a reason instead of
    silently missing its SLO;

  * DEGRADE — route newly admitted requests to SPARSER SparsityPlan
    effort tiers (dense -> balanced -> turbo) while load watermarks are
    tripped. This is the paper's FLOP/accuracy knob (Fast Forward
    Alg. 1) applied as an overload policy: every tier's executables are
    pre-registered and pre-compiled (PR 5), so degrading costs zero
    recompilation — the server sheds FLOPs, not requests.

Degradation is HYSTERETIC: it escalates one ladder step when pressure
crosses the high watermark (queue depth >= queue_high OR free
resource fraction <= free_low), de-escalates one step when load falls
below the low watermark (queue depth <= queue_low AND free fraction >=
free_high), and holds each level for at least `dwell_ticks` ticks so a
noisy queue doesn't flap tiers tick-to-tick. The gap between the two
watermark pairs is the hysteresis band.

Ordering contract (see ROADMAP "Overload semantics"): SHED happens at
submit (zero work wasted), DEGRADE at admission (the request's whole
lifetime runs one tier), PREEMPT at page pressure (work already done
is discarded last). A request is never degraded to a tier DENSER than
it asked for, and explicit effort requests are only ever made sparser.

The controller is pure host-side policy: it never touches device state,
so it composes with both KV layouts and with the FaultInjector.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Watermarks + hysteresis for the degradation state machine.

    queue_high/queue_low: queue-depth watermarks (requests waiting).
    free_low/free_high:   free-resource-fraction watermarks (AVAILABLE
                          pages of the paged heap — truly free plus
                          reclaimable cached-idle prefix pages, which
                          surrender to eviction on demand; free slots
                          of the slot pool). With memory tiering on
                          (scheduler swap_pages > 0) the fraction is
                          CROSS-TIER: (device available + host free) /
                          (device usable + host capacity) — swap
                          capacity absorbs pressure before preemption,
                          so it is headroom the watermarks should see.
                          Pressure trips at `free_low`, recovery
                          requires `free_high` — the band is the
                          hysteresis.
    dwell_ticks:          minimum ticks between level changes (both
                          directions), so one bursty tick cannot walk
                          the whole ladder.
    degrade:              master switch for tier degradation (shedding
                          of provably-infeasible requests stays on).
    """
    queue_high: int = 8
    queue_low: int = 2
    free_low: float = 0.25
    free_high: float = 0.5
    dwell_ticks: int = 8
    degrade: bool = True

    def __post_init__(self):
        if self.queue_low > self.queue_high:
            raise ValueError(f"queue_low={self.queue_low} must be <= "
                             f"queue_high={self.queue_high}")
        if self.free_low > self.free_high:
            raise ValueError(f"free_low={self.free_low} must be <= "
                             f"free_high={self.free_high}")


class AdmissionController:
    """Deadline-aware shedding + hysteretic effort degradation.

    Owned by a ContinuousBatchingScheduler (pass `admission=` to its
    constructor). The scheduler calls:

      observe(queue_depth, free_frac)   once per tick — advances the
                                        hysteretic degradation level;
      degraded_plan(plan_idx)           at admission — maps the
                                        requested plan to the (possibly
                                        sparser) tier the current level
                                        dictates;
      shed_reason(...)                  at submit — non-None when the
                                        request provably cannot meet a
                                        deadline and must be shed.
    """

    def __init__(self, plans: Sequence = (),
                 config: Optional[AdmissionConfig] = None):
        self.cfg = config or AdmissionConfig()
        self.plans = tuple(plans)
        # ladder: plan indices ordered densest -> sparsest (by
        # analytical FFN FLOP fraction; ties keep registration order,
        # so plans[0] — the default tier — wins them). level L routes
        # new admissions to at least ladder position L.
        self.ladder: List[int] = sorted(
            range(len(self.plans)),
            key=lambda i: (-self.plans[i].flop_frac(), i))
        self._rank = {p: r for r, p in enumerate(self.ladder)}
        self.level = 0
        self._last_change_tick = -self.cfg.dwell_ticks
        self._tick = 0
        # stats (serve.py robustness line / benchmarks)
        self.n_escalations = 0
        self.n_deescalations = 0
        self.peak_level = 0

    # ------------------------------------------------------ hysteresis

    @property
    def max_level(self) -> int:
        return max(len(self.ladder) - 1, 0)

    def observe(self, queue_depth: int, free_frac: float) -> None:
        """One tick of the hysteretic state machine. Escalates on the
        high watermarks, de-escalates on the low ones, holds the level
        for at least dwell_ticks between changes."""
        self._tick += 1
        if not self.cfg.degrade or not self.ladder:
            return
        if self._tick - self._last_change_tick < self.cfg.dwell_ticks:
            return
        c = self.cfg
        pressured = queue_depth >= c.queue_high or free_frac <= c.free_low
        relaxed = queue_depth <= c.queue_low and free_frac >= c.free_high
        if pressured and self.level < self.max_level:
            self.level += 1
            self.peak_level = max(self.peak_level, self.level)
            self.n_escalations += 1
            self._last_change_tick = self._tick
        elif relaxed and self.level > 0:
            self.level -= 1
            self.n_deescalations += 1
            self._last_change_tick = self._tick

    def degraded_plan(self, plan_idx: int) -> int:
        """Plan index a NEW admission should run under: at least as
        sparse as both the request's own tier and the current level
        (never denser than requested — degradation is one-way)."""
        if not self.cfg.degrade or not self.ladder:
            return plan_idx
        rank = max(self._rank.get(plan_idx, 0), self.level)
        return self.ladder[rank]

    def reset(self) -> None:
        """Back to level 0 with cleared stats (scheduler warmup)."""
        self.level = 0
        self._tick = 0
        self._last_change_tick = -self.cfg.dwell_ticks
        self.n_escalations = self.n_deescalations = 0
        self.peak_level = 0

    # -------------------------------------------------------- shedding

    @staticmethod
    def shed_reason(req, now: float, n_blocks: int,
                    min_block_s: Optional[float]) -> Optional[str]:
        """Non-None when `req` PROVABLY cannot meet one of its
        deadlines, with the reason. Provable means a true lower bound:

          * the deadline has already expired at submit time;
          * the request's own prefill needs `n_blocks` sequential
            ticks (one 128-token block per request per tick), and the
            fastest prefill tick ever observed (`min_block_s`) times
            that count already exceeds the time remaining. Optimistic
            on every axis (empty queue, widest batch, fastest ticks),
            so a shed here could not have been served in time by ANY
            schedule.

        With the prefix cache on, the scheduler passes the UNSHARED
        block count — blocks covered by the currently-cached chain run
        zero prefill ticks, so charging them would shed requests that
        sharing serves in time. That keeps the bound quasi-provable:
        coverage can only grow while the request queues (evictions
        fire only under page pressure, i.e. when the request was
        waiting anyway), so the bound never over-charges.

        Returns None while no tick time has been observed yet (nothing
        is provable about an unmeasured system) or when the request
        carries no deadline."""
        arrival = req.arrival_time if req.arrival_time is not None else now
        for label, dl_ms in (("ttft", req.ttft_deadline_ms),
                             ("deadline", req.deadline_ms)):
            if dl_ms is None:
                continue
            remaining = arrival + dl_ms / 1e3 - now
            if remaining <= 0:
                return (f"{label} ({dl_ms:g} ms) already expired at "
                        f"submit")
            if min_block_s and n_blocks * min_block_s > remaining:
                return (f"cannot meet {label}: needs >= "
                        f"{n_blocks} prefill ticks x {min_block_s:.4g}s "
                        f"> {remaining:.4g}s remaining")
        return None

    # ----------------------------------------------------------- stats

    def stats(self) -> dict:
        return {
            "level": self.level,
            "peak_level": self.peak_level,
            "ladder": [getattr(self.plans[i], "name", str(i))
                       for i in self.ladder],
            "escalations": self.n_escalations,
            "deescalations": self.n_deescalations,
        }
