"""ModelRuntime: the uniform jitted interface the serving stack drives.

The continuous-batching scheduler (repro.serving.scheduler) never
touches model internals — it sees four operations:

  init_cache(n_slots, cache_len)  allocate the pooled KV buffers
  prefill_blocks(...)             one 128-token FastForward block of EACH
                                  of P requests (fixed [P, N] batch with
                                  per-row slot/pos0/is_dense/length and
                                  an `active` pad mask) — the batched
                                  prefill hot path
  prefill_block(...)              one block of ONE request (the original
                                  one-block-per-tick entry; kept as the
                                  P=1 path and the batched path's
                                  equivalence baseline)
  decode_step(...)                one token for ALL slots (active mask)
  logits_at(hidden, lengths)      read logits at each row's last prompt
                                  token (static-batch path)

  init_cache_paged / prefill_blocks_paged / decode_step_paged
                                  paged-KV-layout twins (cfg.kv_layout
                                  = "paged"): the cache is a shared
                                  page pool and requests address it
                                  through traced [*, max_pages] page
                                  tables (serving/page_pool.py)
  draft_steps / verify_chunk      speculative-decode protocol entries
  (+ _paged twins)                (serving/speculative.py): k argmax-
                                  feedback draft steps under the draft
                                  plan, then ONE chunk-scored pass over
                                  the fixed [n_slots, k+1] batch under
                                  each row's verify plan — both are
                                  lax.scan loops over the model's own
                                  decode_step body (models/chunked.py),
                                  warmed at warmup so compile counts
                                  stay flat (k is the only static)

Every operation is jitted once with fixed shapes — the prefill entries
trace over (slot, pos0, is_dense, length, active) as *values* and P is
a static batch width (inactive rows pad short ticks), so a churning
request set never triggers recompilation: the same executables serve
the whole stream (asserted via `compile_counts`).

SparsityPlan (repro.core.scheduler): the runtime registers a tuple of
plans (effort tiers) at construction. Each prefill entry takes the
plan as a jit STATIC argument — the scheduler batches only same-plan
rows, and warmup pre-compiles every (plan, width bucket) pair — while
decode keeps ONE executable: the plan tuple is closed over statically
and traced [n_slots] `plan_ids` select each row's per-layer tile
counts, so a slot pool mixing effort tiers decodes in one call.

Adapters: `DenseRuntime` (dense family incl. VLM text stack) and
`MoeRuntime`. Both rely on the per-row-offset block prefill steps the
model modules expose (models/dense.py, models/moe.py: `prefill_block`
and the batched `prefill_blocks`).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp

from repro.compat import jit_cache_size
from repro.core import fastforward as FF
from repro.models.base import ModelConfig
from repro.models.registry import get_model
from repro.nn import attention as A
from repro.nn import layers as L


@runtime_checkable
class ModelRuntime(Protocol):
    """What the scheduler/engine require of a servable model."""

    cfg: ModelConfig
    block_size: int

    def init_cache(self, n_slots: int, cache_len: int): ...

    def prefill_block(self, cache, tokens, slot, pos0, is_dense, length,
                      plan=None):
        """Process one block-size chunk of one request.

        cache: pooled KV pytree (leaves [L, n_slots, S, Kv, dh]);
        tokens: [1, N] int32 (zero-padded past `length`); slot/pos0/
        length: int32 scalars; is_dense: bool scalar (dense first/last
        block). Returns (cache, logits [V]) — logits are read at token
        `length-1-pos0` within the block and only meaningful on the
        request's final block."""
        ...

    def prefill_blocks(self, cache, tokens, slots, pos0s, is_dense,
                       lengths, active, plan=None):
        """Process one block-size chunk of EACH of P distinct requests
        in a single jitted call (the batched prefill hot path).

        cache: pooled KV pytree (leaves [L, n_slots, S, Kv, dh]);
        tokens: [P, N] int32 (row p zero-padded past lengths[p]);
        slots/pos0s/lengths: [P] int32; is_dense: [P] bool (dense
        first/last block PER SEQUENCE); active: [P] bool — P is static,
        so short ticks pad with inactive rows whose slot ids are unused
        by the live rows of THIS call (their KV writes become self-
        copies at scatter-back). Returns (cache, logits [P, V]) —
        row p's logits are read at its token `lengths[p]-1-pos0s[p]`
        and only meaningful on that request's final block."""
        ...

    def decode_step(self, cache, tokens, positions, active,
                    plan_ids=None):
        """One generation step for the whole slot pool. tokens/positions:
        [n_slots] int32; active: [n_slots] bool (inactive rows neither
        write KV nor produce meaningful logits); plan_ids: optional
        [n_slots] int32 indices into the registered plan tuple (per-
        request effort tiers through one executable). Returns
        (logits [n_slots, V], greedy [n_slots] int32, cache)."""
        ...

    def init_cache_paged(self, n_pages: int, page_size: int):
        """Allocate the paged KV pool (leaves [L, n_pages, psz, Kv, dh];
        page 0 reserved as the null page — see serving/page_pool.py)."""
        ...

    def prefill_blocks_paged(self, cache, tokens, page_tables, pos0s,
                             is_dense, lengths, active, plan=None):
        """Paged-layout twin of `prefill_blocks`: cache is the WHOLE
        page pool (no slot gather/scatter — each row's block K/V
        scatters onto the pages its [P, max_pages] table owns, and
        attention gathers the table-mapped view). Tables are traced
        values, so churning tables/offsets reuse one executable per
        width bucket — including width 1, which replaces the slot
        layout's separate `prefill_block` entry."""
        ...

    def decode_step_paged(self, cache, tokens, page_table, positions,
                          active, plan_ids=None):
        """Paged-layout twin of `decode_step`: page_table is the full
        [n_slots, max_pages] table array; each active row's token writes
        into the page covering its position (kernels/paged_attention
        dispatch on the read side)."""
        ...

    def logits_at(self, hidden, lengths):
        """hidden: [B, T, D] pre-final-norm; lengths: [B]. -> [B, V]."""
        ...

    def compile_counts(self) -> dict: ...


class _JittedRuntime:
    """Shared jit plumbing for model modules exposing the
    prefill_block/decode_step/init_cache triple."""

    def __init__(self, cfg: ModelConfig, params, shards: int = 1,
                 plans=None):
        self.cfg = cfg
        self.params = params
        self.model = get_model(cfg)
        self.shards = shards
        self.block_size = cfg.ff.block_size
        # registered SparsityPlans (per-request effort tiers). plans[0]
        # is the DEFAULT (requests without an effort). Each plan is a
        # jit STATIC argument of the prefill entries (one executable
        # per (plan, width bucket), all pre-compiled by warmup), while
        # decode stays ONE executable: the plan tuple is closed over
        # and traced [n_slots] plan_ids select per-row counts.
        if plans is not None:
            self.plans = tuple(plans)
        else:
            default = FF.resolve_plan(cfg, shards=shards)
            self.plans = (default,) if default is not None else ()
        if len({p.name for p in self.plans}) != len(self.plans):
            raise ValueError("SparsityPlan names must be unique: "
                             f"{[p.name for p in self.plans]}")
        self.plan_index = {p.name: i for i, p in enumerate(self.plans)}
        # the scheduler always replaces its cache reference with the
        # returned one, so the pooled KV buffers are donated: on
        # accelerators the update is in-place instead of a full-pool
        # copy per tick (CPU ignores donation)
        self._prefill_block = jax.jit(self._prefill_block_impl,
                                      donate_argnums=(1,),
                                      static_argnames=("plan",))
        self._prefill_blocks = jax.jit(self._prefill_blocks_impl,
                                       donate_argnums=(1,),
                                       static_argnames=("plan",))
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._prefill_blocks_paged = jax.jit(
            self._prefill_blocks_paged_impl, donate_argnums=(1,),
            static_argnames=("plan",))
        self._decode_paged = jax.jit(self._decode_paged_impl,
                                     donate_argnums=(1,))
        # speculative-decode protocol entries: the draft length is the
        # only static (one compile per k, pre-warmed); everything else
        # — tokens, positions, per-row validity, plan ids — is traced,
        # so the churning request mix reuses one executable per layout
        self._draft = jax.jit(self._draft_impl, donate_argnums=(1,),
                              static_argnames=("n_steps",))
        self._verify = jax.jit(self._verify_impl, donate_argnums=(1,))
        self._draft_paged = jax.jit(self._draft_paged_impl,
                                    donate_argnums=(1,),
                                    static_argnames=("n_steps",))
        self._verify_paged = jax.jit(self._verify_paged_impl,
                                     donate_argnums=(1,))
        # COW page copy (prefix sharing): cache donated like every
        # other cache-threading entry; src/dst are traced fixed-width
        # int32 vectors (scheduler pads with null self-copies), so all
        # COW batches share one executable
        self._copy_pages = jax.jit(self._copy_pages_impl,
                                   donate_argnums=(0,))
        # host-tier swap entries (serving/kv_tier.py): read gathers page
        # payloads for device->host swap-out (no donation — the pool
        # keeps the cache; freed pages are simply reusable afterwards),
        # write scatters host payloads back on swap-in (cache donated).
        # Page-id vectors are traced fixed width (scheduler pads with
        # page 0 — null-page reads / zero-payload null writes), so every
        # swap batch reuses one executable per direction.
        self._read_pages = jax.jit(self._read_pages_impl)
        self._write_pages = jax.jit(self._write_pages_impl,
                                    donate_argnums=(0,))
        self._logits_at = jax.jit(self._logits_at_impl)

    # -- plan plumbing -------------------------------------------------

    @property
    def default_plan(self):
        return self.plans[0] if self.plans else None

    def _norm_plan(self, plan):
        return plan if plan is not None else self.default_plan

    def _decode_plan_args(self, plan_ids):
        """(plan, plan_ids) for the model decode call: a single
        registered plan ignores the ids (the bit-compat simple path);
        several plans ride as a static tuple + traced per-row ids."""
        if len(self.plans) > 1:
            return self.plans, plan_ids
        return self.default_plan, None

    # -- model hooks (overridable per family) -------------------------

    def _model_prefill_block(self, params, tokens, sub_cache, pos0,
                             is_dense, lengths, plan):
        return self.model.prefill_block(
            params, self.cfg, tokens, sub_cache, pos0, is_dense=is_dense,
            lengths=lengths, shards=self.shards, plan=plan)

    def _model_prefill_blocks(self, params, tokens, sub_cache, pos0s,
                              is_dense, lengths, active, plan):
        return self.model.prefill_blocks(
            params, self.cfg, tokens, sub_cache, pos0s, is_dense=is_dense,
            lengths=lengths, active=active, shards=self.shards, plan=plan)

    def _model_decode_step(self, params, tokens, cache, positions, active,
                           plan_ids):
        # slot caches hold absolute positions, so sliding-window models
        # get the window as an attention mask in the ragged decode path
        plan, ids = self._decode_plan_args(plan_ids)
        return self.model.decode_step(
            params, self.cfg, tokens, cache, positions,
            shards=self.shards, window=self.cfg.sliding_window,
            active=active, plan=plan, plan_ids=ids)

    def _model_prefill_blocks_paged(self, params, tokens, cache, tables,
                                    pos0s, is_dense, lengths, active,
                                    plan):
        return self.model.prefill_blocks(
            params, self.cfg, tokens, cache, pos0s, is_dense=is_dense,
            lengths=lengths, active=active, page_tables=tables,
            shards=self.shards, plan=plan)

    def _model_decode_step_paged(self, params, tokens, cache, table,
                                 positions, active, plan_ids):
        plan, ids = self._decode_plan_args(plan_ids)
        return self.model.decode_step(
            params, self.cfg, tokens, cache, positions,
            shards=self.shards, window=self.cfg.sliding_window,
            active=active, page_table=table, plan=plan, plan_ids=ids)

    def _model_decode_draft(self, params, tokens, cache, positions,
                            active, n_draft, plan_ids, n_steps,
                            table=None):
        plan, ids = self._decode_plan_args(plan_ids)
        return self.model.decode_draft(
            params, self.cfg, tokens, cache, positions, n_steps,
            shards=self.shards, window=self.cfg.sliding_window,
            active=active, n_draft=n_draft, page_table=table,
            plan=plan, plan_ids=ids)

    def _model_decode_chunk(self, params, tokens, cache, positions,
                            active, n_valid, plan_ids, table=None):
        plan, ids = self._decode_plan_args(plan_ids)
        return self.model.decode_chunk(
            params, self.cfg, tokens, cache, positions,
            shards=self.shards, window=self.cfg.sliding_window,
            active=active, n_valid=n_valid, page_table=table,
            plan=plan, plan_ids=ids)

    # -- jitted impls --------------------------------------------------

    def _prefill_block_impl(self, params, cache, tokens, slot, pos0,
                            is_dense, length, plan=None):
        kc = jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, axis=1)
        sub, hidden = self._model_prefill_block(
            params, tokens, {"k": kc, "v": vc}, pos0, is_dense,
            jnp.reshape(length, (1,)), plan)
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], sub["k"], slot, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], sub["v"], slot, axis=1),
        }
        # logits at the request's last prompt token — only meaningful
        # when this block is the final one (length-1 falls inside it)
        idx = jnp.clip(length - 1 - pos0, 0, hidden.shape[1] - 1)
        h = self._final_norm(params, hidden[0, idx])
        return cache, L.unembed(params["lm_head"], h)

    def _prefill_blocks_impl(self, params, cache, tokens, slots, pos0s,
                             is_dense, lengths, active, plan=None):
        # gather each live row's slot from the pool, run one batched
        # per-row-offset block step, then scatter the updated rows back.
        # Slot ids within one call are DISTINCT (the scheduler pads
        # inactive rows with slots unused by this call's live rows), so
        # the scatter is write-disjoint; inactive rows write back their
        # own gathered KV — a deterministic self-copy.
        kc = jnp.take(cache["k"], slots, axis=1)
        vc = jnp.take(cache["v"], slots, axis=1)
        sub, hidden = self._model_prefill_blocks(
            params, tokens, {"k": kc, "v": vc}, pos0s, is_dense, lengths,
            active, plan)
        sel = active[None, :, None, None, None]
        cache = {
            "k": cache["k"].at[:, slots].set(
                jnp.where(sel, sub["k"], kc)),
            "v": cache["v"].at[:, slots].set(
                jnp.where(sel, sub["v"], vc)),
        }
        # per-row logits at each request's last prompt token — only
        # meaningful for rows whose final block is this one
        idx = jnp.clip(lengths - 1 - pos0s, 0, hidden.shape[1] - 1)
        h = jnp.take_along_axis(
            hidden, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        h = self._final_norm(params, h)
        return cache, L.unembed(params["lm_head"], h)

    def _decode_impl(self, params, cache, tokens, positions, active,
                     plan_ids):
        logits, cache = self._model_decode_step(
            params, tokens, cache, positions, active, plan_ids)
        # device-side greedy argmax: the scheduler's hot loop transfers
        # [n_slots] token ids, not [n_slots, V] logits (logits are only
        # pulled to host when a request samples with temperature > 0)
        return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _prefill_blocks_paged_impl(self, params, cache, tokens, tables,
                                   pos0s, is_dense, lengths, active,
                                   plan=None):
        # no slot gather/scatter: the whole page pool rides through the
        # model, which scatters each row's block onto the pages its
        # table owns (write-disjoint — pages are exclusively owned; pad
        # rows carry all-null tables and self-copy the null page)
        cache, hidden = self._model_prefill_blocks_paged(
            params, tokens, cache, tables, pos0s, is_dense, lengths,
            active, plan)
        idx = jnp.clip(lengths - 1 - pos0s, 0, hidden.shape[1] - 1)
        h = jnp.take_along_axis(
            hidden, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        h = self._final_norm(params, h)
        return cache, L.unembed(params["lm_head"], h)

    def _decode_paged_impl(self, params, cache, tokens, table, positions,
                           active, plan_ids):
        logits, cache = self._model_decode_step_paged(
            params, tokens, cache, table, positions, active, plan_ids)
        return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _draft_impl(self, params, cache, tokens, positions, active,
                    n_draft, plan_ids, n_steps):
        return self._model_decode_draft(params, tokens, cache, positions,
                                        active, n_draft, plan_ids,
                                        n_steps)

    def _verify_impl(self, params, cache, tokens, positions, active,
                     n_valid, plan_ids):
        return self._model_decode_chunk(params, tokens, cache, positions,
                                        active, n_valid, plan_ids)

    def _draft_paged_impl(self, params, cache, tokens, table, positions,
                          active, n_draft, plan_ids, n_steps):
        return self._model_decode_draft(params, tokens, cache, positions,
                                        active, n_draft, plan_ids,
                                        n_steps, table=table)

    def _verify_paged_impl(self, params, cache, tokens, table, positions,
                           active, n_valid, plan_ids):
        return self._model_decode_chunk(params, tokens, cache, positions,
                                        active, n_valid, plan_ids,
                                        table=table)

    def _copy_pages_impl(self, cache, src, dst):
        return A.copy_kv_pages(cache, src, dst)

    def _read_pages_impl(self, cache, pages):
        return jax.tree.map(lambda a: jnp.take(a, pages, axis=1), cache)

    def _write_pages_impl(self, cache, pages, payload):
        return jax.tree.map(lambda a, p: a.at[:, pages].set(p),
                            cache, payload)

    def _logits_at_impl(self, params, hidden, lengths):
        idx = jnp.clip(lengths - 1, 0, hidden.shape[1] - 1)
        h = jnp.take_along_axis(
            hidden, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        h = self._final_norm(params, h)
        return L.unembed(params["lm_head"], h)

    def _final_norm(self, params, h):
        from repro.models.dense import apply_norm
        return apply_norm(self.cfg, params["ln_f"], h)

    # -- public API ----------------------------------------------------

    def init_cache(self, n_slots: int, cache_len: int):
        return self.model.init_cache(self.cfg, n_slots, cache_len)

    def prefill_block(self, cache, tokens, slot, pos0, is_dense, length,
                      plan=None):
        return self._prefill_block(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            np.int32(slot), np.int32(pos0), np.bool_(is_dense),
            np.int32(length), plan=self._norm_plan(plan))

    def prefill_blocks(self, cache, tokens, slots, pos0s, is_dense,
                       lengths, active, plan=None):
        return self._prefill_blocks(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(slots, jnp.int32), jnp.asarray(pos0s, jnp.int32),
            jnp.asarray(is_dense, bool), jnp.asarray(lengths, jnp.int32),
            jnp.asarray(active, bool), plan=self._norm_plan(plan))

    def decode_step(self, cache, tokens, positions, active,
                    plan_ids=None):
        if plan_ids is None:
            plan_ids = np.zeros(len(np.asarray(tokens)), np.int32)
        return self._decode(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(positions, jnp.int32),
            jnp.asarray(active, bool), jnp.asarray(plan_ids, jnp.int32))

    def init_cache_paged(self, n_pages: int, page_size: int):
        # same spec factory as the slot cache with (batch, cache_len) ->
        # (n_pages, page_size): a page pool IS a slot pool whose "slots"
        # are page_size long and table-composed per request. With
        # cfg.kv_quant each K/V leaf becomes the int8 heap
        # {"q": int8 [L, n_pages, psz, Kv, dh], "s": f32 [L, n_pages, Kv]}
        # — zero-init, so page 0 (the null page) starts all-zeros with
        # scale 0 in both representations. lax.scan and jax.tree.map
        # thread dict leaves transparently, so the model modules are
        # untouched.
        cache = self.model.init_cache(self.cfg, n_pages, page_size)
        if not self.cfg.kv_quant:
            return cache
        def quantize_leaf(a):
            L_, np_, psz, kv, _dh = a.shape
            return {"q": jnp.zeros(a.shape, jnp.int8),
                    "s": jnp.zeros((L_, np_, kv), jnp.float32)}
        return {k: quantize_leaf(v) for k, v in cache.items()}

    def prefill_blocks_paged(self, cache, tokens, page_tables, pos0s,
                             is_dense, lengths, active, plan=None):
        return self._prefill_blocks_paged(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(page_tables, jnp.int32),
            jnp.asarray(pos0s, jnp.int32), jnp.asarray(is_dense, bool),
            jnp.asarray(lengths, jnp.int32), jnp.asarray(active, bool),
            plan=self._norm_plan(plan))

    def decode_step_paged(self, cache, tokens, page_table, positions,
                          active, plan_ids=None):
        if plan_ids is None:
            plan_ids = np.zeros(len(np.asarray(tokens)), np.int32)
        return self._decode_paged(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(page_table, jnp.int32),
            jnp.asarray(positions, jnp.int32), jnp.asarray(active, bool),
            jnp.asarray(plan_ids, jnp.int32))

    def draft_steps(self, cache, tokens, positions, active, n_draft, k,
                    plan_ids=None):
        """k argmax-feedback draft steps for the whole slot pool under
        the draft plan(s) in plan_ids. tokens: [n_slots] committed next
        tokens; n_draft: [n_slots] per-row valid draft counts (<= k —
        rows stop writing KV past their count); k is STATIC. Returns
        (drafts [n_slots, k] int32, cache)."""
        if plan_ids is None:
            plan_ids = np.zeros(len(np.asarray(tokens)), np.int32)
        return self._draft(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(positions, jnp.int32), jnp.asarray(active, bool),
            jnp.asarray(n_draft, jnp.int32),
            jnp.asarray(plan_ids, jnp.int32), n_steps=int(k))

    def verify_chunk(self, cache, tokens, positions, active, n_valid,
                     plan_ids=None):
        """ONE chunk-scored pass over the fixed [n_slots, k+1] batch
        under each row's own (verify) plan, REWRITING the draft's KV at
        positions p .. p+k-1. n_valid: [n_slots] per-row valid chunk
        widths (n_draft + 1). Returns (logits0 [n_slots, V],
        greedy [n_slots, k+1] int32, cache)."""
        if plan_ids is None:
            plan_ids = np.zeros(len(np.asarray(positions)), np.int32)
        return self._verify(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(positions, jnp.int32), jnp.asarray(active, bool),
            jnp.asarray(n_valid, jnp.int32),
            jnp.asarray(plan_ids, jnp.int32))

    def draft_steps_paged(self, cache, tokens, page_table, positions,
                          active, n_draft, k, plan_ids=None):
        if plan_ids is None:
            plan_ids = np.zeros(len(np.asarray(tokens)), np.int32)
        return self._draft_paged(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(page_table, jnp.int32),
            jnp.asarray(positions, jnp.int32), jnp.asarray(active, bool),
            jnp.asarray(n_draft, jnp.int32),
            jnp.asarray(plan_ids, jnp.int32), n_steps=int(k))

    def verify_chunk_paged(self, cache, tokens, page_table, positions,
                           active, n_valid, plan_ids=None):
        if plan_ids is None:
            plan_ids = np.zeros(len(np.asarray(positions)), np.int32)
        return self._verify_paged(
            self.params, cache, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(page_table, jnp.int32),
            jnp.asarray(positions, jnp.int32), jnp.asarray(active, bool),
            jnp.asarray(n_valid, jnp.int32),
            jnp.asarray(plan_ids, jnp.int32))

    def copy_pages(self, cache, src_pages, dst_pages):
        """Device COW copy src -> dst across every cache leaf (page
        axis 1). Fixed-width traced indices: the scheduler pads short
        batches with 0 -> 0 null self-copies so one executable covers
        every COW count."""
        return self._copy_pages(cache, jnp.asarray(src_pages, jnp.int32),
                                jnp.asarray(dst_pages, jnp.int32))

    def read_pages(self, cache, pages):
        """Gather page payloads [*, W, ...] across every cache leaf
        (page axis 1) for device->host swap-out. pages: [W] int32,
        FIXED width (pad with 0 -> harmless null-page reads). The cache
        is NOT donated: swap-out only copies bytes out; the pool then
        recycles the still-resident source pages."""
        return self._read_pages(cache, jnp.asarray(pages, jnp.int32))

    def write_pages(self, cache, pages, payload):
        """Scatter host payloads back into the heap on swap-in (the
        inverse of `read_pages`; cache donated). Padding pairs page 0
        with an all-zero payload — rewriting the null page's own
        content — so one executable serves every swap-in width."""
        return self._write_pages(cache, jnp.asarray(pages, jnp.int32),
                                 payload)

    def logits_at(self, hidden, lengths):
        return self._logits_at(self.params, hidden,
                               jnp.asarray(lengths, jnp.int32))

    def compile_counts(self) -> dict:
        """Distinct compilations per jitted entry point. After warmup
        (one prefill tick + one decode step) these must not grow —
        the serving loop's zero-recompilation invariant. The batched
        `prefill_blocks` entry is covered too: its [P, N] batch width
        is static, so a churning mix of requests, offsets, and pad rows
        reuses one executable."""
        return {
            "prefill_block": jit_cache_size(self._prefill_block),
            "prefill_blocks": jit_cache_size(self._prefill_blocks),
            "decode_step": jit_cache_size(self._decode),
            "prefill_blocks_paged": jit_cache_size(
                self._prefill_blocks_paged),
            "decode_step_paged": jit_cache_size(self._decode_paged),
            "draft_steps": jit_cache_size(self._draft),
            "verify_chunk": jit_cache_size(self._verify),
            "draft_steps_paged": jit_cache_size(self._draft_paged),
            "verify_chunk_paged": jit_cache_size(self._verify_paged),
            "copy_pages": jit_cache_size(self._copy_pages),
            "read_pages": jit_cache_size(self._read_pages),
            "write_pages": jit_cache_size(self._write_pages),
            "logits_at": jit_cache_size(self._logits_at),
        }


class DenseRuntime(_JittedRuntime):
    """Dense llama-family models (and the VLM text stack)."""

    ARCHS = ("dense", "vlm")

    def __init__(self, cfg: ModelConfig, params, shards: int = 1,
                 mesh=None, plans=None):
        if cfg.arch not in self.ARCHS:
            raise ValueError(f"DenseRuntime cannot drive arch={cfg.arch}")
        self.mesh = mesh
        super().__init__(cfg, params, shards, plans=plans)

    def _model_prefill_block(self, params, tokens, sub_cache, pos0,
                             is_dense, lengths, plan):
        from repro.models import dense
        return dense.prefill_block(
            params, self.cfg, tokens, sub_cache, pos0, is_dense=is_dense,
            lengths=lengths, shards=self.shards, plan=plan,
            mesh=self.mesh)

    def _model_prefill_blocks(self, params, tokens, sub_cache, pos0s,
                              is_dense, lengths, active, plan):
        from repro.models import dense
        return dense.prefill_blocks(
            params, self.cfg, tokens, sub_cache, pos0s, is_dense=is_dense,
            lengths=lengths, active=active, shards=self.shards,
            plan=plan, mesh=self.mesh)

    def _model_prefill_blocks_paged(self, params, tokens, cache, tables,
                                    pos0s, is_dense, lengths, active,
                                    plan):
        from repro.models import dense
        return dense.prefill_blocks(
            params, self.cfg, tokens, cache, pos0s, is_dense=is_dense,
            lengths=lengths, active=active, page_tables=tables,
            shards=self.shards, plan=plan, mesh=self.mesh)


class MoeRuntime(_JittedRuntime):
    """Mixture-of-experts models (qwen2-moe, kimi-k2). Dropless routed
    dispatch is dispatch-group invariant: a token routes identically in
    the [1, N] single-block, [P, N] batched-prefill, and [n_slots, 1]
    decode entries, so blockwise serving reproduces the full-sequence
    forward token-for-token. The sorted-segment buffers are sized by
    the fixed batch shapes (N*K rows) — no recompilation as requests
    churn, same contract as the dense runtime."""

    ARCHS = ("moe",)

    def __init__(self, cfg: ModelConfig, params, shards: int = 1,
                 plans=None):
        if cfg.arch not in self.ARCHS:
            raise ValueError(f"MoeRuntime cannot drive arch={cfg.arch}")
        super().__init__(cfg, params, shards, plans=plans)


def make_runtime(cfg: ModelConfig, params, shards: int = 1,
                 mesh=None, plans=None) -> ModelRuntime:
    """Dispatch cfg.arch -> runtime adapter. plans: optional tuple of
    SparsityPlans to register (plans[0] is the default tier; requests
    pick one by name — the per-request serving knob)."""
    if cfg.arch in DenseRuntime.ARCHS:
        return DenseRuntime(cfg, params, shards=shards, mesh=mesh,
                            plans=plans)
    if cfg.arch in MoeRuntime.ARCHS:
        return MoeRuntime(cfg, params, shards=shards, plans=plans)
    raise ValueError(
        f"no serving runtime for arch={cfg.arch}; supported: "
        f"{DenseRuntime.ARCHS + MoeRuntime.ARCHS}")
