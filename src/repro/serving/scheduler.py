"""Continuous-batching scheduler: chunked FastForward prefill
interleaved with batched decode over a KV slot pool.

The paper's 128-token prefill block is exactly one schedulable unit of
work, so each scheduler tick does

  1. ADMIT   — move queued requests into free KV slots (mid-flight:
               a slot freed by a finishing request is re-filled on the
               very next tick, while other requests keep decoding);
  2. PREFILL — process one FastForward block of EACH of up to
               `prefill_batch` requests still prefilling (oldest
               first), in ONE jitted `prefill_blocks` call with
               per-row slot/offset/is_dense/length vectors
               (dense-first/last semantics preserved *per sequence*,
               unlike the static right-padded batch where the padded
               batch's last block is dense instead). The batch width P
               is static: short ticks pad with inactive rows whose
               slot ids are unused by this call's live rows.
               prefill_batch=1 keeps the original one-block-per-tick
               `prefill_block` path (baseline for benchmarks/tests);
  3. DECODE  — one batched decode step over every slot in the decode
               phase (fixed batch = n_slots, active-slot mask).

All device work goes through the jitted ModelRuntime entry points, so
after the first tick of each kind there is zero recompilation —
`ModelRuntime.compile_counts()` is the enforcement hook.

Requests carrying an `eos_id` finish the moment they emit it —
mid-generation — and their slot returns to the free list on the same
tick, so EOS-heavy streams churn admission under the batched prefill
path (`n_eos_stops` counts early exits).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.serving.cache_pool import KVSlotPool
from repro.serving.runtime import ModelRuntime


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    arrival_time: Optional[float] = None   # None -> stamped at submit()


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: List[int]
    prompt_len: int
    ttft_seconds: float          # arrival -> first token
    finish_seconds: float        # arrival -> last token


@dataclasses.dataclass
class _ActiveState:
    req: Request
    slot: int
    seq: int                     # admission order (FIFO prefill)
    n_blocks: int
    blocks_done: int = 0
    phase: str = "prefill"       # prefill | decode
    out: List[int] = dataclasses.field(default_factory=list)
    next_token: int = 0          # last sampled token (decode input)
    pos: int = 0                 # next KV write position
    first_token_time: Optional[float] = None


class ContinuousBatchingScheduler:
    """Admits requests from a queue into KV slots mid-flight and
    interleaves chunked blockwise prefill with batched decode."""

    def __init__(self, runtime: ModelRuntime, n_slots: int = 8,
                 cache_len: int = 2048, seed: int = 0,
                 prefill_batch: int = 4, clock=time.perf_counter,
                 sleep=time.sleep):
        self.runtime = runtime
        self.pool = KVSlotPool.create(runtime, n_slots, cache_len)
        self.n_slots = n_slots
        self.cache_len = cache_len
        # max width of the batched prefill entry: up to this many
        # requests advance one block per tick in ONE jitted call. Must
        # not exceed n_slots (pad rows need distinct unused slot ids).
        self.prefill_batch = max(1, min(prefill_batch, n_slots))
        # width buckets (powers of two up to prefill_batch): each tick
        # picks the smallest bucket holding its live rows, so a thin
        # backlog never pays the full P-wide padded batch. One
        # executable per bucket, all pre-compiled by warmup().
        self.prefill_widths = []
        w = 1
        while w < self.prefill_batch:
            self.prefill_widths.append(w)
            w *= 2
        self.prefill_widths.append(self.prefill_batch)
        self.clock = clock
        # idle wait between stream arrivals (drive_stream). Injected
        # alongside `clock` so a fake/simulated clock brings a matching
        # sleep: waiting on wall time for a delta measured on a fake
        # clock would block a deterministic stream test on real seconds.
        self.sleep = sleep
        self._rng = np.random.default_rng(seed)
        self.queue: deque[Request] = deque()
        self.active: Dict[int, _ActiveState] = {}   # slot -> state
        self.finished: Dict[int, RequestOutput] = {}
        self._admit_seq = 0
        # tick counters (benchmarks / tests)
        self.n_ticks = 0
        self.n_prefill_blocks = 0
        self.n_prefill_ticks = 0
        self.n_decode_steps = 0
        self.n_eos_stops = 0

    # --------------------------------------------------------- submit

    def submit(self, req: Request) -> None:
        need = max(self._n_blocks(req) * self.runtime.block_size,
                   len(req.prompt) + req.max_new)
        if not self.pool.fits(need):
            raise ValueError(
                f"request {req.rid} needs {need} cache positions but the "
                f"pool's cache_len is {self.cache_len}")
        if not req.prompt:
            raise ValueError(f"request {req.rid} has an empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1 "
                             f"(the first token is sampled from prefill "
                             f"logits and always emitted)")
        if req.arrival_time is None:
            req.arrival_time = self.clock()
        self.queue.append(req)

    def _n_blocks(self, req: Request) -> int:
        N = self.runtime.block_size
        return -(-len(req.prompt) // N)

    # ----------------------------------------------------------- tick

    @property
    def drained(self) -> bool:
        return not self.queue and not self.active

    def tick(self) -> int:
        """One scheduling step; returns the number of tokens emitted."""
        self.n_ticks += 1
        self._admit()
        emitted = self._prefill_blocks()
        emitted += self._decode_all()
        return emitted

    def run(self, max_ticks: int = 1_000_000) -> Dict[int, RequestOutput]:
        """Drive ticks until every submitted request has finished."""
        for _ in range(max_ticks):
            if self.drained:
                break
            self.tick()
        if not self.drained:
            raise RuntimeError(f"scheduler not drained after {max_ticks} "
                               f"ticks")
        return self.finished

    def warmup(self) -> dict:
        """Compile every serving executable by running one throwaway
        request through this scheduler's own pool (no second KV
        allocation) — the single-block prefill + decode pair — and then
        one ALL-INACTIVE `prefill_blocks` call per batched width bucket
        (inactive rows scatter back their own gathered KV, so the pool
        is untouched), then reset counters/stats. After this, a
        churning request mix never compiles again. Returns the
        post-warmup compile counts."""
        if self.active or self.queue or self.finished:
            raise RuntimeError("warmup() must run before real traffic")
        N = self.runtime.block_size
        self.submit(Request(rid=-1, prompt=[1] * min(N, self.cache_len - 2),
                            max_new=2))
        self.run()
        for w in self.prefill_widths:
            if w == 1:
                continue          # compiled by the throwaway request
            self.pool.cache, _ = self.runtime.prefill_blocks(
                self.pool.cache, np.zeros((w, N), np.int32),
                np.arange(w, dtype=np.int32), np.zeros(w, np.int32),
                np.zeros(w, bool), np.ones(w, np.int32),
                np.zeros(w, bool))
        self.finished.clear()
        self._admit_seq = 0
        self.n_ticks = self.n_prefill_blocks = self.n_decode_steps = 0
        self.n_prefill_ticks = self.n_eos_stops = 0
        self.pool.total_acquires = self.pool.total_releases = 0
        self.pool.max_in_use = 0
        return self.runtime.compile_counts()

    # ------------------------------------------------------- internals

    def _admit(self) -> None:
        while self.queue:
            slot = self.pool.acquire()
            if slot is None:
                return
            req = self.queue.popleft()
            self.active[slot] = _ActiveState(
                req=req, slot=slot, seq=self._admit_seq,
                n_blocks=self._n_blocks(req))
            self._admit_seq += 1

    def _block_meta(self, st: _ActiveState):
        """(chunk tokens, pos0, is_dense) for a state's next block."""
        N = self.runtime.block_size
        ff = self.runtime.cfg.ff
        b = st.blocks_done
        is_dense = ((ff.dense_first_block and b == 0) or
                    (ff.dense_last_block and b == st.n_blocks - 1))
        return st.req.prompt[b * N:(b + 1) * N], b * N, is_dense

    def _finish_block(self, st: _ActiveState, logits_row) -> int:
        """Book-keeping after a state's block was processed; samples the
        first token (TTFT) when it was the final prompt block. Returns
        tokens emitted (0 or 1)."""
        N = self.runtime.block_size
        st.blocks_done += 1
        self.n_prefill_blocks += 1
        self.pool.lengths[st.slot] = min(st.blocks_done * N,
                                         len(st.req.prompt))
        if st.blocks_done < st.n_blocks:
            return 0
        tok = self._sample(logits_row(), st.req)
        st.first_token_time = self.clock()
        st.out.append(tok)
        st.next_token = tok
        st.pos = len(st.req.prompt)
        st.phase = "decode"
        self._maybe_finish(st)
        return 1

    def _prefill_one_block(self, st: _ActiveState, meta) -> int:
        """Original one-block-per-tick path (PR-1): one request, one
        [1, N] jitted call. Kept as the prefill_batch=1 / width-1 bucket
        the batched path is benchmarked and bit-compared against.
        `meta` is the state's precomputed `_block_meta` for this tick."""
        N = self.runtime.block_size
        chunk, pos0, is_dense = meta
        tok_blk = np.zeros((1, N), np.int32)
        tok_blk[0, :len(chunk)] = chunk
        self.pool.cache, logits = self.runtime.prefill_block(
            self.pool.cache, tok_blk, st.slot, pos0, is_dense,
            len(st.req.prompt))
        self.n_prefill_ticks += 1
        return self._finish_block(st, lambda: np.asarray(logits))

    def _prefill_blocks(self) -> int:
        """Batched prefill: drain one block of EACH of up to
        `prefill_batch` distinct prefilling requests (oldest first) in
        one jitted `prefill_blocks` call.

        Two batch-shaping policies keep the batched tick cheap:

          * density-homogeneous batching — only rows whose next block
            needs the SAME FFN branch as the oldest request's ride in
            one call (skipped rows go next tick; the oldest is always
            included, so no starvation). The per-row is_dense vector is
            then all-equal and `ff_blocks_sparse`'s any()-gated conds
            execute exactly ONE branch — a mixed batch would pay for
            both;
          * width bucketing — the batch is padded up to the smallest
            pre-compiled width bucket (not always to P) with inactive
            rows parked on slot ids unused by this call's live rows
            (their KV writes are discarded device-side), so a backlog
            of 1-2 requests doesn't pay a P-wide padded call.
        """
        states = sorted(
            (s for s in self.active.values() if s.phase == "prefill"),
            key=lambda s: s.seq)                        # FIFO
        if not states:
            return 0
        # one _block_meta per state per tick: the same meta drives both
        # the density filter and the batch fill (re-deriving it would
        # re-slice each prompt chunk)
        metas = [(s, self._block_meta(s)) for s in states]
        lead_dense = metas[0][1][2]
        batch = [(s, m) for s, m in metas if m[2] == lead_dense]
        batch = batch[:self.prefill_batch]
        if len(batch) == 1:
            return self._prefill_one_block(*batch[0])   # width-1 bucket
        P = next(w for w in self.prefill_widths if w >= len(batch))
        N = self.runtime.block_size
        tokens = np.zeros((P, N), np.int32)
        slots = np.zeros(P, np.int32)
        pos0s = np.zeros(P, np.int32)
        is_dense = np.full(P, lead_dense, bool)
        lengths = np.ones(P, np.int32)
        active = np.zeros(P, bool)
        for i, (st, (chunk, pos0, _)) in enumerate(batch):
            tokens[i, :len(chunk)] = chunk
            slots[i] = st.slot
            pos0s[i] = pos0
            lengths[i] = len(st.req.prompt)
            active[i] = True
        used = {st.slot for st, _ in batch}
        spare = (s for s in range(self.n_slots) if s not in used)
        for i in range(len(batch), P):
            slots[i] = next(spare)
        self.pool.cache, logits = self.runtime.prefill_blocks(
            self.pool.cache, tokens, slots, pos0s, is_dense, lengths,
            active)
        self.n_prefill_ticks += 1
        logits_np = [None]        # pull [P, V] to host at most once

        def row(i):
            def get():
                if logits_np[0] is None:
                    logits_np[0] = np.asarray(logits)
                return logits_np[0][i]
            return get

        return sum(self._finish_block(st, row(i))
                   for i, (st, _) in enumerate(batch))

    def _decode_all(self) -> int:
        decoding = [s for s in self.active.values() if s.phase == "decode"]
        if not decoding:
            return 0
        tokens = np.zeros(self.n_slots, np.int32)
        positions = np.zeros(self.n_slots, np.int32)
        active = np.zeros(self.n_slots, bool)
        for st in decoding:
            tokens[st.slot] = st.next_token
            positions[st.slot] = st.pos
            active[st.slot] = True
        logits, greedy, self.pool.cache = self.runtime.decode_step(
            self.pool.cache, tokens, positions, active)
        self.n_decode_steps += 1
        greedy = np.asarray(greedy)
        # logits cross to host only if someone actually samples
        logits_np = (np.asarray(logits)
                     if any(s.req.temperature > 0 for s in decoding)
                     else None)
        emitted = 0
        for st in decoding:
            tok = (int(greedy[st.slot]) if st.req.temperature <= 0
                   else self._sample(logits_np[st.slot], st.req))
            st.out.append(tok)
            st.next_token = tok
            st.pos += 1
            self.pool.lengths[st.slot] = st.pos
            emitted += 1
            self._maybe_finish(st)
        return emitted

    def _maybe_finish(self, st: _ActiveState) -> None:
        hit_eos = (st.req.eos_id is not None and st.out
                   and st.out[-1] == st.req.eos_id)
        done = len(st.out) >= st.req.max_new or hit_eos
        if not done:
            return
        if hit_eos and len(st.out) < st.req.max_new:
            self.n_eos_stops += 1     # early exit frees the slot now
        now = self.clock()
        self.finished[st.req.rid] = RequestOutput(
            rid=st.req.rid, tokens=list(st.out),
            prompt_len=len(st.req.prompt),
            ttft_seconds=st.first_token_time - st.req.arrival_time,
            finish_seconds=now - st.req.arrival_time)
        del self.active[st.slot]
        self.pool.release(st.slot)

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.argmax(logits))
        # Gumbel-max with the scheduler's host RNG (per-stream seed)
        g = self._rng.gumbel(size=logits.shape)
        return int(np.argmax(logits.astype(np.float64)
                             / req.temperature + g))


def drive_stream(sched: ContinuousBatchingScheduler,
                 requests: List[Request]) -> float:
    """Drive a timed request stream to completion.

    Each request's `arrival_time` is an OFFSET in seconds from stream
    start; requests are submitted as their arrival time passes (the
    scheduler keeps ticking — mid-flight admission), and the loop
    sleeps instead of spinning while the pool is idle between
    arrivals. The caller's Request objects are never mutated (absolute
    deadlines are stamped onto copies), so the same list can drive
    several schedulers for A/B runs. Returns the wall-clock seconds
    for the whole stream. Used by launch/serve.py --stream and the
    continuous-batching benchmark so both exercise the identical
    serving loop."""
    clock = sched.clock
    t0 = clock()
    # ascending stable sort + popleft keeps FIFO order for tied arrivals
    pending = deque(
        dataclasses.replace(r, prompt=list(r.prompt),
                            arrival_time=t0 + (r.arrival_time or 0.0))
        for r in sorted(requests, key=lambda r: r.arrival_time or 0.0))
    while pending or not sched.drained:
        now = clock()
        while pending and pending[0].arrival_time <= now:
            sched.submit(pending.popleft())
        if sched.drained:
            # route the idle wait through the scheduler's injected sleep:
            # the delta is measured on sched.clock, so a simulated clock
            # must come with a simulated sleep (time.sleep on a fake-
            # clock delta would block on real wall time)
            sched.sleep(max(0.0, pending[0].arrival_time - clock()))
            continue
        sched.tick()
    return clock() - t0
