"""Continuous-batching scheduler: chunked FastForward prefill
interleaved with batched decode over a KV slot pool.

The paper's 128-token prefill block is exactly one schedulable unit of
work, so each scheduler tick does

  1. ADMIT   — move queued requests into free KV slots (mid-flight:
               a slot freed by a finishing request is re-filled on the
               very next tick, while other requests keep decoding);
  2. PREFILL — process one FastForward block of EACH of up to
               `prefill_batch` requests still prefilling (oldest
               first), in ONE jitted `prefill_blocks` call with
               per-row slot/offset/is_dense/length vectors
               (dense-first/last semantics preserved *per sequence*,
               unlike the static right-padded batch where the padded
               batch's last block is dense instead). The batch width P
               is static: short ticks pad with inactive rows whose
               slot ids are unused by this call's live rows.
               prefill_batch=1 keeps the original one-block-per-tick
               `prefill_block` path (baseline for benchmarks/tests);
  3. DECODE  — one batched decode step over every slot in the decode
               phase (fixed batch = n_slots, active-slot mask).

All device work goes through the jitted ModelRuntime entry points, so
after the first tick of each kind there is zero recompilation —
`ModelRuntime.compile_counts()` is the enforcement hook.

Requests carrying an `eos_id` finish the moment they emit it —
mid-generation — and their slot returns to the free list on the same
tick, so EOS-heavy streams churn admission under the batched prefill
path (`n_eos_stops` counts early exits).

KV layouts (cfg.kv_layout): with the default "slot" layout every
admitted request reserves a full max-cache_len KVSlotPool slot. With
"paged" the pool is a PagedKVPool page heap: ADMIT gates on free PAGES
(enough for one prefill block), pages are allocated lazily — one block
per prefill tick, one page at a time as decode crosses a page boundary
— and when the heap runs dry the scheduler PREEMPTS the youngest
request (release its pages, requeue it for re-prefill from scratch;
greedy output is deterministic so the final tokens are unchanged, only
its latency suffers — `n_preemptions` counts evictions). Only
strictly-younger requests are ever evicted, so the oldest always makes
progress and a stream that fits the heap per-request always drains.
Page tables are traced values, so the paged entries compile once per
width bucket exactly like the slot entries.

PREFIX SHARING (paged only, `prefix_cache=True`): page ownership is
refcounted (PagedKVPool) and a host-side PrefixIndex maps page-aligned
(SparsityPlan, token-chain) keys to cached pages. Admission looks up
the longest cached chain for the queue head, maps those pages into its
table as shared READERS (`pool.share`), copy-on-writes the partial
tail of the restart block, charges the gate only the UNSHARED page
footprint, and starts prefill at the first unshared block — the TTFT
win: shared prompt blocks never run. Each completed prompt block
(never the last — its pages see the request's own decode-adjacent
partial fills) is published back to the index. Release paths decrement
refcounts; cached pages whose last reader left park on a reclaimable
LRU, evicted (`PrefixIndex.drop_page`, whole subtrees) before the
scheduler resorts to preemption. Shared KV is bit-identical to
recomputing it — block b's KV depends only on the token chain before
it and the plan — so greedy output with sharing on equals sharing off,
and requests under DIFFERENT plans never share (plan keys the trie
root).

OVERLOAD SEMANTICS (the robustness contract, as load-bearing as the
bit-equivalence contract): requests carry optional deadlines
(`ttft_deadline_ms`, `deadline_ms`) and every request finishes with a
`RequestOutput.status` in {ok, timed_out, shed, cancelled}. The
pressure valves fire in a fixed order — SHED at submit (a request that
cannot fit the pool, or provably cannot meet its deadline, costs zero
device work), DEGRADE at admission (an `AdmissionController` routes
new admissions to sparser pre-compiled SparsityPlan tiers while
watermarks are tripped; the decision STICKS for the request's
lifetime, so preemption re-admits under the same tier and stays
output-transparent), PREEMPT under page pressure (work already done is
discarded last, youngest first). Deadline expiry and client
cancellation (`cancel(rid)`) free slots/pages idempotently mid-flight,
and a stall watchdog raises `SchedulerStallError` with a full state
dump when `stall_ticks` consecutive ticks make no observable progress
— a livelocked scheduler fails loudly instead of spinning. A seeded
`FaultInjector` (serving/faults.py, `faults=`) can drive all of these
paths deterministically.
"""
from __future__ import annotations

import dataclasses
import pprint
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.serving.admission import AdmissionController
from repro.serving.cache_pool import KVSlotPool
from repro.serving.kv_tier import HostKVTier
from repro.serving.page_pool import PagedKVPool
from repro.serving.prefix_index import PrefixIndex
from repro.serving.runtime import ModelRuntime
from repro.serving.speculative import SpeculativeConfig, accept_drafts


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    arrival_time: Optional[float] = None   # None -> stamped at submit()
    # SparsityPlan name registered on the runtime (effort tier, e.g.
    # "balanced"/"turbo"/"dense"); None -> the runtime's default plan.
    # The per-request sparsity knob: SLO-tiered traffic mixes tiers in
    # one stream with zero recompilation (plans are pre-compiled).
    effort: Optional[str] = None
    # deadlines, measured from arrival_time. Expiry frees the request's
    # resources mid-flight with status="timed_out"; at submit, a
    # provably-unmeetable deadline sheds instead (status="shed").
    ttft_deadline_ms: Optional[float] = None   # arrival -> first token
    deadline_ms: Optional[float] = None        # arrival -> last token
    # trace replay: the client cancels this many seconds after arrival
    # (drive_stream issues the cancel; see serving/trace.py)
    cancel_after_s: Optional[float] = None
    # per-request cap on the speculative draft length (tokens drafted
    # per decode tick); None -> the scheduler's SpeculativeConfig.k,
    # 0 -> speculation off for this request. Only latency-relevant:
    # greedy output is bit-identical for every value (the verify plan
    # is always the request's own plan).
    speculate: Optional[int] = None
    # scheduler-internal: plan index pinned at FIRST admission (the
    # degradation decision sticks, so preemption re-admits under the
    # SAME tier and stays output-transparent). Not a user field.
    assigned_plan_idx: Optional[int] = None


@dataclasses.dataclass
class RequestOutput:
    rid: int
    tokens: List[int]
    prompt_len: int
    ttft_seconds: Optional[float]  # arrival -> first token (None when
    #                                none was produced: shed, cancelled
    #                                or timed out during prefill)
    finish_seconds: float        # arrival -> terminal state
    # terminal status: "ok" | "timed_out" | "shed" | "cancelled".
    # Non-ok outputs keep whatever tokens were produced before the
    # terminal event (timed_out/cancelled may be partial; shed is
    # always empty).
    status: str = "ok"
    reason: Optional[str] = None   # human-readable cause for non-ok
    effort: Optional[str] = None   # REALIZED plan name (degradation
    #                                may have made it sparser than the
    #                                request asked)


@dataclasses.dataclass
class _ActiveState:
    req: Request
    slot: int
    seq: int                     # admission order (FIFO prefill)
    n_blocks: int
    rng: np.random.Generator     # per-request sampling stream, seeded
    #                              (scheduler seed, rid): a preempted
    #                              request re-admits with a FRESH copy,
    #                              so its re-run replays identical
    #                              temperature draws — preemption is
    #                              output-transparent for sampled
    #                              requests too, and one request's
    #                              draws never shift another's
    plan_idx: int = 0            # index into scheduler.plans (effort)
    blocks_done: int = 0
    phase: str = "prefill"       # prefill | decode
    out: List[int] = dataclasses.field(default_factory=list)
    next_token: int = 0          # last sampled token (decode input)
    pos: int = 0                 # next KV write position
    first_token_time: Optional[float] = None
    # prefix sharing: the prompt's page-aligned token tuples (None when
    # the cache is off) — lookup happens at admission, publish per
    # completed block
    page_keys: Optional[List[tuple]] = None


class SchedulerStallError(RuntimeError):
    """The scheduler made no observable progress for `stall_ticks`
    consecutive ticks while work was pending — a livelock. Carries the
    full scheduler-state dump (`.state`) that is also formatted into
    the message, so the failure is diagnosable from the raise alone."""

    def __init__(self, message: str, state: dict):
        super().__init__(message)
        self.state = state


class ContinuousBatchingScheduler:
    """Admits requests from a queue into KV slots mid-flight and
    interleaves chunked blockwise prefill with batched decode."""

    def __init__(self, runtime: ModelRuntime, n_slots: int = 8,
                 cache_len: int = 2048, seed: int = 0,
                 prefill_batch: int = 4, clock=time.perf_counter,
                 sleep=time.sleep, page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 admission: Optional[AdmissionController] = None,
                 faults=None, stall_ticks: int = 1000,
                 prefix_cache: bool = False,
                 speculative: Optional[SpeculativeConfig] = None,
                 swap_pages: int = 0):
        self.runtime = runtime
        layout = getattr(runtime.cfg, "kv_layout", "slot")
        self.kv_layout = layout
        self.paged = layout == "paged"
        if self.paged:
            psz = int(page_size or runtime.cfg.kv_page_size
                      or runtime.block_size)
            if runtime.block_size % psz:
                raise ValueError(
                    f"page_size={psz} must divide the prefill block size "
                    f"{runtime.block_size} (a block scatters whole pages)")
            max_pages = -(-cache_len // psz)
            # page-align the per-request capacity so the gathered
            # attention views keep one fixed [*, max_pages*psz] width
            cache_len = max_pages * psz
            if n_pages is None:
                # default: full backing (every slot can reach max_pages
                # simultaneously — no preemption) + the null page. Pass
                # a smaller n_pages to oversubscribe the heap.
                n_pages = n_slots * max_pages + 1
            self.pool = PagedKVPool.create(runtime, n_pages, psz, n_slots,
                                           max_pages)
            self._npb = runtime.block_size // psz   # pages per block
        elif layout == "slot":
            self.pool = KVSlotPool.create(runtime, n_slots, cache_len)
        else:
            raise ValueError(f"unknown kv_layout={layout!r}; expected "
                             f"'slot' or 'paged'")
        if prefix_cache and not self.paged:
            raise ValueError("prefix_cache requires kv_layout='paged' "
                             "(the slot layout has no shareable pages)")
        # memory tiering (serving/kv_tier.py): a host swap tier of
        # swap_pages pages behind the device heap. Under page pressure
        # the scheduler swaps out the youngest request's exclusive
        # pages (device->host, request PARKED keeping its slot) before
        # resorting to preempt-and-recompute; parked requests resume
        # oldest-first as pages free up, with bit-identical KV bytes.
        if swap_pages and not self.paged:
            raise ValueError("swap_pages requires kv_layout='paged' "
                             "(the slot layout has no swappable pages)")
        self.host_tier = (HostKVTier(swap_pages) if swap_pages else None)
        if self.host_tier is not None:
            self.pool.attach_host_tier(self.host_tier)
        self.parked: Dict[int, _ActiveState] = {}   # slot -> state
        self.n_swap_outs = 0          # park events (requests swapped out)
        self.n_swap_ins = 0           # resume events
        self.prefix_cache = bool(prefix_cache)
        self.prefix_index = (PrefixIndex(self.pool) if self.prefix_cache
                             else None)
        # prefix-sharing counters (hit accounting lives here — the
        # index counts lookups/publishes, the pool counts mappings)
        self.n_prefix_hits = 0        # admissions that skipped >=1 block
        self.n_shared_blocks = 0      # prompt blocks never prefilled
        self.n_slots = n_slots
        self.cache_len = cache_len
        # max width of the batched prefill entry: up to this many
        # requests advance one block per tick in ONE jitted call. Must
        # not exceed n_slots (pad rows need distinct unused slot ids).
        self.prefill_batch = max(1, min(prefill_batch, n_slots))
        # width buckets (powers of two up to prefill_batch): each tick
        # picks the smallest bucket holding its live rows, so a thin
        # backlog never pays the full P-wide padded batch. One
        # executable per bucket, all pre-compiled by warmup().
        self.prefill_widths = []
        w = 1
        while w < self.prefill_batch:
            self.prefill_widths.append(w)
            w *= 2
        self.prefill_widths.append(self.prefill_batch)
        # registered SparsityPlans (effort tiers) — plan identity joins
        # the prefill batching key next to is_dense, and decode carries
        # per-slot plan_ids (one executable; see runtime)
        self.plans = tuple(getattr(runtime, "plans", ()) or ())
        self.plan_index = dict(getattr(runtime, "plan_index", {}) or {})
        n_plans = max(len(self.plans), 1)
        self.plan_prefill_blocks = np.zeros(n_plans, np.int64)
        self.plan_decode_tokens = np.zeros(n_plans, np.int64)
        # self-speculative decoding (serving/speculative.py): drafts
        # come from the SAME weights under the named (sparser) plan, so
        # both executables are already registered. Per-VERIFY-plan
        # draft index, clamped so a degraded request's draft is never
        # DENSER than its verify plan (flop_frac orders the tiers).
        self.speculative = speculative
        self.n_spec_ticks = 0
        self.spec_row_ticks = np.zeros(n_plans, np.int64)
        self.spec_drafted = np.zeros(n_plans, np.int64)
        self.spec_accepted = np.zeros(n_plans, np.int64)
        self.spec_emitted = np.zeros(n_plans, np.int64)
        if speculative is not None and speculative.k > 0:
            if speculative.draft not in self.plan_index:
                raise ValueError(
                    f"speculative draft plan {speculative.draft!r} is not "
                    f"a registered SparsityPlan "
                    f"(have {sorted(self.plan_index)}); pass plans= to "
                    f"make_runtime / serve.py --effort")
            di = self.plan_index[speculative.draft]
            dff = self.plans[di].flop_frac()
            self._draft_plan_for = np.array(
                [di if self.plans[i].flop_frac() >= dff else i
                 for i in range(len(self.plans))], np.int32)
        else:
            self._draft_plan_for = np.zeros(n_plans, np.int32)
        # overload-resilience layer: admission controller (deadline
        # shedding + hysteretic tier degradation, serving/admission.py)
        # and deterministic fault injector (serving/faults.py). The
        # injector wraps the clock so injected slow ticks advance
        # observed time for the deadline/timeout paths.
        self.admission = admission
        self.faults = faults
        if faults is not None:
            clock = faults.wrap_clock(clock)
        self.clock = clock
        # idle wait between stream arrivals (drive_stream). Injected
        # alongside `clock` so a fake/simulated clock brings a matching
        # sleep: waiting on wall time for a delta measured on a fake
        # clock would block a deterministic stream test on real seconds.
        self.sleep = sleep
        self.seed = seed
        self.queue: deque[Request] = deque()
        self.active: Dict[int, _ActiveState] = {}   # slot -> state
        self.finished: Dict[int, RequestOutput] = {}
        self._admit_seq = 0
        # tick counters (benchmarks / tests)
        self.n_ticks = 0
        self.n_prefill_blocks = 0
        self.n_prefill_ticks = 0
        self.n_decode_steps = 0
        self.n_eos_stops = 0
        self.n_preemptions = 0
        # robustness counters (terminal statuses + degradation)
        self.n_shed = 0
        self.n_timed_out = 0
        self.n_cancelled = 0
        self.n_degraded = 0
        # stall watchdog: raise after this many consecutive ticks with
        # no observable progress while work is pending (see tick())
        self.stall_ticks = stall_ticks
        self._stall_count = 0
        self._last_sig = None
        self._total_emitted = 0
        # fastest prefill tick ever observed — the LOWER BOUND the
        # predictive deadline shed is proved against (None until a
        # nonzero duration is measured; fake clocks never shed
        # predictively)
        self._min_prefill_tick_s: Optional[float] = None

    # --------------------------------------------------------- submit

    def submit(self, req: Request) -> None:
        """Validate and enqueue. Malformed requests (empty prompt,
        max_new < 1, unknown effort) are CALLER bugs and still raise;
        a well-formed request the pool can never hold, or that provably
        cannot meet its deadline, is SHED instead — it finishes
        immediately with status="shed" and a reason, so one oversized
        request in a stream no longer kills the whole replay (and can
        never livelock admission waiting for pages that cannot exist)."""
        if not req.prompt:
            raise ValueError(f"request {req.rid} has an empty prompt")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1 "
                             f"(the first token is sampled from prefill "
                             f"logits and always emitted)")
        if req.speculate is not None and req.speculate < 0:
            raise ValueError(f"request {req.rid}: speculate must be >= 0, "
                             f"got {req.speculate}")
        if req.effort is not None and req.effort not in self.plan_index:
            raise ValueError(
                f"request {req.rid}: effort {req.effort!r} is not a "
                f"registered SparsityPlan "
                f"(have {sorted(self.plan_index)}); pass plans= to "
                f"make_runtime / serve.py --effort")
        if req.arrival_time is None:
            req.arrival_time = self.clock()
        need = max(self._n_blocks(req) * self.runtime.block_size,
                   len(req.prompt) + req.max_new)
        if not self.pool.fits(need):
            if self.paged:
                reason = (
                    f"needs {need} cache positions "
                    f"({self.pool.pages_for(need)} pages) but the paged "
                    f"pool backs at most {self.pool.n_pages - 1} usable "
                    f"pages of {self.pool.page_size} tokens per request "
                    f"(table width {self.pool.max_pages} pages) — grow "
                    f"n_pages/--pool-pages or cache_len")
            else:
                reason = (f"needs {need} cache positions but the pool's "
                          f"cache_len is {self.cache_len}")
            self._finish_queued(req, "shed", reason)
            return
        reason = AdmissionController.shed_reason(
            req, now=self.clock(), n_blocks=self._n_unshared_blocks(req),
            min_block_s=self._min_prefill_tick_s)
        if reason is not None:
            self._finish_queued(req, "shed", reason)
            return
        self.queue.append(req)

    def _n_blocks(self, req: Request) -> int:
        N = self.runtime.block_size
        return -(-len(req.prompt) // N)

    def _n_unshared_blocks(self, req: Request) -> int:
        """Prompt blocks this request would actually RUN if admitted
        right now — with the prefix cache on, blocks covered by the
        currently-cached chain are subtracted (the shed bound charges
        only unshared work). Quasi-provable rather than provable: the
        cached chain can only GROW a request's coverage while it queues
        (evictions only fire under pressure, in which case the request
        was going to wait anyway), so shedding against today's coverage
        never sheds a request that sharing would have saved."""
        n_blocks = self._n_blocks(req)
        if self.prefix_index is None:
            return n_blocks
        plan_idx = (req.assigned_plan_idx
                    if req.assigned_plan_idx is not None
                    else self.plan_index.get(req.effort, 0))
        matched = self.prefix_index.lookup(
            self._plan_name(plan_idx), self._page_keys(req), record=False)
        return n_blocks - min(len(matched) // self._npb, n_blocks - 1)

    def _page_keys(self, req: Request) -> List[tuple]:
        """Page-aligned token tuples of the SHAREABLE prompt prefix
        (everything before the last block — a request's final prompt
        block is never shared: its pages hold the partial fill and the
        decode-adjacent state)."""
        return PrefixIndex.page_keys(
            req.prompt, self.pool.page_size,
            (self._n_blocks(req) - 1) * self._npb)

    # ----------------------------------------------------------- tick

    @property
    def drained(self) -> bool:
        return not self.queue and not self.active and not self.parked

    def tick(self) -> int:
        """One scheduling step; returns the number of tokens emitted.

        Order of the overload valves: fault injection (chaos runs),
        admission-pressure observation, deadline expiry (frees
        resources BEFORE admission so an expired request's pages seat
        the next one), swap-in resume (parked requests claim freed
        pages BEFORE new admissions — they are older than anything
        queued), admit (with degradation), prefill, decode, and
        finally the stall watchdog — `stall_ticks` consecutive ticks
        with pending work and no observable progress raise
        `SchedulerStallError` with a full state dump."""
        self.n_ticks += 1
        if self.faults is not None:
            self.faults.on_tick(self)
        if self.admission is not None:
            self.admission.observe(len(self.queue), self._free_frac())
        self._expire_deadlines()
        self._resume_swapped()
        self._admit()
        t0 = self.clock()
        before = self.n_prefill_ticks
        emitted = self._prefill_blocks()
        if self.n_prefill_ticks > before:
            dt = self.clock() - t0
            # fastest observed prefill tick: the provable lower bound
            # behind predictive deadline shedding (fake clocks measure
            # 0.0 and therefore never enable it)
            if dt > 0 and (self._min_prefill_tick_s is None
                           or dt < self._min_prefill_tick_s):
                self._min_prefill_tick_s = dt
        # sample occupancy/stranding stats mid-tick too: short requests
        # can admit, prefill, decode, and release within ONE tick, and
        # the peak the kv_memory benchmark compares is the post-prefill
        # moment, not the post-release one
        self.pool.note_tick()
        emitted += self._decode_all()
        self.pool.note_tick()
        self._total_emitted += emitted
        self._watchdog()
        return emitted

    def _free_frac(self) -> float:
        """Free-resource fraction for the admission watermarks:
        available pages of the paged heap (truly free PLUS reclaimable
        cached-idle pages — they surrender to eviction on demand, so
        counting them as pressure would make a popular cached prefix
        read as an overloaded heap), free slots of the slot pool. With
        a host tier attached its free capacity counts too: swap-out
        absorbs pressure that would otherwise preempt, so admission
        watermarks gate on BOTH tiers' headroom."""
        if self.paged:
            usable = self.pool.n_pages - 1
            avail = self.pool.n_available_pages
            if self.host_tier is not None:
                usable += self.host_tier.capacity_pages
                avail += self.host_tier.n_free
            return avail / usable if usable else 0.0
        return self.pool.n_free / self.n_slots

    def _watchdog(self) -> None:
        if self.drained:
            self._stall_count = 0
            self._last_sig = None
            return
        # every way the scheduler can make progress moves one of these:
        # admissions/finishes change the queue/finished lengths, prefill
        # moves n_prefill_blocks, decode moves _total_emitted, and
        # preemption/swap churn moves n_preemptions/n_swap_outs/ins
        sig = (len(self.queue), len(self.active), len(self.finished),
               self.n_prefill_blocks, self.n_preemptions,
               self.n_swap_outs, self.n_swap_ins,
               self._total_emitted)
        if sig == self._last_sig:
            self._stall_count += 1
        else:
            self._stall_count = 0
            self._last_sig = sig
        if self._stall_count >= self.stall_ticks:
            state = self.dump_state()
            raise SchedulerStallError(
                f"scheduler stalled: no progress for {self._stall_count} "
                f"consecutive ticks with work pending\n"
                f"{pprint.pformat(state, width=78)}", state)

    def run(self, max_ticks: int = 1_000_000) -> Dict[int, RequestOutput]:
        """Drive ticks until every submitted request has finished (any
        terminal status). Raises SchedulerStallError — with a full
        scheduler-state dump — instead of spinning when ticks stop
        making progress."""
        for _ in range(max_ticks):
            if self.drained:
                break
            self.tick()
        if not self.drained:
            state = self.dump_state()
            raise SchedulerStallError(
                f"scheduler not drained after {max_ticks} ticks\n"
                f"{pprint.pformat(state, width=78)}", state)
        if self.faults is not None:
            self.faults.finalize(self)
        return self.finished

    # ----------------------------------------------------- state dump

    def dump_state(self) -> dict:
        """Full host-side scheduler state (watchdog raises carry it;
        also handy interactively). Device buffers are summarized, not
        dumped."""
        pool_state = {
            "layout": self.kv_layout,
            "n_free_slots": self.pool.n_free,
            "acquires": self.pool.total_acquires,
            "releases": self.pool.total_releases,
        }
        if self.paged:
            pool_state.update(
                n_free_pages=self.pool.n_free_pages,
                n_reclaimable_pages=self.pool.n_reclaimable,
                usable_pages=self.pool.n_pages - 1,
                pages_in_use=self.pool.n_pages_in_use)
        if self.host_tier is not None:
            pool_state["host_tier"] = self.host_tier.stats()
            pool_state["n_swapped_pages"] = self.pool.n_swapped_pages
        if self.prefix_index is not None:
            pool_state["prefix_index"] = self.prefix_stats()
        return {
            "tick": self.n_ticks,
            "queue": [
                {"rid": r.rid, "prompt_len": len(r.prompt),
                 "blocks": self._n_blocks(r), "effort": r.effort,
                 "deadline_ms": r.deadline_ms}
                for r in self.queue],
            "active": [
                {"rid": st.req.rid, "slot": st.slot, "seq": st.seq,
                 "phase": st.phase, "blocks_done": st.blocks_done,
                 "n_blocks": st.n_blocks, "pos": st.pos,
                 "out_tokens": len(st.out),
                 "plan": self._plan_name(st.plan_idx)}
                for st in sorted(self.active.values(),
                                 key=lambda s: s.seq)],
            "parked": [
                {"rid": st.req.rid, "slot": st.slot, "seq": st.seq,
                 "phase": st.phase, "pos": st.pos,
                 "out_tokens": len(st.out)}
                for st in sorted(self.parked.values(),
                                 key=lambda s: s.seq)],
            "pool": pool_state,
            "counters": {
                "finished": len(self.finished),
                "emitted": self._total_emitted,
                "prefill_blocks": self.n_prefill_blocks,
                "decode_steps": self.n_decode_steps,
                "spec_ticks": self.n_spec_ticks,
                "preemptions": self.n_preemptions,
                "swap_outs": self.n_swap_outs,
                "swap_ins": self.n_swap_ins,
                "shed": self.n_shed, "timed_out": self.n_timed_out,
                "cancelled": self.n_cancelled,
                "degraded": self.n_degraded,
            },
            "admission": (self.admission.stats()
                          if self.admission is not None else None),
            "faults": (self.faults.stats()
                       if self.faults is not None else None),
        }

    def warmup(self) -> dict:
        """Compile every serving executable by running one throwaway
        request through this scheduler's own pool (no second KV
        allocation) — the single-block prefill + decode pair — and then
        one ALL-INACTIVE `prefill_blocks` call per batched width bucket
        (inactive rows scatter back their own gathered KV, so the pool
        is untouched), then reset counters/stats. After this, a
        churning request mix never compiles again. Returns the
        post-warmup compile counts."""
        if self.active or self.queue or self.finished:
            raise RuntimeError("warmup() must run before real traffic")
        # chaos must not perturb compilation: the injector is suspended
        # for the duration of warmup and re-attached after
        faults, self.faults = self.faults, None
        N = self.runtime.block_size
        self.submit(Request(rid=-1, prompt=[1] * min(N, self.cache_len - 2),
                            max_new=2))
        self.run()
        # one executable per (plan, width bucket): every registered
        # effort tier is pre-compiled, so a stream MIXING tiers stays on
        # the zero-recompilation contract. Decode needs no per-plan
        # pass — the plan tuple is closed over and traced plan_ids
        # select per-row counts, so the throwaway request's single
        # decode step compiled the one executable.
        for i, plan in enumerate(self.plans or (None,)):
            for w in self.prefill_widths:
                if w == 1:
                    if i == 0:
                        continue  # compiled by the throwaway request
                    if not self.paged:
                        # width-1 slot bucket is the single-block entry;
                        # slot 0 is free during warmup, its KV garbage
                        # is overwritten by any real prefill from pos 0
                        self.pool.cache, _ = self.runtime.prefill_block(
                            self.pool.cache, np.zeros((1, N), np.int32),
                            0, 0, False, 1, plan=plan)
                        continue
                if self.paged:
                    # all-inactive rows carry all-null page tables: their
                    # writes are self-copies of the reserved null page
                    self.pool.cache, _ = self.runtime.prefill_blocks_paged(
                        self.pool.cache, np.zeros((w, N), np.int32),
                        np.zeros((w, self.pool.max_pages), np.int32),
                        np.zeros(w, np.int32), np.zeros(w, bool),
                        np.ones(w, np.int32), np.zeros(w, bool),
                        plan=plan)
                else:
                    self.pool.cache, _ = self.runtime.prefill_blocks(
                        self.pool.cache, np.zeros((w, N), np.int32),
                        np.arange(w, dtype=np.int32), np.zeros(w, np.int32),
                        np.zeros(w, bool), np.ones(w, np.int32),
                        np.zeros(w, bool), plan=plan)
        if self.speculative is not None and self.speculative.k > 0:
            # pre-compile the speculative protocol entries with an all-
            # inactive call each (masked writes are self-copies — slot
            # KV untouched, paged all-null tables sink into the null
            # page). The throwaway request already compiled the chunk
            # entry but its max_new=2 never drafts (the bonus token
            # claims the last emission), so the draft entry needs this.
            kd = self.speculative.k
            z = np.zeros(self.n_slots, np.int32)
            f = np.zeros(self.n_slots, bool)
            ch = np.zeros((self.n_slots, kd + 1), np.int32)
            if self.paged:
                _, self.pool.cache = self.runtime.draft_steps_paged(
                    self.pool.cache, z, self.pool.page_table, z, f, z, kd)
                _, _, self.pool.cache = self.runtime.verify_chunk_paged(
                    self.pool.cache, ch, self.pool.page_table, z, f, z + 1)
            else:
                _, self.pool.cache = self.runtime.draft_steps(
                    self.pool.cache, z, z, f, z, kd)
                _, _, self.pool.cache = self.runtime.verify_chunk(
                    self.pool.cache, ch, z, f, z + 1)
        self.finished.clear()
        self._admit_seq = 0
        self.n_ticks = self.n_prefill_blocks = self.n_decode_steps = 0
        self.n_prefill_ticks = self.n_eos_stops = 0
        self.n_preemptions = 0
        self.n_shed = self.n_timed_out = self.n_cancelled = 0
        self.n_degraded = 0
        self._stall_count = 0
        self._last_sig = None
        self._total_emitted = 0
        if self.admission is not None:
            self.admission.reset()
        self.faults = faults
        self.plan_prefill_blocks[:] = 0
        self.plan_decode_tokens[:] = 0
        self.n_spec_ticks = 0
        self.spec_row_ticks[:] = 0
        self.spec_drafted[:] = 0
        self.spec_accepted[:] = 0
        self.spec_emitted[:] = 0
        self.pool.total_acquires = self.pool.total_releases = 0
        self.pool.max_in_use = 0
        self.pool.stranded_tokens_at_peak = 0
        if self.paged:
            self.pool.total_page_allocs = self.pool.total_page_frees = 0
            self.pool.max_pages_in_use = 0
        if self.paged and self.host_tier is not None:
            # pre-compile both swap byte-movers with a null round trip:
            # read the null page's zeros, write them straight back —
            # the pool is untouched and every later swap batch (any
            # page count, chunked to width _npb) reuses these two
            # executables
            ids = np.zeros(self._npb, np.int32)
            # payload crosses to HOST numpy exactly like a real swap:
            # the jit cache keys device arrays and numpy arrays
            # differently, so warming with a device payload would leave
            # the first real swap-in to compile a second executable
            payload = jax.tree.map(
                np.asarray, self.runtime.read_pages(self.pool.cache, ids))
            self.pool.cache = self.runtime.write_pages(
                self.pool.cache, ids, payload)
            self.n_swap_outs = self.n_swap_ins = 0
            self.pool.total_pages_swapped_out = 0
            self.pool.total_pages_swapped_in = 0
            tier = self.host_tier
            tier.total_host_puts = tier.total_host_frees = 0
            tier.peak_used = 0
        if self.prefix_index is not None:
            # pre-compile the COW copy entry (all-null self-copy: page
            # 0 copied onto itself), then drop the throwaway request's
            # published blocks and zero the sharing stats — real
            # traffic starts from an empty, fully-counted cache
            z = np.zeros(self._npb, np.int32)
            self.pool.cache = self.runtime.copy_pages(self.pool.cache,
                                                      z, z)
            self.prefix_index.clear()
            self.prefix_index.n_lookups = self.prefix_index.n_hits = 0
            self.prefix_index.n_published = 0
            self.prefix_index.n_evictions = 0
            self.n_prefix_hits = self.n_shared_blocks = 0
            self.pool.total_page_shares = 0
            self.pool.n_cow_pages = 0
            self.pool.total_page_allocs = self.pool.total_page_frees = 0
        return self.runtime.compile_counts()

    # ------------------------------------------------------- internals

    def _peek_plan_idx(self, req: Request) -> int:
        """The plan this request would be admitted under RIGHT NOW
        (pinned index if re-admitting, else the current degradation
        level applied to its effort tier). Pure — safe to call before
        the admission gate; the n_degraded counter moves only when the
        request is actually seated."""
        if req.assigned_plan_idx is not None:
            return req.assigned_plan_idx
        plan_idx = self.plan_index.get(req.effort, 0)
        if self.admission is not None and self.plans:
            plan_idx = self.admission.degraded_plan(plan_idx)
        return plan_idx

    def _admit(self) -> None:
        while self.queue:
            shared: List[int] = []
            keys: Optional[List[tuple]] = None
            if self.paged:
                # paged admission gates on available PAGES: seat a
                # request only when the heap can back its whole UNSHARED
                # prompt footprint on top of what already-seated prefills
                # are still owed (allocation is lazy, so the free count
                # alone would let a burst over-admit and thrash
                # re-prefill). Decode growth past the prompt is
                # deliberately NOT reserved — that would re-create the
                # slot pool's worst-case reservation and its stranded
                # bytes; the preemption path absorbs it.
                req0 = self.queue[0]
                n_blocks = self._n_blocks(req0)
                if self.prefix_index is not None:
                    # record=False: the same head can be re-probed for
                    # many gated ticks — stats count admissions below
                    keys = self._page_keys(req0)
                    shared = self.prefix_index.lookup(
                        self._plan_name(self._peek_plan_idx(req0)), keys,
                        record=False)
                owed = sum(
                    max(s.n_blocks * self._npb
                        - int(self.pool.allocated[s.slot]), 0)
                    for s in self.active.values() if s.phase == "prefill")
                # parked requests resume BEFORE admission and need
                # exactly their swapped page counts back — charge the
                # gate so new admissions don't strand them parked
                owed += self.pool.n_swapped_pages
                # whole blocks the shared chain covers are never
                # prefilled; a partial tail block still re-runs (its
                # tail pages COW-detach), so it is charged in full
                m_aligned = len(shared) - len(shared) % self._npb
                need = n_blocks * self._npb - m_aligned
                # matched refcount-zero pages sit on the reclaimable
                # list, so n_available_pages counts them as capacity —
                # but mapping them consumes that capacity, so charge
                # them out of the gate (or a full-but-cached heap would
                # admit work it cannot back)
                matched_idle = sum(
                    1 for p in shared if self.pool.refcount[p] == 0)
                avail = self.pool.n_available_pages - matched_idle
                if avail - owed < need:
                    return
            slot = self.pool.acquire()
            if slot is None:
                return
            req = self.queue.popleft()
            if req.assigned_plan_idx is not None:
                # re-admission after preemption: the degradation
                # decision was made at FIRST admission and sticks, so
                # preemption stays output-transparent even if the
                # controller's level moved meanwhile
                plan_idx = req.assigned_plan_idx
            else:
                plan_idx = self._peek_plan_idx(req)
                if plan_idx != self.plan_index.get(req.effort, 0):
                    self.n_degraded += 1
                req.assigned_plan_idx = plan_idx
            st = _ActiveState(
                req=req, slot=slot, seq=self._admit_seq,
                n_blocks=self._n_blocks(req),
                plan_idx=plan_idx,
                # rid folded to uint32: seed sequences reject negative
                # entries (the warmup throwaway request carries rid=-1)
                rng=np.random.default_rng(
                    (self.seed, req.rid % (1 << 32))),
                page_keys=keys)
            self.active[slot] = st
            self._admit_seq += 1
            if self.prefix_index is not None:
                self.prefix_index.n_lookups += 1
                if shared:
                    self.prefix_index.n_hits += 1
                    self._map_prefix(st, shared)

    def _map_prefix(self, st: _ActiveState, shared: List[int]) -> None:
        """Seat an admitted request on its matched prefix chain: map
        the shared pages read-only, copy-on-write the partial tail of
        the restart block, and fast-forward blocks_done past the fully-
        covered blocks — those prompt blocks never run (the TTFT win)."""
        N = self.runtime.block_size
        self.pool.share(st.slot, shared)
        tail = len(shared) % self._npb
        if tail:
            self._cow_tail(st, tail)
        start = int(self.pool.allocated[st.slot]) // self._npb
        st.blocks_done = start
        self.pool.lengths[st.slot] = start * N
        if start > 0:
            self.n_prefix_hits += 1
            self.n_shared_blocks += start

    def _cow_tail(self, st: _ActiveState, tail: int) -> None:
        """Detach the last `tail` shared pages (a chain that ends mid-
        block: partial subtree eviction is the only producer — publishes
        are whole-block). The restart block's prefill scatters over ALL
        its pages, so keeping them shared would write pages other
        requests read; COW gives the writer private bit-identical
        copies instead, preserving "writes only touch exclusively-owned
        pages" without special cases. Dry-heap fallback: unmap the rest
        of the tail (those positions simply re-prefill)."""
        pool = self.pool
        base = int(pool.allocated[st.slot])
        srcs: List[int] = []
        dsts: List[int] = []
        for j in range(base - tail, base):
            while (pool.n_free_pages == 0
                   and self.prefix_index.evict_lru()):
                pass
            res = pool.cow(st.slot, j)
            if res is None:
                pool.unmap_tail(st.slot, base - j)
                break
            srcs.append(res[0])
            dsts.append(res[1])
        if srcs:
            # one fixed-width jitted device copy per admission: pad
            # with 0 -> 0 null self-copies so every COW count hits the
            # single pre-warmed executable
            src = np.zeros(self._npb, np.int32)
            dst = np.zeros(self._npb, np.int32)
            src[:len(srcs)] = srcs
            dst[:len(dsts)] = dsts
            self.pool.cache = self.runtime.copy_pages(
                self.pool.cache, src, dst)

    # ------------------------------------------- lifecycle: cancel/expiry

    def _plan_name(self, plan_idx: int) -> Optional[str]:
        return self.plans[plan_idx].name if self.plans else None

    def _count_status(self, status: str) -> None:
        if status == "shed":
            self.n_shed += 1
        elif status == "timed_out":
            self.n_timed_out += 1
        elif status == "cancelled":
            self.n_cancelled += 1

    def _finish_queued(self, req: Request, status: str,
                       reason: str) -> None:
        """Terminal state for a request that never held resources
        (shed at submit, expired/cancelled while queued)."""
        now = self.clock()
        arrival = (req.arrival_time if req.arrival_time is not None
                   else now)
        self.finished[req.rid] = RequestOutput(
            rid=req.rid, tokens=[], prompt_len=len(req.prompt),
            ttft_seconds=None, finish_seconds=now - arrival,
            status=status, reason=reason, effort=None)
        self._count_status(status)

    def _finish_abnormal(self, st: _ActiveState, status: str,
                         reason: str) -> None:
        """Terminal state for an ACTIVE request (timeout/cancel):
        records whatever tokens were produced and frees the slot and —
        paged — every page, idempotently (the pool guards double
        release)."""
        now = self.clock()
        self.finished[st.req.rid] = RequestOutput(
            rid=st.req.rid, tokens=list(st.out),
            prompt_len=len(st.req.prompt),
            ttft_seconds=(st.first_token_time - st.req.arrival_time
                          if st.first_token_time is not None else None),
            finish_seconds=now - st.req.arrival_time,
            status=status, reason=reason,
            effort=self._plan_name(st.plan_idx))
        if self.active.get(st.slot) is st:
            del self.active[st.slot]
        elif self.parked.get(st.slot) is st:
            del self.parked[st.slot]
        self.pool.release(st.slot)   # frees host-tier pages too if parked
        self._count_status(status)

    def cancel(self, rid: int, reason: str = "client cancelled") -> bool:
        """Mid-flight cancellation: finish `rid` with
        status="cancelled" wherever it currently lives — still queued
        (zero work done) or active (slot/pages freed idempotently,
        partial tokens kept). Returns False when the request is
        unknown or already finished (cancelling twice is a no-op)."""
        for r in self.queue:
            if r.rid == rid:
                self.queue.remove(r)
                self._finish_queued(r, "cancelled", reason)
                return True
        for st in list(self.active.values()) + list(self.parked.values()):
            if st.req.rid == rid:
                self._finish_abnormal(st, "cancelled", reason)
                return True
        return False

    def _expire_deadlines(self) -> None:
        """Enforce per-request deadlines (tick-entry hook): expired
        requests finish with status="timed_out" and free their
        resources immediately — BEFORE admission, so the pages a dead
        request held can seat the next queued one on the same tick."""
        now = self.clock()

        def expired(req: Request, phase: str) -> Optional[str]:
            waited = now - req.arrival_time
            if (req.deadline_ms is not None
                    and waited >= req.deadline_ms / 1e3):
                return (f"end-to-end deadline {req.deadline_ms:g} ms "
                        f"expired ({phase})")
            if (req.ttft_deadline_ms is not None and phase != "decode"
                    and waited >= req.ttft_deadline_ms / 1e3):
                return (f"ttft deadline {req.ttft_deadline_ms:g} ms "
                        f"expired ({phase})")
            return None

        for r in [r for r in self.queue
                  if r.deadline_ms is not None
                  or r.ttft_deadline_ms is not None]:
            reason = expired(r, "queued")
            if reason is not None:
                self.queue.remove(r)
                self._finish_queued(r, "timed_out", reason)
        for st in list(self.active.values()):
            if self.active.get(st.slot) is not st:
                continue
            reason = expired(st.req, st.phase)
            if reason is not None:
                self._finish_abnormal(st, "timed_out", reason)
        # parked (swapped-out) requests age on the same deadlines: an
        # expired one frees BOTH tiers' pages right here
        for st in list(self.parked.values()):
            if self.parked.get(st.slot) is not st:
                continue
            reason = expired(st.req, st.phase)
            if reason is not None:
                self._finish_abnormal(st, "timed_out", reason)

    # ---------------------------------------------- paged page pressure

    def _preempt(self, st: _ActiveState) -> None:
        """Evict a request: release its slot (and — paged — its pages),
        requeue it at the FRONT of the queue for re-prefill from
        scratch (preempted requests are older than anything still
        queued). Preemption is output-transparent: greedy decode is
        deterministic, the request re-admits under its PINNED plan
        (assigned_plan_idx), and temperature sampling replays its own
        (seed, rid) RNG stream on re-admission — only TTFT/latency
        suffer. Layout-independent (the FaultInjector forces it on the
        slot layout too). Parked (swapped-out) victims release their
        host-tier pages too (pool.release covers both tiers)."""
        if self.active.get(st.slot) is st:
            del self.active[st.slot]
        elif self.parked.get(st.slot) is st:
            del self.parked[st.slot]
        self.pool.release(st.slot)
        self.queue.appendleft(st.req)
        self.n_preemptions += 1

    def _swap_out(self, st: _ActiveState) -> bool:
        """Park `st`: move its exclusive (refcount-1, uncached) pages'
        payloads to the host tier through the fixed-width jitted
        `read_pages` entry, free the device pages, and remove it from
        the active set — it keeps its slot (and its shared/cached
        mappings, which are swap-exempt) and resumes with bit-identical
        KV bytes once the heap recovers. Returns False — changing
        nothing — when tiering is off, the tier is full, or `st` has no
        exclusive pages to move (the caller then falls back to true
        preemption)."""
        tier = self.host_tier
        if tier is None:
            return False
        swappable = self.pool.swappable_pages(st.slot)
        if not swappable or not tier.can_hold(len(swappable)):
            return False
        js = [j for j, _ in swappable]
        pages = [p for _, p in swappable]
        hid = tier.put(self._read_page_payloads(pages))
        self.pool.swap_out_commit(st.slot, js, hid)
        del self.active[st.slot]
        self.parked[st.slot] = st
        self.n_swap_outs += 1
        return True

    def _read_page_payloads(self, pages: List[int]) -> list:
        """Device->host copy of `pages` payloads, one per-page numpy
        pytree each, through the single pre-warmed fixed-width
        `read_pages` executable (chunks of _npb page ids, padded with
        the null page — harmless extra reads)."""
        W = self._npb
        payloads = []
        for i in range(0, len(pages), W):
            chunk = pages[i:i + W]
            ids = np.zeros(W, np.int32)
            ids[:len(chunk)] = chunk
            got = jax.tree.map(np.asarray,
                               self.runtime.read_pages(self.pool.cache,
                                                       ids))
            for j in range(len(chunk)):
                payloads.append(jax.tree.map(lambda a: a[:, j].copy(),
                                             got))
        return payloads

    def _write_page_payloads(self, pages: List[int],
                             payloads: list) -> None:
        """Host->device scatter of swap-in payloads onto freshly
        allocated `pages`, through the single pre-warmed fixed-width
        `write_pages` executable (padding pairs page 0 with an all-zero
        payload — rewriting the null page's own bytes)."""
        W = self._npb
        zero = jax.tree.map(np.zeros_like, payloads[0])
        for i in range(0, len(pages), W):
            chunk = pages[i:i + W]
            ids = np.zeros(W, np.int32)
            ids[:len(chunk)] = chunk
            group = list(payloads[i:i + W])
            group += [zero] * (W - len(chunk))
            stacked = jax.tree.map(lambda *xs: np.stack(xs, axis=1),
                                   *group)
            self.pool.cache = self.runtime.write_pages(self.pool.cache,
                                                       ids, stacked)

    def _resume_swapped(self) -> None:
        """Swap parked requests back in, OLDEST first, before any new
        admission (a parked request predates everything still queued).
        Each resume allocates fresh device pages (evicting cached-idle
        prefixes if that unblocks it), scatters the host payloads back,
        and releases the host pages. Stops at the first parked request
        that cannot be re-backed this tick — younger parked requests
        never jump an older one."""
        if not self.parked:
            return
        for st in sorted(self.parked.values(), key=lambda s: s.seq):
            while True:
                res = self.pool.swap_in_alloc(st.slot)
                if res is not None:
                    break
                if (self.prefix_index is not None
                        and self.prefix_index.evict_lru()):
                    continue
                return          # heap still dry: retry next tick
            hid, _js, pages = res
            self._write_page_payloads(pages, self.host_tier.get(hid))
            self.pool.swap_in_commit(st.slot)
            del self.parked[st.slot]
            self.active[st.slot] = st
            self.n_swap_ins += 1

    def _ensure_pages(self, st: _ActiveState, n_total: int) -> bool:
        """Grow st's page table to n_total pages. While the free heap
        is dry, the pressure valves fire cheapest-first: (1) evict
        cached-but-unreferenced prefixes (LRU, a whole index subtree
        per victim — reclaiming cold cache costs nothing live); (2)
        SWAP OUT the youngest strictly-younger active request's
        exclusive pages to the host tier (its work is preserved — it
        parks and resumes with bit-identical KV); (3) only when the
        host tier is full or useless, PREEMPT that victim outright
        (discard-and-recompute); (4) as a last resort preempt the
        youngest strictly-younger PARKED request (frees its host pages
        and shared mappings). Never evicts older requests (the oldest
        always progresses, so any stream whose requests individually
        fit the heap drains). Returns False when st cannot be grown
        this tick (it is skipped, not evicted — retried next tick)."""
        while True:
            if self.pool.ensure(st.slot, n_total):
                return True
            if (self.prefix_index is not None
                    and self.prefix_index.evict_lru()):
                continue
            # only victims actually HOLDING pages: evicting a just-
            # admitted zero-page request frees nothing and churns
            # admission for no gain. Under sharing a victim's release
            # may free nothing PHYSICALLY (pages still read elsewhere
            # or parked cached) — its cached pages become reclaimable,
            # so the next loop iteration evicts them.
            victim = max((s for s in self.active.values()
                          if s.seq > st.seq
                          and self.pool.allocated[s.slot] > 0),
                         key=lambda s: s.seq, default=None)
            if victim is not None:
                if not self._swap_out(victim):
                    self._preempt(victim)
                continue
            parked_victim = max(
                (s for s in self.parked.values() if s.seq > st.seq),
                key=lambda s: s.seq, default=None)
            if parked_victim is None:
                return False
            self._preempt(parked_victim)

    def _plan_of(self, st: _ActiveState):
        return self.plans[st.plan_idx] if self.plans else None

    def _block_meta(self, st: _ActiveState):
        """(chunk tokens, pos0, is_dense) for a state's next block."""
        N = self.runtime.block_size
        ff = self.runtime.cfg.ff
        b = st.blocks_done
        is_dense = ((ff.dense_first_block and b == 0) or
                    (ff.dense_last_block and b == st.n_blocks - 1))
        return st.req.prompt[b * N:(b + 1) * N], b * N, is_dense

    def _finish_block(self, st: _ActiveState, logits_row) -> int:
        """Book-keeping after a state's block was processed; samples the
        first token (TTFT) when it was the final prompt block. Returns
        tokens emitted (0 or 1)."""
        N = self.runtime.block_size
        st.blocks_done += 1
        self.n_prefill_blocks += 1
        self.plan_prefill_blocks[st.plan_idx] += 1
        self.pool.lengths[st.slot] = min(st.blocks_done * N,
                                         len(st.req.prompt))
        if self.prefix_index is not None and st.blocks_done < st.n_blocks:
            # publish the just-completed block's pages (never the LAST
            # prompt block — excluded by the guard above AND by the
            # page_keys cap). First writer wins on existing nodes; a
            # COWed restart block re-publishes under the same keys and
            # is skipped there.
            b = st.blocks_done - 1
            self.prefix_index.publish(
                self._plan_name(st.plan_idx), st.page_keys,
                self.pool.page_table[st.slot],
                b * self._npb, (b + 1) * self._npb)
        if st.blocks_done < st.n_blocks:
            return 0
        tok = self._sample(logits_row(), st)
        st.first_token_time = self.clock()
        st.out.append(tok)
        st.next_token = tok
        st.pos = len(st.req.prompt)
        st.phase = "decode"
        self._maybe_finish(st)
        return 1

    def _prefill_one_block(self, st: _ActiveState, meta) -> int:
        """Original one-block-per-tick path (PR-1): one request, one
        [1, N] jitted call. Kept as the prefill_batch=1 / width-1 bucket
        the batched path is benchmarked and bit-compared against. In
        the paged layout this is the width-1 `prefill_blocks_paged`
        bucket (there is no separate single-request paged entry).
        `meta` is the state's precomputed `_block_meta` for this tick."""
        N = self.runtime.block_size
        chunk, pos0, is_dense = meta
        tok_blk = np.zeros((1, N), np.int32)
        tok_blk[0, :len(chunk)] = chunk
        plan = self._plan_of(st)
        if self.paged:
            self.pool.cache, logits = self.runtime.prefill_blocks_paged(
                self.pool.cache, tok_blk,
                self.pool.page_table[st.slot][None],
                np.array([pos0], np.int32), np.array([is_dense], bool),
                np.array([len(st.req.prompt)], np.int32),
                np.ones(1, bool), plan=plan)
            self.n_prefill_ticks += 1
            return self._finish_block(st, lambda: np.asarray(logits)[0])
        self.pool.cache, logits = self.runtime.prefill_block(
            self.pool.cache, tok_blk, st.slot, pos0, is_dense,
            len(st.req.prompt), plan=plan)
        self.n_prefill_ticks += 1
        return self._finish_block(st, lambda: np.asarray(logits))

    def _prefill_blocks(self) -> int:
        """Batched prefill: drain one block of EACH of up to
        `prefill_batch` distinct prefilling requests (oldest first) in
        one jitted `prefill_blocks` call.

        Two batch-shaping policies keep the batched tick cheap:

          * density-homogeneous batching — only rows whose next block
            needs the SAME FFN branch as the oldest request's ride in
            one call (skipped rows go next tick; the oldest is always
            included, so no starvation). The per-row is_dense vector is
            then all-equal and `ff_blocks_sparse`'s any()-gated conds
            execute exactly ONE branch — a mixed batch would pay for
            both;
          * width bucketing — the batch is padded up to the smallest
            pre-compiled width bucket (not always to P) with inactive
            rows parked on slot ids unused by this call's live rows
            (their KV writes are discarded device-side), so a backlog
            of 1-2 requests doesn't pay a P-wide padded call.
        """
        states = sorted(
            (s for s in self.active.values() if s.phase == "prefill"),
            key=lambda s: s.seq)                        # FIFO
        if not states:
            return 0
        # one _block_meta per state per tick: the same meta drives both
        # the density filter and the batch fill (re-deriving it would
        # re-slice each prompt chunk). In the paged layout each
        # candidate must also grow its page table to cover this block —
        # a dry heap preempts strictly-younger requests (which may be
        # later entries of `states`, hence the is-still-active guard);
        # a state that cannot be grown is skipped this tick, not evicted.
        batch = []
        lead_dense = None
        lead_plan = None
        for st in states:
            if len(batch) == self.prefill_batch:
                break
            if self.active.get(st.slot) is not st:
                continue                    # preempted earlier this tick
            meta = self._block_meta(st)
            if lead_dense is not None and meta[2] != lead_dense:
                continue                    # density-homogeneous batch
            if lead_plan is not None and st.plan_idx != lead_plan:
                continue                    # plan-homogeneous batch: the
                #                             plan is a jit STATIC arg, so
                #                             one call runs ONE plan
                #                             (skipped rows go next tick;
                #                             the oldest always leads)
            if self.paged and not self._ensure_pages(
                    st, (st.blocks_done + 1) * self._npb):
                continue
            if lead_dense is None:
                lead_dense = meta[2]
                lead_plan = st.plan_idx
            batch.append((st, meta))
        if not batch:
            return 0
        if len(batch) == 1:
            return self._prefill_one_block(*batch[0])   # width-1 bucket
        P = next(w for w in self.prefill_widths if w >= len(batch))
        N = self.runtime.block_size
        tokens = np.zeros((P, N), np.int32)
        slots = np.zeros(P, np.int32)
        pos0s = np.zeros(P, np.int32)
        is_dense = np.full(P, lead_dense, bool)
        lengths = np.ones(P, np.int32)
        active = np.zeros(P, bool)
        for i, (st, (chunk, pos0, _)) in enumerate(batch):
            tokens[i, :len(chunk)] = chunk
            slots[i] = st.slot
            pos0s[i] = pos0
            lengths[i] = len(st.req.prompt)
            active[i] = True
        plan = self.plans[lead_plan] if self.plans else None
        if self.paged:
            # pad rows carry all-null tables (write-sink self-copies)
            tables = np.zeros((P, self.pool.max_pages), np.int32)
            for i, (st, _) in enumerate(batch):
                tables[i] = self.pool.page_table[st.slot]
            self.pool.cache, logits = self.runtime.prefill_blocks_paged(
                self.pool.cache, tokens, tables, pos0s, is_dense,
                lengths, active, plan=plan)
        else:
            used = {st.slot for st, _ in batch}
            spare = (s for s in range(self.n_slots) if s not in used)
            for i in range(len(batch), P):
                slots[i] = next(spare)
            self.pool.cache, logits = self.runtime.prefill_blocks(
                self.pool.cache, tokens, slots, pos0s, is_dense, lengths,
                active, plan=plan)
        self.n_prefill_ticks += 1
        logits_np = [None]        # pull [P, V] to host at most once

        def row(i):
            def get():
                if logits_np[0] is None:
                    logits_np[0] = np.asarray(logits)
                return logits_np[0][i]
            return get

        return sum(self._finish_block(st, row(i))
                   for i, (st, _) in enumerate(batch))

    def _decode_all(self) -> int:
        if self.speculative is not None and self.speculative.k > 0:
            return self._decode_all_speculative()
        decoding = [s for s in self.active.values() if s.phase == "decode"]
        if self.paged:
            # each decoding row must own the page covering its write
            # position before the batched step; a dry heap preempts the
            # youngest request (possibly one of `decoding` — hence the
            # is-still-active guard). Oldest-first, so an early grow
            # never evicts an already-granted older row.
            psz = self.pool.page_size
            ready = []
            for st in sorted(decoding, key=lambda s: s.seq):
                if self.active.get(st.slot) is not st:
                    continue
                if not self._ensure_pages(st, st.pos // psz + 1):
                    continue               # stalled this tick, retried
                ready.append(st)
            decoding = ready
        if not decoding:
            return 0
        tokens = np.zeros(self.n_slots, np.int32)
        positions = np.zeros(self.n_slots, np.int32)
        active = np.zeros(self.n_slots, bool)
        plan_ids = np.zeros(self.n_slots, np.int32)
        for st in decoding:
            tokens[st.slot] = st.next_token
            positions[st.slot] = st.pos
            active[st.slot] = True
            plan_ids[st.slot] = st.plan_idx
        if self.paged:
            logits, greedy, self.pool.cache = self.runtime.decode_step_paged(
                self.pool.cache, tokens, self.pool.page_table, positions,
                active, plan_ids=plan_ids)
        else:
            logits, greedy, self.pool.cache = self.runtime.decode_step(
                self.pool.cache, tokens, positions, active,
                plan_ids=plan_ids)
        self.n_decode_steps += 1
        greedy = np.asarray(greedy)
        # logits cross to host only if someone actually samples
        logits_np = (np.asarray(logits)
                     if any(s.req.temperature > 0 for s in decoding)
                     else None)
        emitted = 0
        for st in decoding:
            tok = (int(greedy[st.slot]) if st.req.temperature <= 0
                   else self._sample(logits_np[st.slot], st))
            st.out.append(tok)
            st.next_token = tok
            st.pos += 1
            self.pool.lengths[st.slot] = st.pos
            self.plan_decode_tokens[st.plan_idx] += 1
            emitted += 1
            self._maybe_finish(st)
        return emitted

    def _spec_draft_limit(self, st: _ActiveState) -> int:
        """How many tokens this row may draft THIS tick (0 .. k):
        capped by the request's own `speculate` field, the tokens it
        can still emit (the bonus token claims one), and the cache
        positions left (the chunk writes p .. p+lim). Temperature > 0
        rows never draft — their tick must replay the exact
        non-speculative sampling step (the chunk's step-0 logits ARE
        that step's logits, so lim = 0 degenerates to it)."""
        if st.req.temperature > 0:
            return 0
        lim = self.speculative.k
        if st.req.speculate is not None:
            lim = min(lim, st.req.speculate)
        lim = min(lim, st.req.max_new - len(st.out) - 1)
        lim = min(lim, self.cache_len - 1 - st.pos)
        return max(lim, 0)

    def _decode_all_speculative(self) -> int:
        """One speculative decode tick (serving/speculative.py):
        draft `n_draft[row]` tokens per row under its draft plan, score
        all n_draft+1 positions in ONE chunk entry under its own
        (verify) plan — REWRITING the draft's KV — then emit the
        longest agreeing prefix plus the verifier's bonus token.

        Rollback of rejected writes: the slot layout just never
        advances `pool.lengths`/`st.pos` past the accepted position
        (stale bytes beyond it are rewritten before any later step can
        attend them — the mask is `kj <= position`); the paged layout
        additionally truncates tail pages past the accepted position
        (`unmap_tail`) so alloc/free accounting stays exact. Tail
        pages are always exclusively-owned decode growth: published
        prefix pages cover only pre-last-block prompt positions
        (< prompt_len <= pos), so truncation can never touch them."""
        k = self.speculative.k
        psz = self.pool.page_size if self.paged else 0
        decoding = []
        n_draft = np.zeros(self.n_slots, np.int32)
        for st in sorted((s for s in self.active.values()
                          if s.phase == "decode"), key=lambda s: s.seq):
            if self.active.get(st.slot) is not st:
                continue               # preempted by an earlier row's grow
            lim = self._spec_draft_limit(st)
            if self.paged:
                # base coverage (the committed token's page) may evict/
                # preempt exactly like the non-speculative tick; the
                # SPECULATIVE extra pages are only taken from the free
                # heap — never preempting live work just to draft
                if not self._ensure_pages(st, st.pos // psz + 1):
                    continue           # stalled this tick, retried
                while lim > 0 and not self.pool.ensure(
                        st.slot, (st.pos + lim) // psz + 1):
                    lim -= 1
            n_draft[st.slot] = lim
            decoding.append(st)
        if not decoding:
            return 0
        tokens = np.zeros(self.n_slots, np.int32)
        positions = np.zeros(self.n_slots, np.int32)
        active = np.zeros(self.n_slots, bool)
        verify_ids = np.zeros(self.n_slots, np.int32)
        draft_ids = np.zeros(self.n_slots, np.int32)
        for st in decoding:
            tokens[st.slot] = st.next_token
            positions[st.slot] = st.pos
            active[st.slot] = True
            verify_ids[st.slot] = st.plan_idx
            draft_ids[st.slot] = self._draft_plan_for[st.plan_idx]
        chunk = np.zeros((self.n_slots, k + 1), np.int32)
        chunk[:, 0] = tokens
        if int(n_draft.max()) > 0:
            if self.paged:
                drafts, self.pool.cache = self.runtime.draft_steps_paged(
                    self.pool.cache, tokens, self.pool.page_table,
                    positions, active, n_draft, k, plan_ids=draft_ids)
            else:
                drafts, self.pool.cache = self.runtime.draft_steps(
                    self.pool.cache, tokens, positions, active, n_draft,
                    k, plan_ids=draft_ids)
            chunk[:, 1:] = np.asarray(drafts)
        if self.paged:
            logits0, greedy, self.pool.cache = self.runtime.verify_chunk_paged(
                self.pool.cache, chunk, self.pool.page_table, positions,
                active, n_draft + 1, plan_ids=verify_ids)
        else:
            logits0, greedy, self.pool.cache = self.runtime.verify_chunk(
                self.pool.cache, chunk, positions, active, n_draft + 1,
                plan_ids=verify_ids)
        self.n_decode_steps += 1
        self.n_spec_ticks += 1
        greedy = np.asarray(greedy)
        logits0_np = (np.asarray(logits0)
                      if any(s.req.temperature > 0 for s in decoding)
                      else None)
        emitted = 0
        for st in decoding:
            nd = int(n_draft[st.slot])
            if st.req.temperature > 0:
                # exact non-speculative sampling tick: nd == 0, and the
                # chunk's step-0 logits are the decode_step logits
                toks = [self._sample(logits0_np[st.slot], st)]
            else:
                n_acc, accepted = accept_drafts(
                    chunk[st.slot, 1:], greedy[st.slot], nd)
                toks = [int(t) for t in accepted]
                self.spec_row_ticks[st.plan_idx] += 1
                self.spec_drafted[st.plan_idx] += nd
                self.spec_accepted[st.plan_idx] += n_acc
            row_emitted = 0
            for tok in toks:
                st.out.append(tok)
                st.next_token = tok
                st.pos += 1
                self.pool.lengths[st.slot] = st.pos
                self.plan_decode_tokens[st.plan_idx] += 1
                row_emitted += 1
                self._maybe_finish(st)
                if self.active.get(st.slot) is not st:
                    break   # EOS/max_new released the slot (and, paged,
                    #         every page) — nothing left to roll back
            if st.req.temperature <= 0:
                self.spec_emitted[st.plan_idx] += row_emitted
            emitted += row_emitted
            if self.paged and self.active.get(st.slot) is st:
                # truncate tail pages past the accepted position: pages
                # the rejected drafts grew go back to the free heap with
                # exact alloc/free accounting. st.pos >= 1 always
                # (decode starts at pos = prompt_len >= 1).
                keep = (st.pos - 1) // psz + 1
                trim = int(self.pool.allocated[st.slot]) - keep
                if trim > 0:
                    self.pool.unmap_tail(st.slot, trim)
        return emitted

    # ----------------------------------------------------- plan stats

    def sparsity_stats(self) -> dict:
        """Realized sparsity accounting (serve.py stats line): per
        registered plan, the per-layer keep fractions, analytical FFN
        FLOP fraction, and how much work (prefill blocks / decode
        tokens) actually ran under it; plus the work-weighted aggregate
        FFN FLOP fraction of the whole stream."""
        N = self.runtime.block_size
        out = {"plans": [], "aggregate_ffn_flop_frac": None,
               "aggregate_attn_flop_frac": None}
        if not self.plans:
            return out
        weights = (self.plan_prefill_blocks * N
                   + self.plan_decode_tokens).astype(np.float64)
        fracs = np.array([p.flop_frac() for p in self.plans])
        # dual-budget plans also carry an attention-block budget; plans
        # without one run dense attention (fraction 1.0)
        afracs = np.array([p.attn_flop_frac() if p.has_attn else 1.0
                           for p in self.plans])
        if weights.sum() > 0:
            out["aggregate_ffn_flop_frac"] = float(
                (weights * fracs).sum() / weights.sum())
            out["aggregate_attn_flop_frac"] = float(
                (weights * afracs).sum() / weights.sum())
        for i, p in enumerate(self.plans):
            out["plans"].append({
                "name": p.name,
                "keep_per_layer": [round(float(f), 4)
                                   for f in p.keep_fracs],
                "ffn_flop_frac": round(p.flop_frac(), 4),
                "attn_keep_per_layer": (
                    [round(float(f), 4) for f in p.attn_keep_fracs]
                    if p.has_attn else None),
                "attn_flop_frac": (round(p.attn_flop_frac(), 4)
                                   if p.has_attn else None),
                "prefill_blocks": int(self.plan_prefill_blocks[i]),
                "decode_tokens": int(self.plan_decode_tokens[i]),
            })
        return out

    def speculative_stats(self) -> Optional[dict]:
        """Speculation accounting (serve.py stats line + the
        speculative_decode bench section); None when speculation is
        off. Per VERIFY plan: which draft plan served it (after the
        never-denser clamp), drafted/accepted counts, acceptance rate,
        and emitted tokens per speculated row-tick (1.0 would be the
        non-speculative tick; the speculative win is this number minus
        one, bought for one draft pass)."""
        if self.speculative is None or self.speculative.k == 0:
            return None
        out = {"k": self.speculative.k, "draft": self.speculative.draft,
               "spec_ticks": int(self.n_spec_ticks), "plans": []}
        for i, p in enumerate(self.plans):
            drafted = int(self.spec_drafted[i])
            accepted = int(self.spec_accepted[i])
            rows = int(self.spec_row_ticks[i])
            out["plans"].append({
                "name": p.name,
                "draft_plan": self._plan_name(int(self._draft_plan_for[i])),
                "row_ticks": rows,
                "drafted": drafted,
                "accepted": accepted,
                "acceptance_rate": (round(accepted / drafted, 4)
                                    if drafted else None),
                "emitted": int(self.spec_emitted[i]),
                "tokens_per_row_tick": (
                    round(int(self.spec_emitted[i]) / rows, 4) if rows
                    else None),
            })
        return out

    def prefix_stats(self) -> Optional[dict]:
        """Prefix-sharing accounting (serve.py stats line + the
        prefix_sharing bench section); None when the cache is off."""
        if self.prefix_index is None:
            return None
        s = self.prefix_index.stats()
        s.update(
            requests_hit=self.n_prefix_hits,
            blocks_skipped=self.n_shared_blocks,
            pages_shared=self.pool.total_page_shares,
            cow_pages=self.pool.n_cow_pages,
        )
        return s

    def tier_stats(self) -> Optional[dict]:
        """Memory-tiering accounting (serve.py stats line + the
        kv_tiering bench section); None when the host tier is off."""
        if self.host_tier is None:
            return None
        s = self.host_tier.stats()
        s.update(
            swap_outs=self.n_swap_outs,
            swap_ins=self.n_swap_ins,
            pages_swapped_out=self.pool.total_pages_swapped_out,
            pages_swapped_in=self.pool.total_pages_swapped_in,
            parked=len(self.parked),
        )
        return s

    def _maybe_finish(self, st: _ActiveState) -> None:
        hit_eos = (st.req.eos_id is not None and st.out
                   and st.out[-1] == st.req.eos_id)
        done = len(st.out) >= st.req.max_new or hit_eos
        if not done:
            return
        if hit_eos and len(st.out) < st.req.max_new:
            self.n_eos_stops += 1     # early exit frees the slot now
        now = self.clock()
        self.finished[st.req.rid] = RequestOutput(
            rid=st.req.rid, tokens=list(st.out),
            prompt_len=len(st.req.prompt),
            ttft_seconds=st.first_token_time - st.req.arrival_time,
            finish_seconds=now - st.req.arrival_time,
            status="ok", effort=self._plan_name(st.plan_idx))
        del self.active[st.slot]
        self.pool.release(st.slot)

    def _sample(self, logits: np.ndarray, st: _ActiveState) -> int:
        if st.req.temperature <= 0:
            return int(np.argmax(logits))
        # Gumbel-max with the REQUEST's own host RNG stream (seeded
        # (scheduler seed, rid)): draws are independent of batch
        # composition, admission order, and preemption re-runs
        g = st.rng.gumbel(size=logits.shape)
        return int(np.argmax(logits.astype(np.float64)
                             / st.req.temperature + g))


def drive_stream(sched: ContinuousBatchingScheduler,
                 requests: List[Request], after_tick=None) -> float:
    """Drive a timed request stream to completion.

    Each request's `arrival_time` is an OFFSET in seconds from stream
    start; requests are submitted as their arrival time passes (the
    scheduler keeps ticking — mid-flight admission), and the loop
    sleeps instead of spinning while the pool is idle between
    arrivals. The caller's Request objects are never mutated (absolute
    deadlines are stamped onto copies), so the same list can drive
    several schedulers for A/B runs.

    Requests carrying `cancel_after_s` are cancelled by this loop that
    many seconds after their arrival (the trace-replay form of a
    client disconnect). `after_tick(sched)`, when given, runs after
    every tick — the hook the overload benchmark uses to advance its
    simulated clock by a per-tick cost model. When the scheduler
    carries a FaultInjector, its still-stolen resources are restored
    at stream end so leak accounting over the whole stream is exact.

    Returns the clock seconds for the whole stream. Used by
    launch/serve.py --stream and the continuous-batching benchmark so
    both exercise the identical serving loop."""
    clock = sched.clock
    t0 = clock()
    # ascending stable sort + popleft keeps FIFO order for tied arrivals
    pending = deque(
        dataclasses.replace(r, prompt=list(r.prompt),
                            arrival_time=t0 + (r.arrival_time or 0.0))
        for r in sorted(requests, key=lambda r: r.arrival_time or 0.0))
    cancels = deque(sorted(
        (r.arrival_time + r.cancel_after_s, r.rid)
        for r in pending if r.cancel_after_s is not None))
    while pending or not sched.drained:
        now = clock()
        while pending and pending[0].arrival_time <= now:
            sched.submit(pending.popleft())
        while cancels and cancels[0][0] <= now:
            _, rid = cancels.popleft()
            # False (already finished) is fine: a cancel that loses the
            # race to completion is a no-op, as for a real client
            sched.cancel(rid, reason="client cancelled (cancel_after_s)")
        if sched.drained:
            if not pending:
                break
            # route the idle wait through the scheduler's injected sleep:
            # the delta is measured on sched.clock, so a simulated clock
            # must come with a simulated sleep (time.sleep on a fake-
            # clock delta would block on real wall time)
            sched.sleep(max(0.0, pending[0].arrival_time - clock()))
            continue
        sched.tick()
        if after_tick is not None:
            after_tick(sched)
    if sched.faults is not None:
        sched.faults.finalize(sched)
    return clock() - t0
