"""Real-traffic trace replay for the continuous-batching stream driver.

A trace is a jsonl file, one request per line, replayed through the
SAME `drive_stream` loop as the Poisson simulator (launch/serve.py
--trace), so recorded production arrival patterns — bursts, diurnal
ramps, heavy-tailed prompt/output lengths — exercise the scheduler
exactly as synthetic streams do. Record schema (one JSON object per
line):

  arrival_s    float   arrival offset in seconds from stream start
  prompt_len   int     prompt length in tokens (prompt content is
                       synthesized deterministically per record unless
                       `prompt` is given — public traces ship shapes
                       and timing, not text)
  gen_len      int     max new tokens to generate
  prompt       [int]   optional explicit token ids (overrides
                       prompt_len)
  temperature  float   optional, default 0.0 (greedy)
  eos_id       int     optional per-request early-stop token
  effort       str     optional SparsityPlan tier name ("dense" /
                       "balanced" / "turbo") — per-request sparsity;
                       records without it use the default plan
  deadline_ms  float   optional end-to-end deadline (arrival -> last
                       token); expiry frees the request mid-flight
                       with status="timed_out", a provably-unmeetable
                       deadline is shed at submit
  ttft_deadline_ms
               float   optional arrival -> first-token deadline
  cancel_after_s
               float   optional: the client disconnects this many
                       seconds after arrival (drive_stream issues the
                       cancel; status="cancelled")
  prefix_group str/int optional: records sharing a group synthesize an
                       IDENTICAL token prefix of `prefix_len` tokens
                       (group-seeded stream), with the remainder drawn
                       from the usual per-record stream — the
                       shared-system-prompt workload the prefix cache
                       (--prefix-cache) serves. No-op without the field.
  prefix_len   int     tokens of shared prefix when `prefix_group` is
                       set (default: half the prompt, page-aligned by
                       the cache itself, not the trace)
  speculate    int     optional per-request cap on the speculative
                       draft length (serve.py --speculate): 0 turns
                       speculation off for this record, a positive
                       value caps tokens drafted per decode tick.
                       Latency-only — greedy output is bit-identical
                       for every value. Ignored when the scheduler
                       runs without a SpeculativeConfig.

Unknown keys are ignored (real traces carry extra metadata). Sample
traces live at benchmarks/traces/sample_trace.jsonl, — for the
overload fields — benchmarks/traces/sample_overload.jsonl, for
prefix_group — benchmarks/traces/sample_shared_prefix.jsonl, —
generation-heavy, for --speculate — sample_speculate.jsonl, and —
long decodes driving KV page pressure, for the memory tier
(--swap-pages) — sample_longdecode.jsonl.
"""
from __future__ import annotations

import json
import zlib
from typing import List, Optional

import numpy as np

from repro.serving.scheduler import Request


def load_trace(path: str, vocab: int, seed: int = 0,
               eos_id: Optional[int] = None,
               temperature: Optional[float] = None,
               max_requests: Optional[int] = None,
               effort: Optional[str] = None,
               deadline_ms: Optional[float] = None,
               ttft_deadline_ms: Optional[float] = None) -> List[Request]:
    """Parse a jsonl trace into `Request`s for `drive_stream`.

    Prompt tokens are synthesized from a per-record deterministic RNG
    stream (seeded by `seed` and the record index), so replaying the
    same trace is bit-reproducible run-to-run and engine-to-engine.
    `eos_id`, `temperature`, `effort` and the deadline defaults apply
    to records that do not carry their own."""
    requests: List[Request] = []
    with open(path) as f:
        for idx, line in enumerate(f):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            if max_requests is not None and len(requests) >= max_requests:
                break
            rec = json.loads(line)
            if "prompt" in rec:
                prompt = [int(t) for t in rec["prompt"]]
            else:
                n = int(rec["prompt_len"])
                if n < 1:
                    raise ValueError(
                        f"{path}:{idx + 1}: prompt_len must be >= 1")
                rng = np.random.default_rng((seed, idx))
                if "prefix_group" in rec:
                    # group members synthesize an IDENTICAL prefix from
                    # a group-seeded stream (crc32, not hash() — python
                    # hashes are per-process randomized) and keep the
                    # per-record stream for the unique remainder
                    plen = min(int(rec.get("prefix_len", n // 2)), n)
                    if plen < 0:
                        raise ValueError(f"{path}:{idx + 1}: prefix_len "
                                         f"must be >= 0")
                    gseed = zlib.crc32(str(rec["prefix_group"]).encode())
                    grng = np.random.default_rng((seed, gseed))
                    prompt = (grng.integers(0, vocab, size=plen).tolist()
                              + rng.integers(0, vocab,
                                             size=n - plen).tolist())
                else:
                    prompt = rng.integers(0, vocab, size=n).tolist()
            gen_len = int(rec.get("gen_len", 16))
            if gen_len < 1:
                # reject at LOAD time: scheduler.submit would only
                # raise mid-replay, long after earlier requests ran
                raise ValueError(
                    f"{path}:{idx + 1}: gen_len must be >= 1")
            requests.append(Request(
                rid=len(requests),
                prompt=prompt,
                max_new=gen_len,
                temperature=float(rec.get("temperature",
                                          temperature or 0.0)),
                eos_id=(int(rec["eos_id"]) if "eos_id" in rec
                        else eos_id),
                effort=(str(rec["effort"]) if "effort" in rec
                        else effort),
                deadline_ms=(float(rec["deadline_ms"])
                             if "deadline_ms" in rec else deadline_ms),
                ttft_deadline_ms=(float(rec["ttft_deadline_ms"])
                                  if "ttft_deadline_ms" in rec
                                  else ttft_deadline_ms),
                cancel_after_s=(float(rec["cancel_after_s"])
                                if "cancel_after_s" in rec else None),
                speculate=(int(rec["speculate"])
                           if "speculate" in rec else None),
                arrival_time=float(rec.get("arrival_s", 0.0))))
    if not requests:
        raise ValueError(f"trace {path} contains no requests")
    return requests


def trace_stats(requests: List[Request]) -> dict:
    """Shape summary of a loaded trace (printed by serve.py --trace)."""
    plens = np.array([len(r.prompt) for r in requests])
    gens = np.array([r.max_new for r in requests])
    arr = np.array([r.arrival_time or 0.0 for r in requests])
    dur = float(arr.max()) if len(arr) else 0.0
    return {
        "requests": len(requests),
        "duration_s": round(dur, 3),
        # 0.0 sentinel when every record arrives at t=0 (no spread):
        # an "offered rate" is meaningless for an instantaneous burst
        "offered_rate_req_s": (round(len(requests) / dur, 2)
                               if dur > 0 else 0.0),
        "prompt_len_p50": int(np.percentile(plens, 50)),
        "prompt_len_max": int(plens.max()),
        "gen_len_p50": int(np.percentile(gens, 50)),
        "gen_len_max": int(gens.max()),
        # effort-tier mix (None -> the default plan)
        "efforts": sorted({r.effort or "default" for r in requests}),
        # overload-field counts (serve.py robustness line)
        "with_deadline": sum(r.deadline_ms is not None
                             or r.ttft_deadline_ms is not None
                             for r in requests),
        "with_cancel": sum(r.cancel_after_s is not None
                           for r in requests),
        "with_speculate": sum(r.speculate is not None
                              for r in requests),
    }
