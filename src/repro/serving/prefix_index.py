"""Host-side prefix index: token-chain trie mapping page-aligned prompt
prefixes to cached KV pages (the lookup half of vLLM-style prefix
caching; PagedKVPool holds the refcounted ownership half).

Structure
---------
One trie ROOT per SparsityPlan name: sparse plans change the KV bytes a
prefill block writes (dense_first/last_block, per-layer FFN + attention
budgets all feed the residual stream), so requests running DIFFERENT
plans must never share pages — keying the root on the plan makes cross-
plan sharing structurally impossible rather than merely checked.

Below a root, each node is one PAGE of a prompt: its edge key is the
page's literal token tuple (page_size tokens — dict equality on the
tuple, so lookups are collision-free by construction; no hashing
scheme to trust), and the node records the cached page id whose device
payload holds exactly those positions' KV. A path root -> node spells a
page-aligned token prefix; KV bytes for a page depend only on the
token chain before it plus the plan (causal attention, position-tied
RoPE, deterministic routing/selection), so any request whose prompt
walks the same path can map the chain's pages verbatim — bit-identical
to recomputing them.

Lifecycle
---------
* `publish` is called by the scheduler as each prompt block COMPLETES
  prefill (never the last prompt block — its pages see the request's
  own decode-adjacent state and partial fills), inserting nodes for
  pages not yet cached and `pool.mark_cached`-ing them.
* `lookup` at admission walks the longest cached chain for a prompt;
  the scheduler maps those pages via `pool.share` and starts prefill
  at the first unshared block.
* `drop_page` (eviction under pressure, LRU victim chosen by the pool)
  removes the page's ENTIRE SUBTREE — children's KV is meaningless
  without the ancestor chain, and dropping whole subtrees preserves
  the invariant "every cached node's parent is cached", which is what
  lets `publish` skip mid-chain nodes it finds already present.

The index holds no device state and never touches refcounts directly:
`mark_cached`/`uncache` on the pool flip pages between the free and
reclaimable-LRU lists; eviction POLICY (when, which victim) stays in
the scheduler.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("key", "page", "parent", "children")

    def __init__(self, key, page: int, parent):
        self.key = key                # token tuple of THIS page (root: None)
        self.page = page              # cached page id (root: -1)
        self.parent = parent
        self.children: Dict[tuple, "_Node"] = {}


class PrefixIndex:
    """Trie over (plan name, page token tuples) -> cached page chains."""

    def __init__(self, pool):
        self.pool = pool
        self.page_size = pool.page_size
        self._roots: Dict[Optional[str], _Node] = {}
        self._by_page: Dict[int, _Node] = {}
        # stats (serve.py prefix_sharing line / bench section)
        self.n_lookups = 0
        self.n_hits = 0
        self.n_published = 0
        self.n_evictions = 0

    # ------------------------------------------------------------- keys

    @staticmethod
    def page_keys(prompt: Sequence[int], page_size: int,
                  n_pages: int) -> List[tuple]:
        """The first n_pages page-aligned token tuples of a prompt (the
        scheduler caps n_pages at (n_blocks - 1) * pages_per_block: the
        last prompt block is never shared)."""
        n = min(n_pages, len(prompt) // page_size)
        return [tuple(prompt[i * page_size:(i + 1) * page_size])
                for i in range(n)]

    # ----------------------------------------------------------- lookup

    def lookup(self, plan: Optional[str], keys: Sequence[tuple],
               record: bool = True) -> List[int]:
        """Pages of the longest cached chain matching `keys` under
        `plan`'s root. Counts a hit when at least one page matches;
        record=False skips the stats (advisory probes: the submit-time
        shed bound would otherwise double-count every request)."""
        if record:
            self.n_lookups += 1
        node = self._roots.get(plan)
        pages: List[int] = []
        for key in keys:
            if node is None:
                break
            node = node.children.get(key)
            if node is None:
                break
            pages.append(node.page)
        if pages and record:
            self.n_hits += 1
        return pages

    # ---------------------------------------------------------- publish

    def publish(self, plan: Optional[str], keys: Sequence[tuple],
                pages: Sequence[int], lo: int, hi: int) -> int:
        """Insert pages[lo:hi] (a just-prefilled block's pages) under
        the chain keys[:hi]. Existing nodes are kept (first writer
        wins — the payloads are bit-identical by construction); a
        broken chain (ancestor evicted mid-flight) stops insertion so
        every cached node's parent stays cached. Returns the number of
        pages newly cached."""
        node = self._roots.get(plan)
        if node is None:
            node = self._roots[plan] = _Node(None, -1, None)
        published = 0
        for j in range(hi):
            if j >= len(keys):
                break
            child = node.children.get(keys[j])
            if child is None:
                if j < lo:
                    # ancestor chain broken (evicted while we ran):
                    # publishing deeper pages would orphan them
                    return published
                page = int(pages[j])
                child = _Node(keys[j], page, node)
                node.children[keys[j]] = child
                self._by_page[page] = child
                self.pool.mark_cached(page)
                self.n_published += 1
                published += 1
            node = child
        return published

    # --------------------------------------------------------- eviction

    def drop_page(self, page: int) -> int:
        """Evict the node holding `page` AND its whole subtree (KV below
        a dropped ancestor is unreachable by any future lookup).
        Returns the number of pages dropped; each is `pool.uncache`d —
        idle ones free immediately, still-referenced ones free when
        their last reader releases."""
        node = self._by_page.pop(page, None)
        if node is None:
            return 0
        del node.parent.children[node.key]
        dropped = 0
        stack = [node]
        while stack:
            cur = stack.pop()
            self._by_page.pop(cur.page, None)
            self.pool.uncache(cur.page)
            self.n_evictions += 1
            dropped += 1
            stack.extend(cur.children.values())
            cur.children.clear()
        return dropped

    def evict_lru(self) -> bool:
        """Drop the pool's least-recently-released idle cached page
        (plus its subtree). False when nothing is reclaimable — the
        caller falls back to preemption."""
        victim = self.pool.lru_reclaimable()
        if victim is None:
            return False
        dropped = self.drop_page(victim)
        assert dropped > 0, f"reclaimable page {victim} missing from index"
        return True

    def clear(self) -> int:
        """Drop everything (post-warmup reset; drain-time leak checks).
        Returns the number of pages uncached."""
        dropped = 0
        for page in list(self._by_page):
            node = self._by_page.get(page)
            if node is not None:
                dropped += self.drop_page(page)
        self._roots.clear()
        return dropped

    # ------------------------------------------------------------ stats

    @property
    def n_cached_pages(self) -> int:
        return len(self._by_page)

    def stats(self) -> dict:
        return {
            "lookups": self.n_lookups,
            "hits": self.n_hits,
            "hit_rate": self.n_hits / max(self.n_lookups, 1),
            "pages_cached": self.n_cached_pages,
            "pages_published": self.n_published,
            "evictions": self.n_evictions,
        }
