"""HostKVTier: the host-memory swap tier behind the paged KV heap.

Memory tiering semantics (see ROADMAP.md for the full invariant list):
a page is in exactly ONE of three states —

  resident  device-heap page owned by a live (or prefix-cached)
            request; the only state attention can read.
  swapped   payload lives in THIS tier as host numpy arrays; the
            device page was freed (counted in total_page_frees) and
            the owning request is parked. Swap-in allocates FRESH
            device pages (counted in total_page_allocs) and scatters
            the payload back — physical page ids may change, which is
            invisible through the table-directed gather.
  cached    refcount-zero prefix pages held device-side by the LRU
            reclaim list (serving/prefix_index.py). Cached pages are
            NEVER swapped — under pressure they are evicted (dropped
            and re-prefilled on next miss), because a cache whose hit
            path pays a host round trip is slower than recompute here.

Only the scheduler moves bytes between tiers, and only through the two
fixed-width jitted runtime entries (`read_pages` / `write_pages`,
warmed at warmup so swap traffic never recompiles). This module is
pure host bookkeeping: numpy payload storage plus the same
alloc/free-parity accounting discipline as PagedKVPool, extended so
`total_page_allocs == total_page_frees` holds ACROSS tiers after a
drain.

The fault injector's synthetic page pressure steals from this tier's
free capacity too (kind "host_pages"), forcing the swap path to hit
its capacity wall and fall back to true preemption under chaos.
"""
from __future__ import annotations


class HostKVTier:
    """Fixed-capacity host-memory page store, keyed by opaque handles.

    capacity_pages bounds how many pages may be swapped out at once
    (the host tier is cheap but not free — serving configs size it like
    any other memory budget). Payloads are per-page numpy pytrees
    exactly as produced by ``runtime.read_pages`` (split along the page
    axis), so a swap-in writes back bit-identical bytes.
    """

    def __init__(self, capacity_pages: int):
        if capacity_pages <= 0:
            raise ValueError(
                f"host tier capacity must be positive, got {capacity_pages}")
        self.capacity_pages = int(capacity_pages)
        self._store: dict[int, object] = {}   # handle -> per-page payload
        self._pages: dict[int, int] = {}      # handle -> page count
        self._next_handle = 1
        self.n_used = 0
        # chaos hook: synthetic host-memory pressure (faults.py) steals
        # free capacity and must restore every stolen page by finalize
        self._stolen = 0
        # counters (monotonic; stats() exposes them)
        self.total_host_puts = 0      # pages swapped INTO this tier
        self.total_host_frees = 0     # pages released from this tier
        self.peak_used = 0

    @property
    def n_free(self) -> int:
        return self.capacity_pages - self.n_used - self._stolen

    def can_hold(self, n_pages: int) -> bool:
        return 0 < n_pages <= self.n_free

    def put(self, payloads) -> int:
        """Store one swapped-out page group; returns its handle.

        payloads: list of per-page numpy pytrees (one per swapped
        page, in page-table order). Raises if the tier cannot hold
        them — the caller must check ``can_hold`` first and fall back
        to preemption."""
        n = len(payloads)
        if not self.can_hold(n):
            raise RuntimeError(
                f"host tier overflow: {n} pages into {self.n_free} free")
        hid = self._next_handle
        self._next_handle += 1
        self._store[hid] = payloads
        self._pages[hid] = n
        self.n_used += n
        self.total_host_puts += n
        self.peak_used = max(self.peak_used, self.n_used)
        return hid

    def get(self, hid: int):
        """Payloads for a handle (swap-in reads them before free())."""
        return self._store[hid]

    def pages_of(self, hid: int) -> int:
        return self._pages[hid]

    def free(self, hid: int) -> int:
        """Release a handle's pages (after swap-in, or when the parked
        owner is cancelled/expired). Returns the page count freed."""
        n = self._pages.pop(hid)
        del self._store[hid]
        self.n_used -= n
        self.total_host_frees += n
        return n

    # -- fault-injection hooks (serving/faults.py) ---------------------

    def steal_free_pages(self, n: int) -> int:
        """Synthetic host-memory pressure: remove up to n pages of free
        capacity. Returns how many were actually stolen."""
        n = max(0, min(int(n), self.n_free))
        self._stolen += n
        return n

    def restore_free_pages(self, n: int) -> None:
        if n > self._stolen:
            raise RuntimeError(
                f"restoring {n} host pages but only {self._stolen} stolen")
        self._stolen -= n

    # -- introspection -------------------------------------------------

    def stats(self) -> dict:
        return {
            "capacity_pages": self.capacity_pages,
            "n_used": self.n_used,
            "n_free": self.n_free,
            "n_handles": len(self._store),
            "stolen": self._stolen,
            "total_host_puts": self.total_host_puts,
            "total_host_frees": self.total_host_frees,
            "peak_used": self.peak_used,
        }

    def check_consistency(self) -> None:
        used = sum(self._pages.values())
        if used != self.n_used:
            raise AssertionError(
                f"host tier used {self.n_used} != handle sum {used}")
        if self.n_used + self._stolen > self.capacity_pages:
            raise AssertionError("host tier over capacity")
        if self.total_host_puts - self.total_host_frees != self.n_used:
            raise AssertionError(
                "host tier put/free parity broken: "
                f"{self.total_host_puts} puts, {self.total_host_frees} "
                f"frees, {self.n_used} used")
