"""Slot-based KV cache pool with free-list reuse.

One fixed-capacity device allocation ([n_layers, n_slots, cache_len,
n_kv_heads, head_dim] per K/V) serves a churning request set: a request
is admitted into a free slot, its prefill blocks and decode tokens write
only that slot's rows, and on completion the slot returns to the free
list without touching device memory — stale KV past a row's live length
is never attended (ragged masks) and gets overwritten by the next
occupant's prefill. Because the buffer shapes never change, the jitted
decode step compiled for the pool serves every future request mix with
zero recompilation.

This is the default baseline layout (`cfg.kv_layout = "slot"`); the
block-granular `repro.serving.page_pool.PagedKVPool` is its
fragmentation-free counterpart and shares the acquire/release/fits/
note_tick surface so the scheduler can drive either.

Host-side metadata (free list, per-slot lengths, reuse stats) lives in
plain Python/numpy; only the KV pytree is on device.

`release` is IDEMPOTENT per request: scheduler paths that can both try
to free a slot within one tick (EOS early-stop sampled off prefill
logits, preemption in the paged twin) previously double-counted
`total_releases` and could re-append a slot already on the free list —
now the second release is a no-op and `total_releases ==
total_acquires` holds after any churny stream (regression-pinned in
tests/test_serving.py).
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np


class KVSlotPool:
    """Fixed-capacity pool of per-request KV cache slots."""

    layout = "slot"

    def __init__(self, cache, n_slots: int, cache_len: int):
        self.cache = cache                # device pytree, slot axis = 1
        self.n_slots = n_slots
        self.cache_len = cache_len
        self._free = deque(range(n_slots))
        self._held = np.zeros(n_slots, bool)
        # tokens currently materialized in each slot (prompt + generated)
        self.lengths = np.zeros(n_slots, np.int64)
        # stats (exercised by tests: reuse after completion)
        self.total_acquires = 0
        self.total_releases = 0
        self.max_in_use = 0
        self.stranded_tokens_at_peak = 0

    @classmethod
    def create(cls, runtime, n_slots: int, cache_len: int) -> "KVSlotPool":
        return cls(runtime.init_cache(n_slots, cache_len), n_slots,
                   cache_len)

    # ------------------------------------------------------------ slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return self.n_slots - len(self._free)

    def acquire(self) -> Optional[int]:
        """Claim a free slot (FIFO reuse order), or None when full."""
        if not self._free:
            return None
        slot = self._free.popleft()
        self._held[slot] = True
        self.lengths[slot] = 0
        self.total_acquires += 1
        self.max_in_use = max(self.max_in_use, self.n_in_use)
        return slot

    def release(self, slot: int) -> None:
        """Return a slot to the free list. The device KV rows are left
        as-is; the next occupant's prefill overwrites them. Idempotent:
        releasing an already-free slot is a no-op (never a stats
        double-count or a duplicate free-list entry)."""
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} out of range")
        if not self._held[slot]:
            return
        self._held[slot] = False
        self.lengths[slot] = 0
        self._free.append(slot)
        self.total_releases += 1

    def fits(self, n_tokens: int) -> bool:
        """Whether a request needing n_tokens cache positions can ever
        be served by this pool."""
        return n_tokens <= self.cache_len

    # ----------------------------------------- fault-injection pressure

    def steal_free_slots(self, n: int) -> list:
        """Fault-injection hook (serving/faults.py): temporarily remove
        up to n FREE slots from the free list so admission sees a full
        pool. Stolen slots are not held (acquire never returns them)
        and must come back via `restore_free_slots` — the injector
        guarantees it, so leak accounting stays exact."""
        taken = []
        for _ in range(min(n, len(self._free))):
            taken.append(self._free.popleft())
        return taken

    def restore_free_slots(self, slots: list) -> None:
        self._free.extend(slots)

    # ------------------------------------------------------------ stats

    def stranded_tokens(self) -> int:
        """Reserved-but-dead token positions across held slots: every
        occupant pins a full cache_len row however short it is — the
        fragmentation the paged layout removes."""
        held = self._held
        return int((self.cache_len - self.lengths[held]).sum())

    def note_tick(self) -> None:
        """Scheduler hook, called once per tick: refresh the occupancy
        peak and record stranded bytes at that peak (compared
        layout-vs-layout by benchmarks/continuous_batching.py)."""
        if self.n_in_use >= self.max_in_use:
            self.max_in_use = self.n_in_use
            self.stranded_tokens_at_peak = self.stranded_tokens()
