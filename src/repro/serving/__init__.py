"""Serving stack: continuous-batching runtime over FastForward models.

Layering (see ROADMAP.md "Serving architecture"):

  engine.Engine                 user-facing API (generate + scheduler())
    scheduler.ContinuousBatchingScheduler
                                admit / chunked prefill / batched decode
                                (paged: page-gated admission, lazy
                                per-block allocation, youngest-first
                                preemption)
      cache_pool.KVSlotPool     slot reuse, free list, per-slot lengths
                                (cfg.kv_layout="slot", the baseline)
      page_pool.PagedKVPool     block-granular page heap + per-request
                                page tables (cfg.kv_layout="paged")
      runtime.ModelRuntime      jitted prefill_block / decode_step per
                                model family (dense, MoE) + paged twins
      trace.load_trace          real-traffic jsonl trace replay
"""
from repro.serving.cache_pool import KVSlotPool
from repro.serving.engine import Engine, GenerationResult, StaticEngine
from repro.serving.page_pool import PagedKVPool
from repro.serving.runtime import (DenseRuntime, ModelRuntime, MoeRuntime,
                                   make_runtime)
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     RequestOutput, drive_stream)
from repro.serving.trace import load_trace

__all__ = [
    "ContinuousBatchingScheduler", "DenseRuntime", "Engine",
    "GenerationResult", "KVSlotPool", "ModelRuntime", "MoeRuntime",
    "PagedKVPool", "Request", "RequestOutput", "StaticEngine",
    "drive_stream", "load_trace", "make_runtime",
]
