"""Serving stack: continuous-batching runtime over FastForward models.

Layering (see ROADMAP.md "Serving architecture"):

  engine.Engine                 user-facing API (generate + scheduler())
    scheduler.ContinuousBatchingScheduler
                                admit / chunked prefill / batched decode
      cache_pool.KVSlotPool     slot reuse, free list, per-slot lengths
      runtime.ModelRuntime      jitted prefill_block / decode_step per
                                model family (dense, MoE)
"""
from repro.serving.cache_pool import KVSlotPool
from repro.serving.engine import Engine, GenerationResult, StaticEngine
from repro.serving.runtime import (DenseRuntime, ModelRuntime, MoeRuntime,
                                   make_runtime)
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     RequestOutput, drive_stream)

__all__ = [
    "ContinuousBatchingScheduler", "DenseRuntime", "Engine",
    "GenerationResult", "KVSlotPool", "ModelRuntime", "MoeRuntime",
    "Request", "RequestOutput", "StaticEngine", "drive_stream",
    "make_runtime",
]
