"""Serving stack: continuous-batching runtime over FastForward models.

Layering (see ROADMAP.md "Serving architecture"):

  engine.Engine                 user-facing API (generate + scheduler())
    scheduler.ContinuousBatchingScheduler
                                admit / chunked prefill / batched decode
                                (paged: page-gated admission, lazy
                                per-block allocation, youngest-first
                                preemption; deadline expiry, cancel,
                                stall watchdog)
      admission.AdmissionController
                                deadline-aware shedding + hysteretic
                                effort-tier degradation under overload
      faults.FaultInjector      deterministic seed-driven chaos (forced
                                preemption, synthetic pressure, slow
                                ticks, random aborts)
      cache_pool.KVSlotPool     slot reuse, free list, per-slot lengths
                                (cfg.kv_layout="slot", the baseline)
      page_pool.PagedKVPool     block-granular page heap + per-request
                                page tables (cfg.kv_layout="paged"),
                                refcounted ownership (prefix sharing);
                                int8-quantized storage mode
                                (cfg.kv_quant, kernels/kv_quant) and
                                tier-aware swap accounting
      kv_tier.HostKVTier        host-memory swap tier (swap_pages > 0):
                                page pressure swaps the youngest
                                request's exclusive pages out instead
                                of preempt-and-recompute; parked
                                requests resume bit-identically
      prefix_index.PrefixIndex  host-side (plan, token-chain) trie over
                                cached pages (prefix_cache=True): prefix
                                hits skip whole prefill blocks
      speculative.SpeculativeConfig
                                self-speculative decoding: sparse-plan
                                draft + own-plan chunk verify on the
                                SAME weights (pure acceptance rule;
                                greedy output bit-identical on/off)
      runtime.ModelRuntime      jitted prefill_block / decode_step per
                                model family (dense, MoE) + paged twins
                                + draft_steps / verify_chunk protocol
      trace.load_trace          real-traffic jsonl trace replay
"""
from repro.serving.admission import AdmissionConfig, AdmissionController
from repro.serving.cache_pool import KVSlotPool
from repro.serving.engine import Engine, GenerationResult, StaticEngine
from repro.serving.faults import FaultInjector
from repro.serving.kv_tier import HostKVTier
from repro.serving.page_pool import PagedKVPool
from repro.serving.prefix_index import PrefixIndex
from repro.serving.runtime import (DenseRuntime, ModelRuntime, MoeRuntime,
                                   make_runtime)
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     RequestOutput, SchedulerStallError,
                                     drive_stream)
from repro.serving.speculative import (SpeculativeConfig, accept_drafts,
                                       parse_speculate_arg)
from repro.serving.trace import load_trace

__all__ = [
    "AdmissionConfig", "AdmissionController",
    "ContinuousBatchingScheduler", "DenseRuntime", "Engine",
    "FaultInjector", "GenerationResult", "HostKVTier", "KVSlotPool",
    "ModelRuntime",
    "MoeRuntime", "PagedKVPool", "PrefixIndex", "Request",
    "RequestOutput",
    "SchedulerStallError", "SpeculativeConfig", "StaticEngine",
    "accept_drafts", "drive_stream",
    "load_trace",
    "make_runtime", "parse_speculate_arg",
]
