"""Self-speculative decoding: sparse-draft / dense-verify.

The effort-tier ladder (core.fastforward.EFFORT_TIERS) gives the
serving stack a free draft model: the SAME weights under a sparser
SparsityPlan.  Both the draft and the verify executables are already
compiled and registered on the runtime (serving/runtime.py keeps one
decode executable per entry point with the full plan tuple closed over
and a traced per-row ``plan_ids`` vector), so speculation costs zero
extra parameters and zero extra compiles beyond the two chunk-shaped
protocol entries (``draft_steps`` / ``verify_chunk``) warmed alongside
the rest.

Protocol (one speculative decode tick, per active row)
------------------------------------------------------
Let ``p = st.pos`` (next KV write position) and ``t0 = st.next_token``.

1. **Draft**: ``k`` argmax-feedback ``decode_step`` applications under
   the row's *draft* plan, writing KV at positions ``p .. p+k-1`` and
   proposing ``d_1 .. d_k``.
2. **Verify**: ONE chunk-scored ``decode_step`` feeding
   ``[t0, d_1 .. d_k]`` at positions ``p .. p+k`` under the row's own
   (verify) plan.  The chunk REWRITES positions ``p .. p+k-1``, so
   draft-plan KV is never read by any accepted computation.
3. **Accept**: with ``g_i = argmax(verify_logits_i)``, take the longest
   prefix ``n`` with ``d_{i+1} == g_i`` for all ``i < n`` and emit
   ``g_0 .. g_n`` — ``n+1`` tokens, the last being the standard bonus
   token from the verifier's logits at the first disagreement.
4. **Roll back** rejected KV: slot layout rewinds the length cursor;
   paged layout truncates tail pages past the accepted position with
   exact alloc/free accounting (serving/scheduler.py).

After acceptance, positions ``p .. p+n`` hold exactly the tokens the
sequential greedy loop would have written (``t0, g_0 .. g_{n-1}``), so
greedy output is bit-identical with speculation on or off — the draft
plan affects only latency.  ``accept_drafts`` below is that rule as a
pure function over integer arrays; it is what the Hypothesis property
suite and the scheduler both call.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "SpeculativeConfig",
    "accept_drafts",
    "parse_speculate_arg",
]


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Speculative-decode settings for ContinuousBatchingScheduler.

    k: draft length — tokens proposed per decode tick (k == 0 is the
       exact non-speculative tick; the scheduler short-circuits it).
    draft: name of the registered SparsityPlan used for drafting
       (an effort-tier name when plans come from serve.py).  Rows whose
       *verify* plan is already at least as sparse keep their own plan
       for drafting — a degraded request's draft is never denser than
       its verify plan.
    """

    k: int = 4
    draft: str = "turbo"

    def __post_init__(self):
        if self.k < 0:
            raise ValueError(f"speculative k must be >= 0, got {self.k}")
        if not self.draft:
            raise ValueError("draft plan name must be non-empty")


def accept_drafts(drafts: Sequence[int], greedy: Sequence[int],
                  n_draft: Optional[int] = None,
                  ) -> Tuple[int, np.ndarray]:
    """Longest-agreeing-prefix acceptance with bonus token.

    drafts: the k draft proposals ``d_1 .. d_k`` (draft-plan argmax).
    greedy: the k+1 verifier argmaxes ``g_0 .. g_k`` — ``g_i`` scored at
       position ``p+i`` after feeding ``[t0, d_1 .. d_i]``.
    n_draft: number of VALID drafts for this row (<= k); trailing
       entries of ``drafts``/``greedy`` beyond it are padding from the
       fixed-shape batch and must not influence the result.  Defaults
       to ``len(drafts)``.

    Returns ``(n_accepted, emitted)`` where ``emitted`` is
    ``g_0 .. g_{n_accepted}`` — always at least one token (the verifier
    scored position ``p`` exactly as the non-speculative tick would),
    at most ``n_draft + 1``.  Pure function of its arguments: the
    result for a row is independent of every other row in the batch.
    """
    drafts = np.asarray(drafts, dtype=np.int64)
    greedy = np.asarray(greedy, dtype=np.int64)
    if n_draft is None:
        n_draft = int(drafts.shape[0])
    n_draft = int(n_draft)
    if n_draft < 0 or n_draft > drafts.shape[0]:
        raise ValueError(
            f"n_draft {n_draft} out of range for {drafts.shape[0]} drafts")
    if greedy.shape[0] < n_draft + 1:
        raise ValueError(
            f"need {n_draft + 1} verifier tokens, got {greedy.shape[0]}")
    n = 0
    while n < n_draft and drafts[n] == greedy[n]:
        n += 1
    return n, greedy[: n + 1].astype(np.int64).copy()


def parse_speculate_arg(text: str) -> SpeculativeConfig:
    """Parse the serve.py ``--speculate K[,draft_tier]`` argument."""
    parts = [p.strip() for p in str(text).split(",")]
    if not parts or not parts[0]:
        raise ValueError("--speculate expects K[,draft_tier]")
    try:
        k = int(parts[0])
    except ValueError as e:
        raise ValueError(f"--speculate K must be an int, got {parts[0]!r}") from e
    draft = parts[1] if len(parts) > 1 and parts[1] else "turbo"
    if len(parts) > 2:
        raise ValueError(f"--speculate takes K[,draft_tier], got {text!r}")
    return SpeculativeConfig(k=k, draft=draft)
