"""Error compensation network (paper §3.3).

A low-rank two-layer FFN (bottleneck r' = d_model/8) running in parallel
with the sparsified FFN; its output is added to the sparse FFN output.
Trained by layerwise distillation (MSE against the dense FFN output),
warm-started with oracle masks before switching to predicted masks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec


def compensator_spec(d_model: int, r: int, dtype=jnp.float32):
    return {
        "w1": ParamSpec((d_model, r), ("embed", None), dtype=dtype),
        # zero-init the output projection: the compensator starts as a
        # no-op, so an untrained compensator never hurts fidelity.
        "w2": ParamSpec((r, d_model), (None, "embed"), init="zeros", dtype=dtype),
    }


def compensate(params, x):
    """Eq. 20: Y_comp = sigma(X W1) W2, per token."""
    h = jax.nn.relu(
        jnp.einsum("...d,dr->...r", x, params["w1"],
                   preferred_element_type=jnp.float32)
    )
    y = jnp.einsum("...r,rd->...d", h, params["w2"],
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def compensator_loss(params, x, y_sparse, y_dense):
    """Eq. 22 layerwise distillation MSE (compensated sparse vs dense)."""
    y = y_sparse + compensate(params, x)
    err = (y - y_dense).astype(jnp.float32)
    return jnp.mean(err * err)
