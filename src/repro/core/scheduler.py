"""Layer-wise sparsity scheduling (paper §3.4, Algorithm 1).

Layer importance s_i = attention mass received by *non-sink* tokens
(everything outside the first prompt block), averaged over heads and a
calibration set. Algorithm 1 greedily water-fills keep-fractions
proportional to importance under a global budget.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def nonsink_attention_mass(attn_probs, block_size: int):
    """Eq. 23 per-layer importance from one calibration sample.

    attn_probs: [..., H, T, T] post-softmax attention for ONE layer
    (query axis -2 attends over key axis -1). Returns scalar: total
    attention mass received by keys outside the first block, averaged
    over heads (and any leading batch dims).
    """
    t_k = attn_probs.shape[-1]
    keys = jnp.arange(t_k)
    nonsink = (keys >= block_size).astype(attn_probs.dtype)
    # sum over queries t and non-sink keys k; MEAN over heads/batch
    mass = jnp.einsum("...ts,s->...", attn_probs, nonsink)
    return jnp.mean(mass)


def allocate_budgets(importance, budget: float):
    """Algorithm 1: importance s_i -> per-layer keep-fractions b_i.

    `budget` is the global keep-fraction (1 - sparsity). Returns a numpy
    array b with b_i in (0, 1], mean(b) == budget (up to clipping).
    """
    s = np.asarray(importance, np.float64)
    L = len(s)
    assert np.all(s >= 0), "importance must be non-negative"
    T = budget * L
    S_total = float(np.sum(s))
    b = np.zeros(L)
    # allocate high-importance layers first so min(1, .) clipping
    # redistributes their overflow to the rest (greedy waterfill).
    order = np.argsort(-s)
    for i in order:
        if S_total <= 0:
            b[i] = min(1.0, T / max(L, 1))
            continue
        b[i] = min(1.0, s[i] / S_total * T)
        T -= b[i]
        S_total -= s[i]
    # no floor: budgets_to_tiles enforces >=1 tile per layer downstream
    return np.clip(b, 0.0, 1.0)


def budgets_to_tiles(budgets, n_tiles: int):
    """Per-layer keep-fraction -> integer tile counts (>=1)."""
    return np.maximum(1, np.round(np.asarray(budgets) * n_tiles)).astype(np.int32)


def uniform_budgets(n_layers: int, budget: float):
    return np.full(n_layers, budget)


def calibrate_layer_importance(collect_attn_fn, samples, block_size: int):
    """Run `collect_attn_fn(sample) -> [L, H, T, T]` over a calibration
    set and average Eq. 23 per layer. Pure-python driver (offline)."""
    acc = None
    for x in samples:
        probs = collect_attn_fn(x)  # [L, H, T, T]
        s = jax.vmap(lambda p: nonsink_attention_mass(p, block_size))(probs)
        s = np.asarray(s, np.float64)
        acc = s if acc is None else acc + s
    return acc / max(len(samples), 1)
