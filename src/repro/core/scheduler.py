"""Layer-wise sparsity scheduling (paper §3.4, Algorithm 1) and the
SparsityPlan object that carries its result onto the serving hot path.

Layer importance s_i = attention mass received by *non-sink* tokens
(everything outside the first prompt block), averaged over heads and a
calibration set. Algorithm 1 greedily water-fills keep-fractions
proportional to importance under a global budget.

A `SparsityPlan` is the RESOLVED form of a sparsity policy: per-layer
integer tile counts, fixed once per model (plus optional per-request
effort tiers — see repro.core.fastforward.resolve_plan). It is a
frozen, hashable dataclass so the serving runtime can use it as a jit
static argument: one executable per (plan, batch-width) pair, all
pre-compiled at warmup, zero recompilation across mixed-effort
traffic. See the DESIGN note in repro.core.fastforward for the full
contract (resolution, [L] count padding, batching-key membership).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


def nonsink_attention_mass(attn_probs, block_size: int):
    """Eq. 23 per-layer importance from one calibration sample.

    attn_probs: [..., H, T, T] post-softmax attention for ONE layer
    (query axis -2 attends over key axis -1). Returns scalar: total
    attention mass received by keys outside the first block, averaged
    over heads (and any leading batch dims).
    """
    t_k = attn_probs.shape[-1]
    keys = jnp.arange(t_k)
    nonsink = (keys >= block_size).astype(attn_probs.dtype)
    # sum over queries t and non-sink keys k; MEAN over heads/batch
    mass = jnp.einsum("...ts,s->...", attn_probs, nonsink)
    return jnp.mean(mass)


def allocate_budgets(importance, budget: float):
    """Algorithm 1: importance s_i -> per-layer keep-fractions b_i.

    `budget` is the global keep-fraction (1 - sparsity). Returns a numpy
    array b with b_i in (0, 1], mean(b) == budget (up to clipping).
    """
    s = np.asarray(importance, np.float64)
    L = len(s)
    assert np.all(s >= 0), "importance must be non-negative"
    T = budget * L
    S_total = float(np.sum(s))
    b = np.zeros(L)
    # allocate high-importance layers first so min(1, .) clipping
    # redistributes their overflow to the rest (greedy waterfill).
    order = np.argsort(-s)
    remaining = L
    for i in order:
        if S_total <= 0:
            # zero residual importance mass: spread the residual budget
            # evenly over the layers still unallocated (NOT the full L,
            # and keep decrementing T — otherwise a single importance
            # spike silently loses budget)
            b[i] = min(1.0, T / max(remaining, 1))
        else:
            b[i] = min(1.0, s[i] / S_total * T)
        T -= b[i]
        S_total -= s[i]
        remaining -= 1
    # no floor: budgets_to_tiles enforces >=1 tile per layer downstream
    return np.clip(b, 0.0, 1.0)


def budgets_to_tiles(budgets, n_tiles: int):
    """Per-layer keep-fraction -> integer tile counts in [1, n_tiles].

    Largest-remainder rounding: independent per-layer `round()` lets
    the realized total drift from the global budget by up to L/2 tiles
    (every layer rounding the same way), silently changing the FLOP
    budget Algorithm 1 allocated. Here the total is pinned first —
    T = round(sum(budgets) * n_tiles), clipped to the feasible
    [L, L * n_tiles] — and the per-layer floors are topped up in order
    of largest fractional remainder (ties broken by layer index), so
    sum(counts) == T exactly while staying within [1, n_tiles] per
    layer."""
    b = np.asarray(budgets, np.float64)
    L = len(b)
    raw = np.clip(b, 0.0, 1.0) * n_tiles
    total = int(np.clip(np.round(raw.sum()), L, L * n_tiles))
    counts = np.clip(np.floor(raw), 1, n_tiles).astype(np.int64)
    rem = raw - np.floor(raw)
    # stable order: biggest remainder first, then layer index
    order = np.lexsort((np.arange(L), -rem))
    deficit = total - int(counts.sum())
    if deficit > 0:
        for i in order:
            if deficit == 0:
                break
            room = n_tiles - counts[i]
            take = min(room, deficit)
            counts[i] += take
            deficit -= take
    elif deficit < 0:
        for i in order[::-1]:          # smallest remainder loses first
            if deficit == 0:
                break
            room = counts[i] - 1
            give = min(room, -deficit)
            counts[i] -= give
            deficit += give
    return counts.astype(np.int32)


def uniform_budgets(n_layers: int, budget: float):
    return np.full(n_layers, budget)


# --------------------------------------------------------- SparsityPlan


@dataclasses.dataclass(frozen=True)
class SparsityPlan:
    """A resolved sparsity policy: per-layer kept-tile counts.

    The first-class object every FastForward FLOP-reducing path takes
    (gather, batched Pallas kernel, decode, MoE shared expert) —
    replacing the scattered `k_tiles=` / `keep_frac=` scalars.

    Contract:
      * `tile_counts[l]` is layer l's kept tile count, in [1, n_tiles].
      * `k_max = max(tile_counts)` is the STATIC tile-id width: the
        gather/kernel paths always select the top-`k_max` tiles so the
        layer scan stays shape-homogeneous; a per-layer traced count
        (`k_valid`) masks (XLA) or `pl.when`-skips (Pallas) the tail
        tiles a cheaper layer does not consume.
      * hashable + eq (frozen, tuple-backed): usable as a jit static
        argument. The serving runtime compiles one executable per
        (plan, width bucket), the scheduler batches only same-plan
        rows per prefill call, and warmup pre-compiles every pair, so
        mixed-effort traffic never recompiles.
      * `keep` is the requested GLOBAL keep-fraction the plan was
        resolved from; `with_tiles` uses it to re-derive the plan on a
        different FFN width (MoE shared expert) with the same rule the
        legacy `k_tiles_for` used, keeping the uniform shim
        bit-identical to pre-plan configs.
    """

    name: str
    tile_counts: Tuple[int, ...]
    n_tiles: int
    tile: int
    keep: float
    # --- attention-block budget (dual-budget plans; ISSUE 6) ---
    # Per-layer kept-KV-block counts on a VIRTUAL grid of `attn_tiles`
    # slots (the real causally-valid block count varies per query block,
    # so the budget is a fraction count_l / attn_tiles that the
    # attention wiring scales onto the actual block grid). None/0 means
    # dense attention — the pre-dual-budget plan, hash/eq-compatible
    # with every existing call site. Same largest-remainder pinning,
    # same frozen/hashable jit-static contract as `tile_counts`.
    attn_counts: Optional[Tuple[int, ...]] = None
    attn_tiles: int = 0
    attn_keep: float = 1.0

    def __post_init__(self):
        if not self.tile_counts:
            raise ValueError("SparsityPlan needs at least one layer")
        if min(self.tile_counts) < 1 or max(self.tile_counts) > self.n_tiles:
            raise ValueError(
                f"tile_counts must lie in [1, {self.n_tiles}]: "
                f"{self.tile_counts}")
        if self.attn_counts is not None:
            if len(self.attn_counts) != len(self.tile_counts):
                raise ValueError("attn_counts must cover every layer")
            if self.attn_tiles < 1:
                raise ValueError("attn_counts needs attn_tiles >= 1")
            if (min(self.attn_counts) < 1
                    or max(self.attn_counts) > self.attn_tiles):
                raise ValueError(
                    f"attn_counts must lie in [1, {self.attn_tiles}]: "
                    f"{self.attn_counts}")

    # ----- derived properties -----

    @property
    def n_layers(self) -> int:
        return len(self.tile_counts)

    @property
    def k_max(self) -> int:
        return max(self.tile_counts)

    @property
    def is_uniform(self) -> bool:
        return min(self.tile_counts) == max(self.tile_counts)

    @property
    def keep_fracs(self) -> np.ndarray:
        """Realized per-layer keep fractions (drives the mask-path
        oracle and the stats line)."""
        return np.asarray(self.tile_counts, np.float64) / self.n_tiles

    def flop_frac(self) -> float:
        """Aggregate FFN FLOP fraction vs dense (analytical)."""
        return float(sum(self.tile_counts)) / (self.n_layers * self.n_tiles)

    def counts_array(self):
        """[L] int32 device array — rides the layer scan as xs so each
        layer consumes its own count as a traced value."""
        return jnp.asarray(self.tile_counts, jnp.int32)

    # ----- attention-block budget (dual-budget plans) -----

    @property
    def has_attn(self) -> bool:
        """True when this plan carries a block-sparse attention budget."""
        return self.attn_counts is not None and self.attn_tiles > 0

    @property
    def attn_k_max(self) -> int:
        """Static max per-layer attention count (virtual-grid units) —
        the attention wiring's top-k selection width scales off it."""
        return max(self.attn_counts) if self.has_attn else 0

    @property
    def attn_keep_fracs(self) -> np.ndarray:
        if not self.has_attn:
            return np.ones(self.n_layers)
        return np.asarray(self.attn_counts, np.float64) / self.attn_tiles

    def attn_flop_frac(self) -> float:
        """Aggregate attention-score/value FLOP fraction vs dense
        (analytical, block-budget upper bound — the causal ramp's
        per-block floor of forced sink+diagonal blocks raises the
        realized fraction at short contexts; see
        benchmarks/prefill_speedup.attention_flop_fraction)."""
        if not self.has_attn:
            return 1.0
        return float(sum(self.attn_counts)) / (self.n_layers
                                               * self.attn_tiles)

    def attn_counts_array(self):
        """[L] int32 — rides the layer scan as the SECOND traced
        k_valid (alongside the FFN `counts_array`)."""
        return jnp.asarray(self.attn_counts, jnp.int32)

    def with_attention(self, attn_keep: float, attn_tiles: int,
                       importance=None) -> "SparsityPlan":
        """Attach a per-layer attention-block budget resolved from a
        global keep-fraction: Algorithm 1 waterfill when `importance`
        is supplied, else uniform, then the same largest-remainder
        pinning `tile_counts` uses. attn_keep >= 1 returns the plan
        unchanged (dense attention)."""
        if attn_keep >= 1.0 or attn_tiles < 1:
            return self
        if importance is not None:
            budgets = allocate_budgets(importance, attn_keep)
        else:
            budgets = uniform_budgets(self.n_layers, attn_keep)
        counts = budgets_to_tiles(budgets, attn_tiles)
        return dataclasses.replace(
            self, attn_counts=tuple(int(c) for c in counts),
            attn_tiles=int(attn_tiles), attn_keep=float(attn_keep))

    # ----- constructors -----

    @classmethod
    def uniform(cls, n_layers: int, n_tiles: int, tile: int, keep: float,
                shards: int = 1, name: Optional[str] = None
                ) -> "SparsityPlan":
        """Uniform plan under the legacy `k_tiles_for` rule:
        k = ceil(keep * n_tiles), rounded up to a shard multiple when
        balanced per-shard selection applies — so configs that only set
        cfg.ff.sparsity resolve to a bit-identical policy."""
        k = max(int(np.ceil(keep * n_tiles)), 1)
        if shards > 1 and n_tiles % shards == 0:
            per = max(int(np.ceil(k / shards)), 1)
            k = per * shards
        k = min(k, n_tiles)
        return cls(name=name or f"uniform-k{k}",
                   tile_counts=(k,) * n_layers, n_tiles=n_tiles,
                   tile=tile, keep=float(keep))

    @classmethod
    def uniform_counts(cls, n_layers: int, n_tiles: int, tile: int,
                       k_tiles: int, name: Optional[str] = None
                       ) -> "SparsityPlan":
        """Deprecation shim for bare `k_tiles=` integers."""
        k = min(max(int(k_tiles), 1), n_tiles)
        return cls(name=name or f"uniform-k{k}",
                   tile_counts=(k,) * n_layers, n_tiles=n_tiles,
                   tile=tile, keep=k / n_tiles)

    @classmethod
    def from_budgets(cls, budgets, n_tiles: int, tile: int,
                     keep: Optional[float] = None,
                     name: str = "layerwise") -> "SparsityPlan":
        """Per-layer keep-fractions (Algorithm 1 output) -> plan, with
        largest-remainder rounding so the realized total matches the
        global budget exactly."""
        budgets = np.asarray(budgets, np.float64)
        counts = budgets_to_tiles(budgets, n_tiles)
        return cls(name=name, tile_counts=tuple(int(c) for c in counts),
                   n_tiles=n_tiles, tile=tile,
                   keep=float(keep if keep is not None else budgets.mean()))

    @classmethod
    def from_importance(cls, importance, keep: float, n_tiles: int,
                        tile: int, name: str = "layerwise"
                        ) -> "SparsityPlan":
        """Algorithm 1 end-to-end: calibration importance + global
        keep-fraction -> waterfilled budgets -> integer tile counts."""
        budgets = allocate_budgets(importance, keep)
        return cls.from_budgets(budgets, n_tiles, tile, keep=keep,
                                name=name)

    # ----- derivation -----

    def with_tiles(self, n_tiles: int) -> "SparsityPlan":
        """Re-derive this plan for a different FFN width (tile grid).

        Uniform plans reapply the legacy ceil rule on `keep` — exactly
        what `k_tiles_for(cfg, d_ff=...)` produced, so the MoE shared
        expert keeps its pre-plan tile count under the compat shim.
        Layer-wise plans map per-layer keep fractions onto the new grid
        with the same largest-remainder correction."""
        if n_tiles == self.n_tiles:
            return self
        if self.is_uniform:
            derived = SparsityPlan.uniform(self.n_layers, n_tiles,
                                           self.tile, self.keep)
            derived = dataclasses.replace(derived,
                                          name=f"{self.name}@t{n_tiles}")
        else:
            derived = SparsityPlan.from_budgets(
                self.keep_fracs, n_tiles, self.tile, keep=self.keep,
                name=f"{self.name}@t{n_tiles}")
        # the attention budget is FFN-width independent: carry it over
        return dataclasses.replace(derived, attn_counts=self.attn_counts,
                                   attn_tiles=self.attn_tiles,
                                   attn_keep=self.attn_keep)


def calibrate_layer_importance(collect_attn_fn, samples, block_size: int):
    """Run `collect_attn_fn(sample) -> [L, H, T, T]` over a calibration
    set and average Eq. 23 per layer. Pure-python driver (offline)."""
    acc = None
    for x in samples:
        probs = collect_attn_fn(x)  # [L, H, T, T]
        s = jax.vmap(lambda p: nonsink_attention_mass(p, block_size))(probs)
        s = np.asarray(s, np.float64)
        acc = s if acc is None else acc + s
    return acc / max(len(samples), 1)
