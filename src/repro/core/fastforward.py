"""FastForward FFN module: predictor + tile-sparse FFN + compensator.

This is the drop-in replacement for a transformer FFN. All model
definitions route their FFN through `ff_apply_*` when cfg.ff.enabled.

DESIGN — SparsityPlan contract (the scheduler × kernel composition):

Every FLOP-reducing entry point below takes a `SparsityPlan`
(repro.core.scheduler) instead of the old `k_tiles=` scalar, so the
paper's layer-wise schedule (§3.4, Algorithm 1) reaches the gather
path and the batched Pallas kernel — not just the semantic mask path.

  * RESOLUTION — `resolve_plan(cfg, effort, importance)` builds the
    plan once per model: Algorithm 1 budgets -> integer per-layer tile
    counts (largest-remainder corrected) when importance is supplied
    and cfg.ff.layerwise_schedule is on; otherwise the uniform
    ceil(keep * n_tiles) rule the legacy `k_tiles_for` used, so
    configs that only set cfg.ff.sparsity resolve to a bit-identical
    policy. Named effort tiers ("dense" / "balanced" / "turbo") scale
    the global keep-fraction — the per-request serving knob.
  * PADDING — the static tile-id width is `plan.k_max`; the plan's [L]
    counts ride the layer scan as traced values (`k_valid`), so the
    scan stays shape-homogeneous while each layer consumes its own K.
    The gather path masks tiles past a layer's count; the Pallas
    kernels `pl.when`-skip them (per-row counts at decode carry
    per-request effort through one executable).
  * BATCHING-KEY MEMBERSHIP — the plan is a frozen hashable dataclass:
    the serving runtime takes it as a jit static argument, the
    scheduler admits only same-plan rows into one batched prefill
    call (alongside the density-homogeneous is_dense key), and warmup
    pre-compiles every (plan, width-bucket) pair — zero recompilation
    across mixed-effort traffic.
  * SHARDS — balanced per-shard tile selection needs a shard-multiple
    K; layer-wise counts fall back to global top-k selection (the
    prefix of a sharded selection is not the top-k_l), so sharded
    gathers keep uniform plans (shardmap path unchanged).

DESIGN — block-sparse prefill attention (dual-budget plans):

When cfg.ff.attn_sparsity > 0, `resolve_plan` attaches a SECOND budget
to the same SparsityPlan: per-layer kept-KV-block counts on a virtual
grid of cfg.ff.attn_tiles slots (same Algorithm-1 waterfill when
importance is supplied, same largest-remainder pinning, same frozen
jit-static contract). The effort tiers scale BOTH budgets — "dense"
disables both, "turbo" halves both — and the counts ride the layer
scan as a second traced `k_valid` next to the FFN counts.

  * SCORING PROXY (pooled QK) — per 128-token query block, each
    causally-valid KV block is scored by a mean-pooled dot product:
    q is mean-pooled over the block's query rows (and GQA head group),
    k over each KV block's key rows, and score[b, j] =
    mean_h <q̄_bh, k̄_bhj> / sqrt(dh). One [B, n_blocks] score matrix
    per layer — O(S·d) instead of the O(N·S·d) it gates.
  * THRESHOLD SEMANTICS — selection is top-k on the proxy scores, NOT
    a value threshold: the plan's virtual-grid count a_l maps to a
    per-row kept count c_b = clip(ceil(a_l * nv_b / attn_tiles),
    min(2, nv_b), nv_b) where nv_b is the row's causally-valid block
    count — so the kept FRACTION is the plan's a_l / attn_tiles,
    invariant to where the query block sits on the causal ramp. The
    sink block (block 0, attention-sink mass) and the diagonal block
    (the query block's own keys) are force-included via score bias —
    they are the two blocks the proxy is least reliable about and the
    paper's Eq. 23 importance analysis singles out. At a_l ==
    attn_tiles every valid block is kept and the masked XLA path is
    bit-identical to dense attention.
  * KERNEL CONTRACT — kernels/block_sparse_attention consumes the
    selection as scalar-prefetched block-id + count operands; dead
    selection slots (k >= c_b) are `pl.when`-skipped AND their slab
    DMA is index-map-clamped to the last live block (no bytes move).
    The XLA twin masks the same selection on the gathered view;
    interpret-mode tests pin kernel == online-softmax twin bitwise.

Deprecation shims: `k_tiles_for` survives for callers that only need
the uniform width, and plan-taking entry points accept a bare int
(wrapped via `SparsityPlan.uniform_counts`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig
from repro.nn.param import ParamSpec
from repro.core import predictor as P
from repro.core import compensator as C
from repro.core import sparse_ffn as S
from repro.core import scheduler as SCHED
from repro.core.scheduler import SparsityPlan


def fastforward_ffn_spec(cfg: ModelConfig, d_ff: Optional[int] = None,
                         dtype=None):
    """Spec for one layer's FFN (+ predictor/compensator when enabled)."""
    d_ff = d_ff or cfg.d_ff
    dtype = dtype or cfg.dtype
    sp = S.ffn_spec(cfg.d_model, d_ff, cfg.gated, dtype)
    if cfg.ff.enabled:
        sp["pred"] = P.predictor_spec(
            cfg.d_model, d_ff, cfg.ff.predictor_r(cfg.d_model), dtype)
        if cfg.ff.use_compensator:
            sp["comp"] = C.compensator_spec(
                cfg.d_model, cfg.ff.compensator_r(cfg.d_model), dtype)
    return sp


def _compensate(params, cfg: ModelConfig, x, y):
    if cfg.ff.enabled and cfg.ff.use_compensator and "comp" in params:
        return y + C.compensate(params["comp"], x)
    return y


def ff_dense(params, cfg: ModelConfig, x):
    return S.ffn_dense(params, x, cfg.act)


# ------------------------------------------------- full-sequence (mask)


def ff_masked_sequence(params, cfg: ModelConfig, x, keep_frac,
                       dense_first=None, dense_last=None, k_tiles=None):
    """Mask path over a full sequence, blocked at cfg.ff.block_size.

    x: [B, T, D] with T % block_size == 0. keep_frac: scalar (may be a
    traced per-layer budget from Algorithm 1). k_tiles: optional traced
    int32 tile count overriding keep_frac — a SparsityPlan's per-layer
    count, making this the exact mask-path oracle of the gather/kernel
    paths. Semantically faithful to the paper; FLOPs are NOT reduced
    (see gather path for that).
    """
    ff = cfg.ff
    B, T, D = x.shape
    N = ff.block_size
    nb = T // N
    xb = x.reshape(B, nb, N, D)
    scores = jax.nn.sigmoid(P.neuron_scores(params["pred"], xb))
    mask = S.neuron_mask_from_scores(scores, keep_frac, ff.tile,
                                     k_tiles=k_tiles)
    dense_first = ff.dense_first_block if dense_first is None else dense_first
    dense_last = ff.dense_last_block if dense_last is None else dense_last
    blk = jnp.arange(nb)
    force = jnp.zeros((nb,), bool)
    if dense_first:
        force = force | (blk == 0)
    if dense_last:
        force = force | (blk == nb - 1)
    mask = jnp.where(force[None, :, None], jnp.ones_like(mask), mask)
    y = S.ffn_masked(params, xb, mask[:, :, None, :], cfg.act)
    # forced blocks already run with an all-ones mask (== dense FFN), so
    # only the compensator needs per-block gating: it must not fire on
    # dense blocks (they have zero sparsification error). Gating the
    # compensator term — instead of re-running a full dense FFN pass
    # over every block just to overwrite the forced ones — halves the
    # mask-path FLOPs whenever use_compensator is on.
    if cfg.ff.enabled and cfg.ff.use_compensator and "comp" in params:
        comp = C.compensate(params["comp"], xb)
        y = y + jnp.where(force[None, :, None, None],
                          jnp.zeros_like(comp), comp)
    return y.reshape(B, T, D)


# ------------------------------------------------------ per-block gather


def ff_block_sparse(params, cfg: ModelConfig, x_block, plan,
                    shards: int = 1, is_dense=None, k_valid=None):
    """Gather path for one prompt block: x_block [B, N, D] -> [B, N, D].

    plan: SparsityPlan (static — its k_max is the jit tile-id width; a
    bare int k_tiles is accepted as a deprecation shim). k_valid:
    optional traced int32 — THIS layer's valid tile count (the plan's
    [L] counts riding the layer scan); None keeps all k_max tiles
    (uniform plans take this path, bit-identical to the pre-plan API).
    `is_dense` (traced bool) switches to the dense FFN via lax.cond —
    used for the always-dense first/last blocks inside the
    blockwise-prefill scan. A [B] is_dense VECTOR (rows from distinct
    requests, each at its own boundary) delegates to the per-row
    `ff_blocks_sparse` path.
    """
    if is_dense is not None and jnp.ndim(is_dense) == 1:
        return ff_blocks_sparse(params, cfg, x_block, plan, shards,
                                is_dense, k_valid=k_valid)
    ff = cfg.ff
    plan = _as_plan(cfg, plan, shards=shards)
    sel_shards = 1 if k_valid is not None else shards
    scores = jax.nn.sigmoid(P.neuron_scores(params["pred"], x_block))
    ids = S.balanced_topk_tiles(scores, plan.k_max, ff.tile,
                                sel_shards)                    # [B, K]

    def sparse(x):
        y = S.ffn_sparse_batched(params, x, ids, ff.tile, cfg.act,
                                 k_valid=k_valid)
        return _compensate(params, cfg, x, y)

    if is_dense is None:
        return sparse(x_block)
    return jax.lax.cond(is_dense,
                        lambda x: S.ffn_dense(params, x, cfg.act),
                        sparse, x_block)


def ff_blocks_sparse(params, cfg: ModelConfig, x_blocks, plan,
                     shards: int = 1, is_dense=None, k_valid=None):
    """Gather path for a batch of blocks from DISTINCT requests with
    per-row dense forcing: x_blocks [P, N, D], is_dense [P] bool.

    The batched-prefill twin of `ff_block_sparse`: each row selects its
    own `plan.k_max` tiles (batched kernel / gather path via
    ffn_sparse_batched; `k_valid` — traced scalar or [P] — limits how
    many of them actually compute, carrying the plan's per-layer
    counts), and the paper's dense-first/last semantics hold PER ROW —
    a row whose block is a sequence boundary takes the dense FFN while
    its batchmates stay sparse. Each path runs under a `lax.cond` on
    whether ANY row needs it, so an all-sparse steady-state batch never
    pays dense FLOPs (and an all-dense batch skips predictor + gather).
    The compensator fires only on sparse rows.
    """
    ff = cfg.ff
    plan = _as_plan(cfg, plan, shards=shards)
    sel_shards = 1 if k_valid is not None else shards

    def sparse(x):
        scores = jax.nn.sigmoid(P.neuron_scores(params["pred"], x))
        ids = S.balanced_topk_tiles(scores, plan.k_max, ff.tile,
                                    sel_shards)
        y = S.ffn_sparse_batched(params, x, ids, ff.tile, cfg.act,
                                 k_valid=k_valid)
        return _compensate(params, cfg, x, y)

    if is_dense is None:
        return sparse(x_blocks)
    zeros = lambda x: jnp.zeros(x.shape, x.dtype)
    y_sp = jax.lax.cond(jnp.any(~is_dense), sparse, zeros, x_blocks)
    y_dn = jax.lax.cond(jnp.any(is_dense),
                        lambda x: S.ffn_dense(params, x, cfg.act),
                        zeros, x_blocks)
    return jnp.where(is_dense[:, None, None], y_dn, y_sp)


def ff_decode_sparse(params, cfg: ModelConfig, x_tok, plan,
                     shards: int = 1, k_valid=None):
    """Decode-time sparsity (paper Table 3): block == current token.
    k_valid: traced scalar or [B] — per-row counts carry per-REQUEST
    effort tiers through the one batched decode executable."""
    return ff_block_sparse(params, cfg, x_tok, plan, shards,
                           k_valid=k_valid)


# ----------------------------------------------------------- scheduling


#: Named effort tiers — the per-request serving knob. Each maps the
#: config's global keep-fraction (1 - cfg.ff.sparsity) to the tier's:
#: "dense" disables sparsification (keep 1.0, still on the gather path
#: so it batches/compiles like any plan), "balanced" is the config
#: budget, "turbo" halves it (floor: 1 tile/layer via SparsityPlan).
EFFORT_TIERS = ("dense", "balanced", "turbo")


def effort_keep(cfg: ModelConfig, effort: Optional[str]) -> float:
    keep = 1.0 - cfg.ff.sparsity
    eff = effort or "balanced"
    if eff == "dense":
        return 1.0
    if eff == "balanced":
        return keep
    if eff == "turbo":
        return keep * 0.5
    raise ValueError(f"unknown effort tier {effort!r}; expected one of "
                     f"{EFFORT_TIERS}")


def effort_attn_keep(cfg: ModelConfig, effort: Optional[str]) -> float:
    """The attention-block twin of `effort_keep`: tiers scale the
    global attention keep-fraction (1 - cfg.ff.attn_sparsity) the same
    way they scale the FFN budget, so one tier governs BOTH."""
    keep = 1.0 - cfg.ff.attn_sparsity
    eff = effort or "balanced"
    if eff == "dense":
        return 1.0
    if eff == "balanced":
        return keep
    if eff == "turbo":
        return keep * 0.5
    raise ValueError(f"unknown effort tier {effort!r}; expected one of "
                     f"{EFFORT_TIERS}")


def resolve_plan(cfg: ModelConfig, effort: Optional[str] = None,
                 importance=None, d_ff: Optional[int] = None,
                 shards: int = 1) -> Optional[SparsityPlan]:
    """Resolve cfg (+ optional effort tier / calibration importance)
    into the SparsityPlan every FLOP-reducing path consumes.

    Returns None when FastForward is disabled. With `importance` (and
    cfg.ff.layerwise_schedule, the default) the per-layer counts come
    from Algorithm 1 under the tier's global budget; otherwise the
    uniform ceil rule — bit-identical to the legacy `k_tiles_for`
    scalar, which is the backward-compat shim for configs that only
    set cfg.ff.sparsity."""
    if not cfg.ff.enabled:
        return None
    d_ff = d_ff or cfg.d_ff or cfg.n_shared_experts * cfg.d_ff_expert
    if not d_ff:
        return None
    n_tiles = max(d_ff // cfg.ff.tile, 1)
    eff = effort or "balanced"
    keep = effort_keep(cfg, eff)
    if (importance is not None and cfg.ff.layerwise_schedule
            and eff != "dense"):
        plan = SparsityPlan.from_importance(
            importance, keep, n_tiles, cfg.ff.tile,
            name=f"{eff}-layerwise")
    else:
        plan = SparsityPlan.uniform(cfg.n_layers, n_tiles, cfg.ff.tile,
                                    keep, shards=shards, name=eff)
    # dual-budget: the same tier scales the attention-block budget
    # (dense tier -> attn_keep 1.0 -> with_attention no-ops, so the
    # plan stays the pre-dual-budget object and its executables)
    if cfg.ff.attn_sparsity > 0:
        plan = plan.with_attention(
            effort_attn_keep(cfg, eff), cfg.ff.attn_tiles,
            importance=(importance if cfg.ff.layerwise_schedule
                        else None))
    return plan


def _as_plan(cfg: ModelConfig, plan, shards: int = 1,
             d_ff: Optional[int] = None) -> Optional[SparsityPlan]:
    """Normalize a plan argument: None -> cfg-resolved uniform plan
    (compat shim), bare int k_tiles -> uniform_counts shim."""
    if plan is None:
        return resolve_plan(cfg, d_ff=d_ff, shards=shards)
    if isinstance(plan, (int, np.integer)):
        d_ff = d_ff or cfg.d_ff or cfg.n_shared_experts * cfg.d_ff_expert
        n_tiles = max(d_ff // cfg.ff.tile, 1)
        return SparsityPlan.uniform_counts(cfg.n_layers, n_tiles,
                                           cfg.ff.tile, int(plan))
    return plan


def decode_plan_setup(plans):
    """Shared decode-time plan plumbing for the model `decode_step`s.

    plans: tuple of resolved SparsityPlans (possibly empty/None-free —
    callers filter). Returns (sel_plan, counts_lp):
      * sel_plan — the plan whose k_max is the static tile-id width
        (max across the tuple; only k_max/tile are consumed);
      * counts_lp — [L, n_plans] int32 per-layer counts to ride the
        layer scan (each step gathers its row by traced plan_ids), or
        None on the single-uniform-plan fast path, which keeps the
        executable bit-identical to the pre-plan decode step.
    """
    if not plans:
        return None, None
    sel_plan = max(plans, key=lambda p: p.k_max)
    if len(plans) == 1 and plans[0].is_uniform:
        return sel_plan, None
    return sel_plan, jnp.asarray(
        np.stack([p.tile_counts for p in plans], axis=1), jnp.int32)


def decode_k_valid(k_row, plan_ids):
    """This layer's traced valid-count from a `decode_plan_setup`
    counts row: per-request [B] under traced plan_ids, scalar
    otherwise, None when no counts ride (uniform fast path)."""
    if k_row is None:
        return None
    if plan_ids is not None:
        return k_row[plan_ids]
    return k_row[0]


def layer_budgets(cfg: ModelConfig, importance=None):
    """Per-layer keep fractions: Algorithm 1 when enabled+calibrated,
    else uniform (1 - sparsity). (Mask-path budgets; the gather path
    consumes the same schedule as SparsityPlan integer counts.)"""
    keep = 1.0 - cfg.ff.sparsity
    if cfg.ff.layerwise_schedule and importance is not None:
        return SCHED.allocate_budgets(importance, keep)
    return SCHED.uniform_budgets(cfg.n_layers, keep)


def k_tiles_for(cfg: ModelConfig, d_ff: Optional[int] = None,
                shards: int = 1) -> int:
    """DEPRECATED shim: static uniform tile count (the pre-SparsityPlan
    scalar). Equals resolve_plan(cfg, d_ff=..., shards=...).k_max —
    kept for callers that only need the uniform width."""
    d_ff = d_ff or cfg.d_ff
    n_tiles = d_ff // cfg.ff.tile
    keep = 1.0 - cfg.ff.sparsity
    k = max(int(np.ceil(keep * n_tiles)), 1)
    if shards > 1 and n_tiles % shards == 0:
        per = max(int(np.ceil(k / shards)), 1)
        k = per * shards
    return min(k, n_tiles)
