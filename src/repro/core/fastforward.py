"""FastForward FFN module: predictor + tile-sparse FFN + compensator.

This is the drop-in replacement for a transformer FFN. All model
definitions route their FFN through `ff_apply_*` when cfg.ff.enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig
from repro.nn.param import ParamSpec
from repro.core import predictor as P
from repro.core import compensator as C
from repro.core import sparse_ffn as S
from repro.core import scheduler as SCHED


def fastforward_ffn_spec(cfg: ModelConfig, d_ff: Optional[int] = None,
                         dtype=None):
    """Spec for one layer's FFN (+ predictor/compensator when enabled)."""
    d_ff = d_ff or cfg.d_ff
    dtype = dtype or cfg.dtype
    sp = S.ffn_spec(cfg.d_model, d_ff, cfg.gated, dtype)
    if cfg.ff.enabled:
        sp["pred"] = P.predictor_spec(
            cfg.d_model, d_ff, cfg.ff.predictor_r(cfg.d_model), dtype)
        if cfg.ff.use_compensator:
            sp["comp"] = C.compensator_spec(
                cfg.d_model, cfg.ff.compensator_r(cfg.d_model), dtype)
    return sp


def _compensate(params, cfg: ModelConfig, x, y):
    if cfg.ff.enabled and cfg.ff.use_compensator and "comp" in params:
        return y + C.compensate(params["comp"], x)
    return y


def ff_dense(params, cfg: ModelConfig, x):
    return S.ffn_dense(params, x, cfg.act)


# ------------------------------------------------- full-sequence (mask)


def ff_masked_sequence(params, cfg: ModelConfig, x, keep_frac,
                       dense_first=None, dense_last=None):
    """Mask path over a full sequence, blocked at cfg.ff.block_size.

    x: [B, T, D] with T % block_size == 0. keep_frac: scalar (may be a
    traced per-layer budget from Algorithm 1). Semantically faithful to
    the paper; FLOPs are NOT reduced (see gather path for that).
    """
    ff = cfg.ff
    B, T, D = x.shape
    N = ff.block_size
    nb = T // N
    xb = x.reshape(B, nb, N, D)
    scores = jax.nn.sigmoid(P.neuron_scores(params["pred"], xb))
    mask = S.neuron_mask_from_scores(scores, keep_frac, ff.tile)
    dense_first = ff.dense_first_block if dense_first is None else dense_first
    dense_last = ff.dense_last_block if dense_last is None else dense_last
    blk = jnp.arange(nb)
    force = jnp.zeros((nb,), bool)
    if dense_first:
        force = force | (blk == 0)
    if dense_last:
        force = force | (blk == nb - 1)
    mask = jnp.where(force[None, :, None], jnp.ones_like(mask), mask)
    y = S.ffn_masked(params, xb, mask[:, :, None, :], cfg.act)
    # forced blocks already run with an all-ones mask (== dense FFN), so
    # only the compensator needs per-block gating: it must not fire on
    # dense blocks (they have zero sparsification error). Gating the
    # compensator term — instead of re-running a full dense FFN pass
    # over every block just to overwrite the forced ones — halves the
    # mask-path FLOPs whenever use_compensator is on.
    if cfg.ff.enabled and cfg.ff.use_compensator and "comp" in params:
        comp = C.compensate(params["comp"], xb)
        y = y + jnp.where(force[None, :, None, None],
                          jnp.zeros_like(comp), comp)
    return y.reshape(B, T, D)


# ------------------------------------------------------ per-block gather


def ff_block_sparse(params, cfg: ModelConfig, x_block, k_tiles: int,
                    shards: int = 1, is_dense=None):
    """Gather path for one prompt block: x_block [B, N, D] -> [B, N, D].

    k_tiles is static (jit shape). `is_dense` (traced bool) switches to
    the dense FFN via lax.cond — used for the always-dense first/last
    blocks inside the blockwise-prefill scan. A [B] is_dense VECTOR
    (rows from distinct requests, each at its own boundary) delegates
    to the per-row `ff_blocks_sparse` path.
    """
    if is_dense is not None and jnp.ndim(is_dense) == 1:
        return ff_blocks_sparse(params, cfg, x_block, k_tiles, shards,
                                is_dense)
    ff = cfg.ff
    scores = jax.nn.sigmoid(P.neuron_scores(params["pred"], x_block))
    ids = S.balanced_topk_tiles(scores, k_tiles, ff.tile, shards)  # [B, K]

    def sparse(x):
        y = S.ffn_sparse_batched(params, x, ids, ff.tile, cfg.act)
        return _compensate(params, cfg, x, y)

    if is_dense is None:
        return sparse(x_block)
    return jax.lax.cond(is_dense,
                        lambda x: S.ffn_dense(params, x, cfg.act),
                        sparse, x_block)


def ff_blocks_sparse(params, cfg: ModelConfig, x_blocks, k_tiles: int,
                     shards: int = 1, is_dense=None):
    """Gather path for a batch of blocks from DISTINCT requests with
    per-row dense forcing: x_blocks [P, N, D], is_dense [P] bool.

    The batched-prefill twin of `ff_block_sparse`: each row selects its
    own K tiles (batched kernel / gather path via ffn_sparse_batched),
    and the paper's dense-first/last semantics hold PER ROW — a row
    whose block is a sequence boundary takes the dense FFN while its
    batchmates stay sparse. Each path runs under a `lax.cond` on
    whether ANY row needs it, so an all-sparse steady-state batch never
    pays dense FLOPs (and an all-dense batch skips predictor + gather).
    The compensator fires only on sparse rows.
    """
    ff = cfg.ff

    def sparse(x):
        scores = jax.nn.sigmoid(P.neuron_scores(params["pred"], x))
        ids = S.balanced_topk_tiles(scores, k_tiles, ff.tile, shards)
        y = S.ffn_sparse_batched(params, x, ids, ff.tile, cfg.act)
        return _compensate(params, cfg, x, y)

    if is_dense is None:
        return sparse(x_blocks)
    zeros = lambda x: jnp.zeros(x.shape, x.dtype)
    y_sp = jax.lax.cond(jnp.any(~is_dense), sparse, zeros, x_blocks)
    y_dn = jax.lax.cond(jnp.any(is_dense),
                        lambda x: S.ffn_dense(params, x, cfg.act),
                        zeros, x_blocks)
    return jnp.where(is_dense[:, None, None], y_dn, y_sp)


def ff_decode_sparse(params, cfg: ModelConfig, x_tok, k_tiles: int,
                     shards: int = 1):
    """Decode-time sparsity (paper Table 3): block == current token."""
    return ff_block_sparse(params, cfg, x_tok, k_tiles, shards)


# ----------------------------------------------------------- scheduling


def layer_budgets(cfg: ModelConfig, importance=None):
    """Per-layer keep fractions: Algorithm 1 when enabled+calibrated,
    else uniform (1 - sparsity)."""
    keep = 1.0 - cfg.ff.sparsity
    if cfg.ff.layerwise_schedule and importance is not None:
        return SCHED.allocate_budgets(importance, keep)
    return SCHED.uniform_budgets(cfg.n_layers, keep)


def k_tiles_for(cfg: ModelConfig, d_ff: Optional[int] = None,
                shards: int = 1) -> int:
    """Static tile count for the gather path (uniform schedule)."""
    d_ff = d_ff or cfg.d_ff
    n_tiles = d_ff // cfg.ff.tile
    keep = 1.0 - cfg.ff.sparsity
    k = max(int(np.ceil(keep * n_tiles)), 1)
    if shards > 1 and n_tiles % shards == 0:
        per = max(int(np.ceil(k / shards)), 1)
        k = per * shards
    return min(k, n_tiles)
