"""Expert neuron predictor (paper §3.2).

A lightweight attention-pooling module: a trainable query vector attends
over the block's token embeddings (keys == values == tokens), and a
two-layer MLP with bottleneck r = d_model/16 (rounded up to a power of
two) maps the pooled representation to one score per FFN neuron.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec


def predictor_spec(d_model: int, d_ff: int, r: int, dtype=jnp.float32):
    return {
        "q_pred": ParamSpec((d_model,), ("embed",), init="normal", scale=0.02, dtype=dtype),
        "w1": ParamSpec((d_model, r), ("embed", None), dtype=dtype),
        "w2": ParamSpec((r, d_ff), (None, "mlp"), dtype=dtype),
    }


def pool_block(params, x_block):
    """Eq. 12: a = softmax(q_pred X^T / sqrt(d)) X.

    x_block: [..., N, D] -> pooled [..., D].
    """
    d = x_block.shape[-1]
    logits = jnp.einsum("...nd,d->...n", x_block.astype(jnp.float32),
                        params["q_pred"].astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(d))
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...n,...nd->...d", w, x_block.astype(jnp.float32))


def neuron_scores(params, x_block):
    """Eq. 13: s = ReLU(a W1) W2 -> [..., d_ff] neuron logits."""
    a = pool_block(params, x_block)
    h = jax.nn.relu(a @ params["w1"].astype(jnp.float32))
    return h @ params["w2"].astype(jnp.float32)


# ------------------------------------------------ GRIFFIN-style labels


def activation_labels(hidden, keep_frac: float = 0.5):
    """Paper §3.2 training targets from dense FFN hidden activations.

    hidden: [..., N, F] (post-activation, pre-down-proj). Returns
    (labels[..., F] in {0,1}, weights[..., F]): top `keep_frac` neurons by
    L2 norm over the block are positive; positive weights decay 32/16/8/
    4/2 over successive top-20%-of-positives bands; negatives weight 1.
    """
    norms = jnp.linalg.norm(hidden.astype(jnp.float32), axis=-2)  # [..., F]
    F = norms.shape[-1]
    n_pos = max(int(round(F * keep_frac)), 1)
    # rank 0 = largest norm
    order = jnp.argsort(-norms, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    labels = (ranks < n_pos).astype(jnp.float32)
    band = jnp.clip(ranks * 5 // max(n_pos, 1), 0, 4)  # 20% bands of positives
    pos_w = jnp.float32(32.0) / (2.0 ** band.astype(jnp.float32))  # 32,16,8,4,2
    weights = jnp.where(labels > 0, pos_w, 1.0)
    return labels, weights


def predictor_loss(params, x_block, hidden, keep_frac: float = 0.5):
    """Weighted BCE (Eq. 19) against activation-derived labels."""
    labels, weights = activation_labels(hidden, keep_frac)
    s = neuron_scores(params, x_block)
    logp = jax.nn.log_sigmoid(s)
    lognp = jax.nn.log_sigmoid(-s)
    bce = -(labels * logp + (1.0 - labels) * lognp)
    return jnp.mean(jnp.sum(weights * bce, axis=-1) / jnp.sum(weights, axis=-1))
