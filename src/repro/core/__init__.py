"""FastForward core: the paper's contribution as composable JAX modules.

- predictor:    expert neuron predictor (§3.2)
- compensator:  error compensation network (§3.3)
- scheduler:    layerwise sparsity schedule, Algorithm 1 (§3.4)
- sparse_ffn:   tile-sparse gated FFN (mask + gather paths)
- fastforward:  integrated FFN module used by all model definitions
- distill:      predictor/compensator training (weighted BCE + MSE)
"""
from repro.core.fastforward import (  # noqa: F401
    fastforward_ffn_spec,
    ff_dense,
    ff_masked_sequence,
    ff_block_sparse,
    ff_decode_sparse,
    layer_budgets,
    k_tiles_for,
    resolve_plan,
    EFFORT_TIERS,
)
from repro.core.scheduler import SparsityPlan  # noqa: F401
