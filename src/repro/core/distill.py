"""FastForward distillation: train predictor (weighted BCE) and error
compensator (layerwise MSE distillation, two-phase: oracle -> predicted
masks), per paper §3.2–§3.3.

Operates layer-by-layer on FFN inputs harvested from a teacher forward
pass; optimizer is plain Adam on the predictor/compensator params only
(the FFN weights are frozen).
"""
from __future__ import annotations

import functools
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig
from repro.core import predictor as P
from repro.core import compensator as C
from repro.core import sparse_ffn as S
from repro.training.optimizer import adam_init, adam_update


def oracle_mask(params_ffn, x_block, keep_frac: float, tile: int, act: str):
    """True top-K tile mask by dense activation norms (paper's oracle).
    Tile aggregation uses SQUARED norms: dropping tile t costs
    ~sum_j||h_j||^2 of its neurons, so norm^2-mass is error-optimal."""
    h = S.ffn_hidden(params_ffn, x_block, act)            # [..., N, F]
    norms = jnp.sum(h.astype(jnp.float32) ** 2, axis=-2)
    return S.neuron_mask_from_scores(norms, keep_frac, tile), h


def predicted_mask(params, x_block, keep_frac: float, tile: int):
    # sigmoid before tile aggregation: expected active-neuron mass per
    # tile (robust to outlier logits; see DESIGN.md tile adaptation)
    scores = jax.nn.sigmoid(P.neuron_scores(params["pred"], x_block))
    return S.neuron_mask_from_scores(scores, keep_frac, tile)


@functools.partial(jax.jit, static_argnames=("keep_frac", "tile", "act"))
def distill_step(train_params, opt_state, ffn_params, x_block, step,
                 *, keep_frac: float, tile: float, act: str, lr=1e-3,
                 oracle_phase=False):
    """One distillation step on a batch of blocks x_block [B, N, D].

    train_params = {"pred": ..., "comp": ...}; ffn_params frozen.
    Returns (train_params, opt_state, metrics).
    """

    def loss_fn(tp):
        h = S.ffn_hidden(ffn_params, x_block, act)
        labels_loss = P.predictor_loss(tp["pred"], x_block, h, keep_frac)
        # mask for the compensator target
        norms = jnp.sum(h.astype(jnp.float32) ** 2, axis=-2)
        m_oracle = S.neuron_mask_from_scores(norms, keep_frac, tile)
        scores = jax.nn.sigmoid(
            P.neuron_scores(jax.lax.stop_gradient(tp["pred"]), x_block))
        m_pred = S.neuron_mask_from_scores(scores, keep_frac, tile)
        mask = jnp.where(oracle_phase, m_oracle, m_pred)
        y_dense = S.ffn_dense(ffn_params, x_block, act)
        y_sparse = S.ffn_masked(ffn_params, x_block, mask[..., None, :], act)
        comp_loss = C.compensator_loss(tp["comp"], x_block, y_sparse, y_dense)
        return labels_loss + comp_loss, (labels_loss, comp_loss)

    (loss, (pl, cl)), grads = jax.value_and_grad(loss_fn, has_aux=True)(train_params)
    train_params, opt_state = adam_update(train_params, grads, opt_state,
                                          step, lr=lr)
    return train_params, opt_state, {"loss": loss, "pred_bce": pl, "comp_mse": cl}


def train_fastforward_layer(ffn_params, blocks: Iterator, cfg: ModelConfig,
                            key, steps: int = 200, warmup_frac: float = 0.3,
                            lr: float = 1e-3):
    """Train predictor+compensator for one layer on an iterator of
    [B, N, D] FFN-input blocks. Two-phase per paper: first
    `warmup_frac*steps` with oracle masks, then predicted masks."""
    from repro.core.fastforward import fastforward_ffn_spec
    from repro.nn.param import init_params

    d_ff = ffn_params["wu"].shape[1]
    spec = fastforward_ffn_spec(cfg, d_ff=d_ff)
    full = init_params({k: v for k, v in spec.items() if k in ("pred", "comp")}, key)
    tp = {"pred": full["pred"], "comp": full["comp"]}
    opt = adam_init(tp)
    keep = 1.0 - cfg.ff.sparsity
    warm = int(steps * warmup_frac)
    hist = []
    for i in range(steps):
        x_block = next(blocks)
        tp, opt, m = distill_step(
            tp, opt, ffn_params, x_block, jnp.int32(i),
            keep_frac=keep, tile=cfg.ff.tile, act=cfg.act, lr=lr,
            oracle_phase=(i < warm))
        hist.append({k: float(v) for k, v in m.items()})
    return tp, hist


def predictor_agreement(train_params, ffn_params, x_block, keep_frac, tile,
                        act: str = "silu"):
    """Fraction of oracle tiles the trained predictor recovers (recall)."""
    m_o, _ = oracle_mask(ffn_params, x_block, keep_frac, tile, act)
    m_p = predicted_mask(train_params, x_block, keep_frac, tile)
    inter = jnp.sum(m_o * m_p, axis=-1)
    return jnp.mean(inter / jnp.maximum(jnp.sum(m_o, axis=-1), 1.0))
