"""Tile-sparse gated FFN — the compute core of FastForward.

TPU adaptation (DESIGN.md §3): neurons are sparsified in tiles of 128
(MXU lane width). Two execution paths, cross-checked in tests:

  * mask path   — multiplicative neuron mask; differentiable; used for
                  training/distillation and for full-sequence fidelity
                  experiments (supports per-layer budgets, Algorithm 1).
  * gather path — tile-index gather of W_gate/W_up rows and W_down
                  columns; static K tiles; real FLOP reduction; used by
                  the serving engine and dry-runs. The Pallas kernel in
                  repro.kernels.sparse_ffn is its TPU twin.

Balanced per-shard top-K: with d_ff sharded over `model`, scores are
reshaped to [shards, tiles_per_shard] and top-(K/shards) is taken per
shard, so the weight gather never crosses a shard boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec
from repro.nn.layers import ACTIVATIONS, swiglu


def ffn_spec(d_model: int, d_ff: int, gated: bool = True, dtype=jnp.float32):
    sp = {
        "wu": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "wd": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }
    if gated:
        sp["wg"] = ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype=dtype)
    return sp


def ffn_hidden(params, x, act: str = "silu"):
    """Post-activation hidden h: [..., F] (used for labels + mask path)."""
    up = jnp.einsum("...d,df->...f", x, params["wu"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if "wg" in params:
        gate = jnp.einsum("...d,df->...f", x, params["wg"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
        return swiglu(gate, up)
    return ACTIVATIONS[act](up.astype(jnp.float32)).astype(x.dtype)


def ffn_dense(params, x, act: str = "silu"):
    h = ffn_hidden(params, x, act)
    y = jnp.einsum("...f,fd->...d", h, params["wd"],
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------- mask path


def tile_scores(scores, tile: int):
    """Neuron scores [..., F] -> tile scores [..., F/tile]."""
    F = scores.shape[-1]
    return scores.reshape(scores.shape[:-1] + (F // tile, tile)).sum(-1)


def neuron_mask_from_scores(scores, keep_frac, tile: int, k_tiles=None):
    """Dynamic-threshold tile mask (supports traced per-layer budgets).

    scores: [..., F]; keep_frac: scalar (may be traced). Returns a
    {0,1} mask [..., F] keeping the top ceil(keep_frac * n_tiles) tiles.
    k_tiles: optional traced int32 tile count that OVERRIDES keep_frac
    — the mask-path twin of a SparsityPlan's per-layer counts, exact
    where ceil(keep * n_tiles) could drift by one tile in float.
    """
    # Hard top-k selection: not differentiable by construction (the
    # predictor is trained via its own BCE objective, paper §3.2), so the
    # whole mask is a stop_gradient region.
    ts = jax.lax.stop_gradient(tile_scores(scores, tile))  # [..., n_tiles]
    n_tiles = ts.shape[-1]
    if k_tiles is not None:
        k = jnp.clip(jnp.asarray(k_tiles, jnp.int32), 1, n_tiles)
    else:
        k = jnp.clip(jnp.ceil(keep_frac * n_tiles).astype(jnp.int32),
                     1, n_tiles)
    sorted_ts = jnp.sort(ts, axis=-1)                   # ascending
    thresh = jnp.take_along_axis(
        sorted_ts, (n_tiles - k) * jnp.ones(ts.shape[:-1] + (1,), jnp.int32),
        axis=-1)
    tmask = (ts >= thresh).astype(scores.dtype)         # [..., n_tiles]
    return jnp.repeat(tmask, tile, axis=-1)


def ffn_masked(params, x, mask, act: str = "silu"):
    """Mask path: h * mask before down-projection. mask: [..., F]
    broadcastable over the token axis of x [..., N, D]."""
    h = ffn_hidden(params, x, act)
    h = h * mask.astype(h.dtype)
    y = jnp.einsum("...f,fd->...d", h, params["wd"],
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------- gather path


def balanced_topk_tiles(scores, k_tiles: int, tile: int, shards: int = 1):
    """Tile ids under balanced per-shard selection.

    scores: [..., F]. Returns int32 [..., k_tiles] of *global* tile ids;
    exactly k_tiles/shards tiles come from each shard's range.
    """
    ts = tile_scores(scores, tile)                      # [..., n_tiles]
    n_tiles = ts.shape[-1]
    if shards > 1 and n_tiles % shards == 0 and k_tiles % shards == 0:
        tps, kps = n_tiles // shards, k_tiles // shards
        grouped = ts.reshape(ts.shape[:-1] + (shards, tps))
        _, idx = jax.lax.top_k(grouped, kps)            # [..., shards, kps]
        base = (jnp.arange(shards) * tps)[..., :, None]
        return (idx + base).reshape(ts.shape[:-1] + (k_tiles,)).astype(jnp.int32)
    _, idx = jax.lax.top_k(ts, k_tiles)
    return idx.astype(jnp.int32)


def ffn_sparse_gather(params, x_block, tile_ids, tile: int, act: str = "silu",
                      k_valid=None):
    """Gather path for ONE block: x_block [N, D], tile_ids [K] -> [N, D].

    FLOPs = (K*tile/d_ff) of the dense FFN. The gathered tiles are
    consumed in [K, tile] layout — the einsums contract over (k, t)
    directly, so no [D, K*tile] reshape copies are materialized.
    (A single take over a concatenated [D, 2*n_tiles, tile] wg|wu view
    was measured ~1.8x SLOWER on XLA-CPU at tinyllama scale: the
    concat materializes the full [D, 2F] weights per layer call,
    memory traffic that dwarfs the take it saves. Two takes it is.)

    k_valid: optional traced int32 scalar — only the FIRST k_valid of
    the K selected tiles contribute (tile_ids are top-k ordered, so the
    prefix IS the top-k_valid selection). This is how a layer-wise
    SparsityPlan consumes fewer tiles on some layers while the scan
    over layers keeps one static K; invalid tiles are masked out of the
    hidden activations before the down-projection.
    """
    D, F = params["wu"].shape
    n_tiles = F // tile
    d = jnp.take(params["wd"].reshape(n_tiles, tile, D), tile_ids, axis=0)
    if "wg" in params:
        g = jnp.take(params["wg"].reshape(D, n_tiles, tile), tile_ids,
                     axis=1)                              # [D, K, tile]
        u = jnp.take(params["wu"].reshape(D, n_tiles, tile), tile_ids,
                     axis=1)
        hg = jnp.einsum("nd,dkt->nkt", x_block, g,
                        preferred_element_type=jnp.float32
                        ).astype(x_block.dtype)
        hu = jnp.einsum("nd,dkt->nkt", x_block, u,
                        preferred_element_type=jnp.float32
                        ).astype(x_block.dtype)
        h = swiglu(hg, hu)
    else:
        u = jnp.take(params["wu"].reshape(D, n_tiles, tile), tile_ids,
                     axis=1)                              # [D, K, tile]
        up = jnp.einsum("nd,dkt->nkt", x_block, u,
                        preferred_element_type=jnp.float32)
        h = ACTIVATIONS[act](up).astype(x_block.dtype)
    if k_valid is not None:
        K = tile_ids.shape[-1]
        valid = jnp.arange(K) < jnp.asarray(k_valid, jnp.int32)
        h = h * valid[None, :, None].astype(h.dtype)
    y = jnp.einsum("nkt,ktd->nd", h, d,
                   preferred_element_type=jnp.float32)
    return y.astype(x_block.dtype)


def ffn_sparse_batched(params, x_blocks, tile_ids, tile: int,
                       act: str = "silu", k_valid=None):
    """x_blocks [B, N, D], tile_ids [B, K] -> [B, N, D] — every row
    selects its own tiles (the multi-request prefill hot path).

    Gated-silu FFNs dispatch through repro.kernels.sparse_ffn.ops:
    TPU hits the batched Pallas kernel (grid (B, n_token_blocks, K),
    per-row scalar-prefetched tile ids), CPU keeps the reshape-free XLA
    path. Other activations fall back to the vmapped gather path.

    k_valid: optional traced int32 scalar or [B] vector — per-row valid
    tile count (<= K). Rows consume only their first k_valid selected
    tiles: the Pallas kernel `pl.when`-skips the dead grid steps (real
    FLOP skip on TPU), the XLA paths mask the hidden tiles. This is
    the mechanism behind per-layer SparsityPlan counts (scalar, riding
    the layer scan) and per-request effort tiers at decode ([B], from
    traced plan ids)."""
    if k_valid is not None:
        k_valid = jnp.broadcast_to(jnp.asarray(k_valid, jnp.int32),
                                   x_blocks.shape[:1])
    if "wg" in params and act == "silu":
        from repro.kernels.sparse_ffn import ops
        y = ops.sparse_ffn_batched_op(x_blocks, params["wg"], params["wu"],
                                      params["wd"], tile_ids, tile=tile,
                                      k_valid=k_valid)
        return y.astype(x_blocks.dtype)
    if k_valid is None:
        return jax.vmap(
            lambda xb, ids: ffn_sparse_gather(params, xb, ids, tile, act)
        )(x_blocks, tile_ids)
    return jax.vmap(
        lambda xb, ids, kv: ffn_sparse_gather(params, xb, ids, tile, act,
                                              k_valid=kv)
    )(x_blocks, tile_ids, k_valid)


def ffn_block_sparse_shardmap(params, cfg, x_block, k_tiles: int, mesh):
    """shard_map gather path (EXPERIMENTS.md §Perf): every weight gather
    stays local to its model shard; only the [B,N,D] partial FFN output
    crosses the ICI (psum), instead of GSPMD all-gathering weight tiles.

    x_block: [B, N, D] (batch sharded over the data axes); params: one
    layer's FastForward FFN params with d_ff sharded over "model".
    """
    import functools
    from jax.sharding import PartitionSpec as P
    from repro.core import predictor as PR
    from repro.core import compensator as C

    tile = cfg.ff.tile
    act = cfg.act
    shards = mesh.shape["model"]
    k_local = max(k_tiles // shards, 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if batch_axes else None

    # predictor pooling + bottleneck are tiny and replicated; only the
    # [r, F] output projection is sharded on F.
    a = PR.pool_block(params["pred"], x_block)                 # [B, D] f32
    h1 = jax.nn.relu(a @ params["pred"]["w1"].astype(jnp.float32))

    def local_fn(wg, wu, wd, w2, h1_, x):
        scores = jax.nn.sigmoid(h1_ @ w2.astype(jnp.float32))  # [B, F_loc]
        ids = balanced_topk_tiles(scores, k_local, tile, shards=1)
        y = ffn_sparse_batched({"wg": wg, "wu": wu, "wd": wd}, x, ids,
                               tile, act)
        return jax.lax.psum(y, "model")

    y = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, "model"), P(None, "model"), P("model", None),
                  P(None, "model"), P(bspec, None), P(bspec, None, None)),
        out_specs=P(bspec, None, None),
        check_vma=False,
    )(params["wg"], params["wu"], params["wd"], params["pred"]["w2"],
      h1, x_block)
    y = y.astype(x_block.dtype)
    if cfg.ff.use_compensator and "comp" in params:
        y = y + C.compensate(params["comp"], x_block)
    return y


def mask_from_tile_ids(tile_ids, n_tiles: int, tile: int):
    """Tile ids -> {0,1} neuron mask (for cross-checking the two paths)."""
    onehot = jax.nn.one_hot(tile_ids, n_tiles, dtype=jnp.float32).sum(-2)
    onehot = jnp.clip(onehot, 0.0, 1.0)
    return jnp.repeat(onehot, tile, axis=-1)
