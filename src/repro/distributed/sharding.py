"""Logical-axis sharding: ParamSpec.axes -> PartitionSpec via rules.

Rules map logical axis names to mesh axis names. A logical axis is only
sharded when the dimension is divisible by the mesh axis size (e.g.
whisper-tiny's 6 heads stay replicated on a 16-way model axis).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.param import ParamSpec, is_spec

# Default logical -> mesh axis rules. "batch" resolves to every
# data-parallel axis present in the mesh (("pod","data") or ("data",)).
DEFAULT_RULES = {
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "mlp_expert": "model",
    "expert": "expert_axis",   # resolved per-config: "data" | None
    "layers": None,
    "batch": "batch_axes",
    "seq": None,
    "kv_seq": "model",         # decode-time sequence-sharded KV
    "state": None,
}


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def resolve_axis(name: Optional[str], dim: int, mesh: Mesh, rules=None,
                 expert_axis=None):
    """One logical axis -> mesh axis (or None), honoring divisibility."""
    if name is None:
        return None
    rules = rules or DEFAULT_RULES
    target = rules.get(name, None)
    if target == "batch_axes":
        axes = data_axes(mesh)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        return axes if axes and dim % size == 0 else None
    if target == "expert_axis":
        target = expert_axis
    if target is None or target not in mesh.axis_names:
        return None
    return target if dim % mesh.shape[target] == 0 else None


def pspec_for(axes, shape, mesh: Mesh, rules=None, expert_axis=None):
    entries = [resolve_axis(a, d, mesh, rules, expert_axis)
               for a, d in zip(axes, shape)]
    # A mesh axis may appear at most once in a PartitionSpec.
    seen = set()
    clean = []
    for e in entries:
        flat = e if isinstance(e, tuple) else ((e,) if e else ())
        if any(f in seen for f in flat):
            clean.append(None)
        else:
            seen.update(flat)
            clean.append(e)
    return P(*clean)


def param_shardings(specs, mesh: Mesh, rules=None, expert_axis=None):
    """ParamSpec tree -> NamedSharding tree."""

    def mk(s: ParamSpec):
        return NamedSharding(mesh, pspec_for(s.axes, s.shape, mesh, rules,
                                             expert_axis))

    return jax.tree.map(mk, specs, is_leaf=is_spec)


def constrain(x, axes, mesh: Optional[Mesh] = None, rules=None,
              expert_axis=None):
    """Best-effort activation sharding constraint; no-op without a mesh."""
    if mesh is None:
        try:
            mesh = jax.sharding.get_abstract_mesh()
        except Exception:
            return x
        if mesh is None or not mesh.axis_names or mesh.empty:
            return x
    spec = pspec_for(axes, x.shape, mesh, rules, expert_axis)
    return jax.lax.with_sharding_constraint(x, spec)
