"""shard_map collectives: flash-decode over sequence-sharded KV caches.

For decode shapes the KV cache is sharded along the SEQUENCE axis (kv
head counts are below the 16-way model axis, and long_500k has batch=1,
so neither batch nor heads can absorb the model axis). GSPMD's default
strategy is to all-gather K and V per layer — O(S * kv * dh) bytes per
chip. This shard_map computes local softmax statistics per shard and
combines them with a log-sum-exp psum — O(H * dh) bytes per chip, a
~1000x collective-byte reduction at 32K context (see EXPERIMENTS.md
§Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _local_decode_attn(q, k, v, position, axis_names):
    """Per-shard body. q: [B,1,H,dh] (replicated over axis_names);
    k,v: [B,S_local,Kv,dh] (local shard of the sequence axis)."""
    B, _, H, dh = q.shape
    S_local = k.shape[1]
    Kv = k.shape[2]
    rep = H // Kv

    shard = jax.lax.axis_index(axis_names)
    offset = shard * S_local
    kj = offset + jnp.arange(S_local)
    valid = (kj <= position)[None, None, None, :]          # [1,1,1,S]

    qg = q.reshape(B, Kv, rep, dh)                         # squeeze T=1
    s = jnp.einsum("bgrk,bsgk->bgrs", qg, k,
                   preferred_element_type=jnp.float32)     # [B,Kv,rep,S]
    s = s / jnp.sqrt(jnp.float32(dh))
    s = jnp.where(valid, s, NEG_INF)
    m_loc = jnp.max(s, axis=-1)                            # [B,Kv,rep]
    m_glob = jax.lax.pmax(m_loc, axis_names)
    p = jnp.exp(s - m_glob[..., None])
    l_loc = jnp.sum(p, axis=-1)                            # [B,Kv,rep]
    acc_loc = jnp.einsum("bgrs,bsgk->bgrk", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
    l_glob = jax.lax.psum(l_loc, axis_names)
    acc_glob = jax.lax.psum(acc_loc, axis_names)
    o = acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
    return o.reshape(B, 1, H, dh).astype(v.dtype)


def decode_attention_seqsharded(q, k_cache, v_cache, position, mesh,
                                seq_axes=("model",)):
    """Flash-decode with the cache sequence dim sharded over `seq_axes`.

    q: [B,1,H,dh]; k_cache/v_cache: [B,S,Kv,dh] with S sharded.
    position: scalar int32 (replicated). Returns [B,1,H,dh].
    """
    body = functools.partial(_local_decode_attn, axis_names=seq_axes)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(None, seq_axes), P(None, seq_axes), P()),
        out_specs=P(),
        check_vma=False,
    )(q, k_cache, v_cache, position)
