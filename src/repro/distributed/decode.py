"""Optimized decode step for dense-family models: shard_map flash-decode
over sequence-sharded KV caches (see collectives.py).

The baseline decode_step leaves KV-cache resharding to GSPMD, which
all-gathers K and V per layer when the cache's sequence axis is sharded
over "model" (kv-head counts on the assigned archs are all below the
16-way model axis, so sequence sharding is the only uniform option).
This variant computes local softmax statistics per shard and combines
with a log-sum-exp psum — only [B,H,dh]-sized payloads cross the ICI.
Numerics validated against the dense reference in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.base import ModelConfig
from repro.models import dense as D
from repro.nn import layers as L
from repro.nn import attention as A
from repro.core import fastforward as FF
from repro.distributed.collectives import decode_attention_seqsharded


def _write_kv_sharded(kc, vc, k_new, v_new, position, mesh):
    """Single-token cache write with the sequence axis sharded over
    "model": shard_map so each shard writes only if it owns `position`
    (no cross-shard scatter traffic)."""

    def local(kc, vc, k_new, v_new, position):
        s_local = kc.shape[1]
        shard = jax.lax.axis_index("model")
        offset = shard * s_local
        local_pos = jnp.clip(position - offset, 0, s_local - 1)
        owns = (position >= offset) & (position < offset + s_local)
        k_upd = jax.lax.dynamic_update_slice_in_dim(
            kc, k_new.astype(kc.dtype), local_pos, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(
            vc, v_new.astype(vc.dtype), local_pos, axis=1)
        kc = jnp.where(owns, k_upd, kc)
        vc = jnp.where(owns, v_upd, vc)
        return kc, vc

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if batch_axes else None
    kv_spec = P(bspec, "model", None, None)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(kv_spec, kv_spec, P(bspec, None, None, None),
                  P(bspec, None, None, None), P()),
        out_specs=(kv_spec, kv_spec),
        check_vma=False,
    )(kc, vc, k_new, v_new, position)


def decode_step_seqsharded(params, cfg: ModelConfig, token, cache,
                           position, mesh, shards: int = 1):
    """Drop-in for dense.decode_step with seq-sharded KV (no window)."""
    ff = cfg.ff
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    positions = jnp.full((B, 1), position)
    plan = (FF.resolve_plan(cfg, shards=shards)
            if (ff.enabled and ff.apply_to_decode) else None)

    def layer_body(x, layer_in):
        lp, kc, vc = layer_in
        xn = D.apply_norm(cfg, lp["ln1"], x)
        k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                    cfg.rope_theta)
        kc, vc = _write_kv_sharded(kc, vc, k_new, v_new, position, mesh)
        q = A.project_q(lp["attn"], xn, positions, cfg.rope_theta)
        o = decode_attention_seqsharded(q, kc, vc, position, mesh)
        x = x + A.output_proj(lp["attn"], o)
        xn2 = D.apply_norm(cfg, lp["ln2"], x)
        if plan is not None:
            y = FF.ff_decode_sparse(lp["ffn"], cfg, xn2, plan, shards)
        else:
            y = FF.ff_dense(lp["ffn"], cfg, xn2)
        return x + y, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        layer_body, x, (params["layers"], cache["k"], cache["v"]))
    x = D.apply_norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["lm_head"], x[:, 0, :])
    return logits, {"k": ks, "v": vs}
