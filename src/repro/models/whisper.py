"""Whisper-style encoder-decoder (audio backbone only, per assignment).

The mel-spectrogram + conv feature extractor is a STUB: `input_specs`
provides precomputed frame embeddings [B, n_audio_frames, d_model].
Encoder: non-causal self-attention, sinusoidal positions, LayerNorm,
GELU FFN. Decoder: causal self-attention + cross-attention; FastForward
applies to the decoder FFN (sink-token reasoning is decoder-side).
long_500k is skipped for this arch (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.nn import param as PM
from repro.nn import layers as L
from repro.nn import attention as A
from repro.core import fastforward as FF
from repro.core import sparse_ffn as S
from repro.models import dense as D


def enc_layer_spec(cfg: ModelConfig, dtype):
    return {
        "ln1": L.layernorm_spec(cfg.d_model, dtype),
        "attn": A.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, True, dtype),
        "ln2": L.layernorm_spec(cfg.d_model, dtype),
        "ffn": S.ffn_spec(cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    }


def dec_layer_spec(cfg: ModelConfig, dtype):
    return {
        "ln1": L.layernorm_spec(cfg.d_model, dtype),
        "self_attn": A.attention_spec(cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.head_dim, True, dtype),
        "ln_x": L.layernorm_spec(cfg.d_model, dtype),
        "cross_attn": A.attention_spec(cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.head_dim, True, dtype),
        "ln2": L.layernorm_spec(cfg.d_model, dtype),
        "ffn": FF.fastforward_ffn_spec(cfg, dtype=dtype),
    }


def specs(cfg: ModelConfig):
    dtype = cfg.dtype
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
        "enc_layers": PM.stack_specs(enc_layer_spec(cfg, dtype), n_enc),
        "ln_enc": L.layernorm_spec(cfg.d_model, dtype),
        "dec_layers": PM.stack_specs(dec_layer_spec(cfg, dtype), cfg.n_layers),
        "ln_f": L.layernorm_spec(cfg.d_model, dtype),
        "lm_head": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
    }


# ---------------------------------------------------------------- encoder


def encode(params, cfg: ModelConfig, audio_embed):
    """audio_embed: [B, T_a, D] (stub frontend output)."""
    T_a = audio_embed.shape[1]
    x = audio_embed.astype(cfg.dtype)
    x = x + L.sinusoidal_positions(T_a, cfg.d_model)[None].astype(cfg.dtype)
    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.arange(T_a)[None], (B, T_a))

    def body(x, lp):
        xn = L.layernorm(lp["ln1"], x)
        h = A.attend_full(lp["attn"], xn, pos, causal=False, use_rope=False)
        x = x + h
        xn2 = L.layernorm(lp["ln2"], x)
        return x + S.ffn_dense(lp["ffn"], xn2, "gelu"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return L.layernorm(params["ln_enc"], x)


# ---------------------------------------------------------------- decoder


def _dec_layer(cfg, lp, x, pos, enc_out, budget):
    xn = L.layernorm(lp["ln1"], x)
    h = A.attend_full(lp["self_attn"], xn, pos, causal=True, use_rope=False)
    x = x + h
    xn = L.layernorm(lp["ln_x"], x)
    q = A.project_q(lp["cross_attn"], xn)
    k, v = A.project_kv(lp["cross_attn"], enc_out)
    o = A.dot_attention(q, k, v)
    x = x + A.output_proj(lp["cross_attn"], o)
    xn2 = L.layernorm(lp["ln2"], x)
    if cfg.ff.enabled:
        y = FF.ff_masked_sequence(lp["ffn"], cfg, xn2, budget)
    else:
        y = FF.ff_dense(lp["ffn"], cfg, xn2)
    return x + y


def forward(params, cfg: ModelConfig, batch, budgets=None):
    """batch: {"audio_embed": [B,Ta,D], "tokens": [B,T]}."""
    enc_out = encode(params, cfg, batch["audio_embed"])
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    x = x + L.sinusoidal_positions(T, cfg.d_model)[None].astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if budgets is None:
        budgets = jnp.asarray(FF.layer_budgets(cfg), jnp.float32)

    def body(x, layer_in):
        lp, budget = layer_in
        return _dec_layer(cfg, lp, x, pos, enc_out, budget), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["dec_layers"], budgets))
    x = L.layernorm(params["ln_f"], x)
    return L.unembed(params["lm_head"], x), {}


# ------------------------------------------------------------------ cache


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    kv = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    xa = (cfg.n_layers, batch, cfg.n_audio_frames, cfg.n_kv_heads,
          cfg.head_dim)
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {
        "k": PM.ParamSpec(kv, ax, init="zeros", dtype=dtype),
        "v": PM.ParamSpec(kv, ax, init="zeros", dtype=dtype),
        "ck": PM.ParamSpec(xa, ax, init="zeros", dtype=dtype),
        "cv": PM.ParamSpec(xa, ax, init="zeros", dtype=dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, cache_len, dtype),
                        is_leaf=PM.is_spec)


def precompute_cross(params, cfg: ModelConfig, audio_embed, cache):
    """Fill the cross-attention KV cache from the encoder output."""
    enc_out = encode(params, cfg, audio_embed)

    def one(lp):
        return A.project_kv(lp["cross_attn"], enc_out)

    ck, cv = jax.vmap(one)(params["dec_layers"])
    return dict(cache, ck=ck, cv=cv)


# ------------------------------------- blockwise prefill (decoder side)


def prefill(params, cfg: ModelConfig, batch, cache, shards: int = 1):
    """Blockwise decoder prefill over the token prompt; cross KV must be
    precomputed (or audio_embed given in batch)."""
    if "audio_embed" in batch:
        cache = precompute_cross(params, cfg, batch["audio_embed"], cache)
    tokens = batch["tokens"]
    ff = cfg.ff
    B, T = tokens.shape
    N = ff.block_size
    nb = T // N
    blocks = tokens.reshape(B, nb, N).transpose(1, 0, 2)
    plan = FF.resolve_plan(cfg, shards=shards) if ff.enabled else None
    pos_table = L.sinusoidal_positions(T, cfg.d_model).astype(cfg.dtype)

    def block_step(cache, blk_in):
        blk_idx, tok_blk = blk_in
        pos0 = blk_idx * N
        x = L.embed(params["embed"], tok_blk).astype(cfg.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pos_table, pos0, N, 0)[None]
        is_dense = jnp.zeros((), bool)
        if ff.dense_first_block:
            is_dense = is_dense | (blk_idx == 0)
        if ff.dense_last_block:
            is_dense = is_dense | (blk_idx == nb - 1)

        def layer_body(x, layer_in):
            lp, kc, vc, ck, cv = layer_in
            xn = L.layernorm(lp["ln1"], x)
            k_new, v_new = A.project_kv(lp["self_attn"], xn)
            kc, vc = A.write_kv_block(kc, vc, k_new, v_new, pos0)
            h = A.attend_block_cached(lp["self_attn"], xn, kc, vc, pos0,
                                      use_rope=False)
            x = x + h
            xn = L.layernorm(lp["ln_x"], x)
            q = A.project_q(lp["cross_attn"], xn)
            o = A.dot_attention(q, ck, cv)
            x = x + A.output_proj(lp["cross_attn"], o)
            xn2 = L.layernorm(lp["ln2"], x)
            if plan is not None:
                y = FF.ff_block_sparse(lp["ffn"], cfg, xn2, plan,
                                       shards, is_dense)
            else:
                y = FF.ff_dense(lp["ffn"], cfg, xn2)
            return x + y, (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            layer_body, x,
            (params["dec_layers"], cache["k"], cache["v"],
             cache["ck"], cache["cv"]))
        return dict(cache, k=ks, v=vs), x[:, -1, :]

    cache, lasts = jax.lax.scan(block_step, cache, (jnp.arange(nb), blocks))
    x_last = L.layernorm(params["ln_f"], lasts[-1])
    return cache, L.unembed(params["lm_head"], x_last)


def decode_step(params, cfg: ModelConfig, token, cache, position,
                shards: int = 1, window=None):
    ff = cfg.ff
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    T_max = cache["k"].shape[2]
    pos_table = L.sinusoidal_positions(T_max, cfg.d_model).astype(cfg.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pos_table, position, 1, 0)[None]
    plan = (FF.resolve_plan(cfg, shards=shards)
            if (ff.enabled and ff.apply_to_decode) else None)

    def layer_body(x, layer_in):
        lp, kc, vc, ck, cv = layer_in
        xn = L.layernorm(lp["ln1"], x)
        k_new, v_new = A.project_kv(lp["self_attn"], xn)
        kc, vc = A.write_kv_block(kc, vc, k_new, v_new, position)
        h = A.attend_decode(lp["self_attn"], xn, kc, vc, position,
                            use_rope=False)
        x = x + h
        xn = L.layernorm(lp["ln_x"], x)
        q = A.project_q(lp["cross_attn"], xn)
        o = A.dot_attention(q, ck, cv)
        x = x + A.output_proj(lp["cross_attn"], o)
        xn2 = L.layernorm(lp["ln2"], x)
        if plan is not None:
            y = FF.ff_decode_sparse(lp["ffn"], cfg, xn2, plan, shards)
        else:
            y = FF.ff_dense(lp["ffn"], cfg, xn2)
        return x + y, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        layer_body, x,
        (params["dec_layers"], cache["k"], cache["v"],
         cache["ck"], cache["cv"]))
    x = L.layernorm(params["ln_f"], x)
    logits = L.unembed(params["lm_head"], x[:, 0, :])
    return logits, dict(cache, k=ks, v=vs)
