"""Architecture registry: dispatch cfg.arch -> model module."""
from __future__ import annotations

from repro.models import dense, moe, whisper, llava, xlstm, zamba2

_MODULES = {
    "dense": dense,
    "moe": moe,
    "audio": whisper,
    "vlm": llava,
    "ssm": xlstm,
    "hybrid": zamba2,
}


def get_model(cfg):
    return _MODULES[cfg.arch]
