"""State-space / recurrent cell machinery: Mamba2 SSD (chunked scan) and
xLSTM cells (chunked mLSTM, sequential sLSTM).

All chunked forms carry an explicit state so blockwise prefill and
one-token decode reuse the same math; tests validate them against
naive per-step recurrent references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def segsum(a):
    """a: [..., T] -> [..., T, T] with out[t, s] = sum_{r=s+1..t} a_r for
    s <= t, -inf above the diagonal (log-space decay matrix)."""
    T = a.shape[-1]
    cum = jnp.cumsum(a, -1)
    d = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, NEG_INF)


# =============================================================== Mamba2 SSD


def ssd_chunked(x, dA, B, C, chunk: int, init_state=None):
    """Chunked SSD scan (Mamba2).

    x:  [Bb, T, H, P]   (inputs, dt already folded in: x * dt)
    dA: [Bb, T, H]      (dt * A, negative log-decays)
    B:  [Bb, T, G, N]   C: [Bb, T, G, N]  (G groups broadcast over H)
    Returns (y [Bb,T,H,P], final_state [Bb,H,P,N]).
    """
    Bb, T, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert T % chunk == 0, (T, chunk)
    nc, cs = T // chunk, chunk
    rep = H // G

    xr = x.reshape(Bb, nc, cs, H, P)
    ar = dA.reshape(Bb, nc, cs, H).transpose(0, 3, 1, 2)      # [Bb,H,nc,cs]
    Br = B.reshape(Bb, nc, cs, G, N)
    Cr = C.reshape(Bb, nc, cs, G, N)

    a_cum = jnp.cumsum(ar, -1)                                 # [Bb,H,nc,cs]
    L = jnp.exp(segsum(ar))                                    # [Bb,H,nc,cs,cs]

    # broadcast groups over heads
    Bh = jnp.repeat(Br, rep, axis=3) if G != H else Br         # [Bb,nc,cs,H,N]
    Ch = jnp.repeat(Cr, rep, axis=3) if G != H else Cr

    # intra-chunk (diagonal blocks)
    Gmat = jnp.einsum("bclhn,bcshn->bhcls", Ch, Bh,
                      preferred_element_type=jnp.float32)
    M = Gmat * L                                               # [Bb,H,nc,cs,cs]
    y_diag = jnp.einsum("bhcls,bcshp->bclhp", M, xr,
                        preferred_element_type=jnp.float32)

    # chunk-final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)            # [Bb,H,nc,cs]
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", Bh, decay_states, xr,
                        preferred_element_type=jnp.float32)    # [Bb,nc,H,P,N]

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                      # [Bb,H,nc]
    s0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        st, dec = inp                                          # [Bb,H,P,N],[Bb,H]
        s_new = dec[..., None, None] * s + st
        return s_new, s                                        # emit state BEFORE chunk

    final_state, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # [Bb,nc,H,P,N]

    # contribution of carried-in states
    state_decay = jnp.exp(a_cum)                               # [Bb,H,nc,cs]
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, prev_states,
                       state_decay, preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(Bb, T, H, P)
    return y.astype(x.dtype), final_state


def ssd_step(state, x_t, dA_t, B_t, C_t):
    """One-token SSD update. state: [Bb,H,P,N]; x_t: [Bb,H,P];
    dA_t: [Bb,H]; B_t, C_t: [Bb,G,N]. Returns (y [Bb,H,P], new_state)."""
    G = B_t.shape[1]
    H = x_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1) if G != H else B_t       # [Bb,H,N]
    Ch = jnp.repeat(C_t, rep, axis=1) if G != H else C_t
    s32 = state.astype(jnp.float32)
    new = (jnp.exp(dA_t)[..., None, None] * s32
           + jnp.einsum("bhp,bhn->bhpn", x_t.astype(jnp.float32), Bh))
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch)
    return y.astype(x_t.dtype), new


# ============================================================ mLSTM (xLSTM)


def mlstm_chunked(q, k, v, i_gate, f_gate, chunk: int, state=None):
    """Chunk-parallel mLSTM with max-stabilized exponential gating.

    q,k: [Bb,T,H,dk]; v: [Bb,T,H,dv]; i_gate,f_gate: [Bb,T,H] (logits).
    state: (C [Bb,H,dk,dv], n [Bb,H,dk], m [Bb,H]) or None.
    Returns (h [Bb,T,H,dv], state).
    """
    Bb, T, H, dk = q.shape
    dv = v.shape[-1]
    assert T % chunk == 0
    nc, cs = T // chunk, chunk
    qs = q.reshape(Bb, nc, cs, H, dk) / np.sqrt(dk)
    ks = k.reshape(Bb, nc, cs, H, dk)
    vs = v.reshape(Bb, nc, cs, H, dv)
    a = jax.nn.log_sigmoid(f_gate).reshape(Bb, nc, cs, H).transpose(0, 3, 1, 2)
    b = i_gate.reshape(Bb, nc, cs, H).transpose(0, 3, 1, 2)    # [Bb,H,nc,cs]
    la = jnp.cumsum(a, -1)                                     # [Bb,H,nc,cs]
    la_tot = la[..., -1]                                       # [Bb,H,nc]
    w = la_tot[..., None] - la + b                             # [Bb,H,nc,cs]

    if state is None:
        C0 = jnp.zeros((Bb, H, dk, dv), jnp.float32)
        n0 = jnp.zeros((Bb, H, dk), jnp.float32)
        m0 = jnp.zeros((Bb, H), jnp.float32)
    else:
        C0, n0, m0 = [s.astype(jnp.float32) for s in state]

    # D[t,s] = la_t - la_s + b_s (s<=t) in log space
    Dmat = segsum(a) + b[..., None, :]                          # [Bb,H,nc,cs,cs]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    Dmat = jnp.where(mask, Dmat, NEG_INF)

    def chunk_step(carry, inp):
        C, n, m = carry
        qc, kc, vc, ac, bc, lac, wc, Dc = inp
        # ac,lac,wc: [Bb,H,cs]; Dc: [Bb,H,cs,cs]; qc..: [Bb,cs,H,*]
        la_t = lac                                             # [Bb,H,cs]
        d_inter = m[..., None] + la_t                          # [Bb,H,cs]
        m_intra = jnp.max(Dc, -1)                              # [Bb,H,cs]
        m_out = jnp.maximum(d_inter, m_intra)                  # [Bb,H,cs]
        S = jnp.exp(Dc - m_out[..., None])                     # [Bb,H,cs,cs]
        qk = jnp.einsum("bthk,bshk->bhts", qc, kc,
                        preferred_element_type=jnp.float32)
        att = S * qk
        h_intra = jnp.einsum("bhts,bshv->bthv", att, vc,
                             preferred_element_type=jnp.float32)
        w_inter = jnp.exp(d_inter - m_out)                     # [Bb,H,cs]
        qC = jnp.einsum("bthk,bhkv->bthv", qc, C,
                        preferred_element_type=jnp.float32)
        h_inter = w_inter.transpose(0, 2, 1)[..., None] * qC
        qn = jnp.einsum("bthk,bhk->bht", qc, n,
                        preferred_element_type=jnp.float32)
        denom_raw = w_inter * qn + jnp.sum(att, -1)   # [Bb,H,cs]
        denom = jnp.maximum(jnp.abs(denom_raw), jnp.exp(-m_out))  # [Bb,H,cs]
        h = (h_intra + h_inter) / denom.transpose(0, 2, 1)[..., None]
        # state update to chunk end
        la_T = lac[..., -1]                                    # [Bb,H]
        m_new = jnp.maximum(m + la_T, jnp.max(wc, -1))
        scale_old = jnp.exp(m + la_T - m_new)                  # [Bb,H]
        src = jnp.exp(wc - m_new[..., None])                   # [Bb,H,cs]
        kv = jnp.einsum("bhs,bshk,bshv->bhkv", src, kc, vc,
                        preferred_element_type=jnp.float32)
        ksum = jnp.einsum("bhs,bshk->bhk", src, kc,
                          preferred_element_type=jnp.float32)
        C = scale_old[..., None, None] * C + kv
        n = scale_old[..., None] * n + ksum
        return (C, n, m_new), h

    xs = (qs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
          vs.transpose(1, 0, 2, 3, 4),
          a.transpose(2, 0, 1, 3), b.transpose(2, 0, 1, 3),
          la.transpose(2, 0, 1, 3), w.transpose(2, 0, 1, 3),
          Dmat.transpose(2, 0, 1, 3, 4))
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(Bb, T, H, dv)
    return h.astype(v.dtype), (C, n, m)


def mlstm_step(state, q_t, k_t, v_t, i_t, f_t):
    """One-token mLSTM update. q_t,k_t: [Bb,H,dk]; v_t: [Bb,H,dv];
    i_t,f_t: [Bb,H] logits. Returns (h [Bb,H,dv], new_state)."""
    C, n, m = [s.astype(jnp.float32) for s in state]
    dk = q_t.shape[-1]
    qf = q_t.astype(jnp.float32) / np.sqrt(dk)
    a = jax.nn.log_sigmoid(f_t)                                # [Bb,H]
    m_new = jnp.maximum(a + m, i_t)
    fscale = jnp.exp(a + m - m_new)
    iscale = jnp.exp(i_t - m_new)
    C = fscale[..., None, None] * C + iscale[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k_t.astype(jnp.float32), v_t.astype(jnp.float32))
    n = fscale[..., None] * n + iscale[..., None] * k_t.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                      jnp.exp(-m_new))
    return (num / den[..., None]).astype(v_t.dtype), (C, n, m_new)


def mlstm_recurrent_ref(q, k, v, i_gate, f_gate):
    """Naive per-step reference (oracle for tests)."""
    Bb, T, H, dk = q.shape
    dv = v.shape[-1]
    state = (jnp.zeros((Bb, H, dk, dv), jnp.float32),
             jnp.zeros((Bb, H, dk), jnp.float32),
             jnp.zeros((Bb, H), jnp.float32))

    def step(state, t_in):
        qt, kt, vt, it, ft = t_in
        h, state = mlstm_step(state, qt, kt, vt, it, ft)
        return state, h

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_gate.transpose(1, 0, 2),
          f_gate.transpose(1, 0, 2))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state


# ============================================================ sLSTM (xLSTM)


def slstm_scan(zg, ig, fg, og, r, state=None):
    """Sequential sLSTM over a sequence with recurrent gate feedback.

    zg,ig,fg,og: [Bb,T,H,dh] pre-activation gate contributions from the
    input projection. r: [H, dh, 4*dh] block-diagonal recurrent matrix
    adding R @ h_{t-1} to the gates. state: (c,n,h,m) each [Bb,H,dh].
    Returns (h_seq [Bb,T,H,dh], state).
    """
    Bb, T, H, dh = zg.shape
    if state is None:
        z0 = jnp.zeros((Bb, H, dh), jnp.float32)
        state = (z0, z0, z0, z0)

    def step(state, gates_t):
        c, n, h, m = state
        zt, it, ft, ot = gates_t                               # [Bb,H,dh]
        rgate = jnp.einsum("bhd,hdg->bhg", h, r.astype(jnp.float32))
        rz, ri, rf, ro = jnp.split(rgate, 4, axis=-1)
        zt = jnp.tanh(zt.astype(jnp.float32) + rz)
        it_l = it.astype(jnp.float32) + ri
        ft_l = ft.astype(jnp.float32) + rf
        ot = jax.nn.sigmoid(ot.astype(jnp.float32) + ro)
        lf = jax.nn.log_sigmoid(ft_l)
        m_new = jnp.maximum(lf + m, it_l)
        i_e = jnp.exp(it_l - m_new)
        f_e = jnp.exp(lf + m - m_new)
        c = f_e * c + i_e * zt
        n = f_e * n + i_e
        h = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    xs = (zg.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2, 3),
          fg.transpose(1, 0, 2, 3), og.transpose(1, 0, 2, 3))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3).astype(zg.dtype), state


# ------------------------------------------------------------- conv utils


def causal_conv1d(x, w, b=None):
    """Depthwise causal conv. x: [Bb,T,Cc]; w: [K,Cc]; b: [Cc]."""
    K = w.shape[0]
    pads = [jnp.pad(x, ((0, 0), (K - 1 - k, 0), (0, 0)))[:, :x.shape[1]]
            for k in range(K)]
    y = sum(pads[k] * w[k][None, None, :] for k in range(K))
    if b is not None:
        y = y + b[None, None, :]
    return y


def conv_step(conv_state, x_t, w, b=None):
    """One-token depthwise conv. conv_state: [Bb,K-1,Cc] (previous
    inputs, oldest first); x_t: [Bb,Cc]. Returns (y_t, new_state)."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [Bb,K,Cc]
    y = jnp.einsum("bkc,kc->bc", full, w)
    if b is not None:
        y = y + b[None, :]
    return y, full[:, 1:, :]
