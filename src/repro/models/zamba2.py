"""Zamba2-style hybrid (arXiv:2411.15242): Mamba2 (SSD) backbone with a
weight-SHARED attention+MLP block applied every `attn_every` layers.

Layers are scanned in groups: each group = `attn_every` Mamba2 layers
followed by one application of the shared block (same parameters every
time, per-site KV cache). FastForward applies to the shared block's MLP
(the Mamba2 layers have no FFN — DESIGN.md §4). long_500k: Mamba2 state
is O(1); the shared attention uses a sliding window in long mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.nn import param as PM
from repro.nn import layers as L
from repro.nn import attention as A
from repro.core import fastforward as FF
from repro.models import dense as D
from repro.models import ssm_ops as O


def _dims(cfg: ModelConfig):
    Dm = cfg.d_model
    Di = cfg.ssm_expand * Dm                  # inner width
    P = cfg.ssm_head_dim
    H = Di // P                                # ssm heads
    G, N = 1, cfg.ssm_state
    return Dm, Di, H, P, G, N


def mamba_spec(cfg: ModelConfig, dtype):
    Dm, Di, H, P, G, N = _dims(cfg)
    conv_dim = Di + 2 * G * N
    return {
        "ln": L.rmsnorm_spec(Dm, dtype),
        "in_proj": PM.ParamSpec((Dm, 2 * Di + 2 * G * N + H),
                                ("embed", "mlp"), dtype=dtype),
        "conv_w": PM.ParamSpec((cfg.ssm_conv, conv_dim), (None, "mlp"),
                               init="normal", scale=0.1, dtype=dtype),
        "conv_b": PM.ParamSpec((conv_dim,), ("mlp",), init="zeros", dtype=dtype),
        "A_log": PM.ParamSpec((H,), (None,), init="zeros", dtype=jnp.float32),
        "D_skip": PM.ParamSpec((H,), (None,), init="ones", dtype=jnp.float32),
        "dt_bias": PM.ParamSpec((H,), (None,), init="zeros", dtype=jnp.float32),
        "ln_gate": L.rmsnorm_spec(Di, dtype),
        "out_proj": PM.ParamSpec((Di, Dm), ("mlp", "embed"), dtype=dtype),
    }


def shared_block_spec(cfg: ModelConfig, dtype):
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model, dtype),
        "attn": A.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, False, dtype),
        "ln2": L.rmsnorm_spec(cfg.d_model, dtype),
        "ffn": FF.fastforward_ffn_spec(cfg, dtype=dtype),
    }


def n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def specs(cfg: ModelConfig):
    dtype = cfg.dtype
    g = n_groups(cfg)
    per_group = PM.stack_specs(mamba_spec(cfg, dtype), cfg.attn_every,
                               axis_name="layers")
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
        "groups": PM.stack_specs(per_group, g, axis_name="layers"),
        "shared": shared_block_spec(cfg, dtype),   # ONE copy, reused
        "ln_f": L.rmsnorm_spec(cfg.d_model, dtype),
        "lm_head": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
    }


# ----------------------------------------------------------- mamba layer


def _mamba_project(lp, cfg, xn):
    Dm, Di, H, P, G, N = _dims(cfg)
    zxbcdt = jnp.einsum("...d,dk->...k", xn, lp["in_proj"],
                        preferred_element_type=jnp.float32).astype(xn.dtype)
    z, xBC, dt_raw = jnp.split(zxbcdt, [Di, 2 * Di + 2 * G * N], axis=-1)
    return z, xBC, dt_raw


def _mamba_post(lp, cfg, x, y_ssm, x_in, z):
    """Gated norm + out projection; y_ssm [B,T,H,P]."""
    Dm, Di, H, P, G, N = _dims(cfg)
    B, T = x.shape[:2]
    y = y_ssm + lp["D_skip"][None, None, :, None] * x_in
    y = y.reshape(B, T, Di)
    y = L.rmsnorm(lp["ln_gate"], y * L.silu(z))
    out = jnp.einsum("...k,kd->...d", y, lp["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return x + out


def mamba_layer(lp, cfg: ModelConfig, x, state=None, chunk=None):
    """x: [B,T,D]; state: (ssm [B,H,P,N], conv [B,K-1,conv_dim]) or None."""
    Dm, Di, H, P, G, N = _dims(cfg)
    B, T = x.shape[:2]
    xn = L.rmsnorm(lp["ln"], x)
    z, xBC, dt_raw = _mamba_project(lp, cfg, xn)
    if state is not None:
        pad = jnp.concatenate([state[1].astype(xBC.dtype), xBC], axis=1)
        xBC_c = O.causal_conv1d(pad, lp["conv_w"], lp["conv_b"])[
            :, state[1].shape[1]:]
        new_conv = pad[:, -(cfg.ssm_conv - 1):, :]
    else:
        xBC_c = O.causal_conv1d(xBC, lp["conv_w"], lp["conv_b"])
        new_conv = xBC[:, -(cfg.ssm_conv - 1):, :]
    xBC_c = L.silu(xBC_c)
    x_in, Bc, Cc = jnp.split(xBC_c, [Di, Di + G * N], axis=-1)
    x_in = x_in.reshape(B, T, H, P)
    Bc = Bc.reshape(B, T, G, N)
    Cc = Cc.reshape(B, T, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    Aneg = -jnp.exp(lp["A_log"])                          # [H]
    dA = dt * Aneg[None, None, :]
    xdt = x_in * dt[..., None].astype(x_in.dtype)
    ssm0 = None if state is None else state[0]
    y, ssm = O.ssd_chunked(xdt, dA, Bc, Cc, chunk or cfg.ssm_chunk, ssm0)
    return _mamba_post(lp, cfg, x, y, x_in, z), (ssm, new_conv)


def mamba_step(lp, cfg: ModelConfig, x_tok, state):
    """One-token step. x_tok [B,1,D]; state (ssm, conv)."""
    Dm, Di, H, P, G, N = _dims(cfg)
    B = x_tok.shape[0]
    xn = L.rmsnorm(lp["ln"], x_tok)
    z, xBC, dt_raw = _mamba_project(lp, cfg, xn)
    y_c, new_conv = O.conv_step(state[1].astype(xBC.dtype), xBC[:, 0],
                                lp["conv_w"], lp["conv_b"])
    xBC_c = L.silu(y_c)
    x_in, Bc, Cc = jnp.split(xBC_c, [Di, Di + G * N], axis=-1)
    x_in = x_in.reshape(B, H, P)
    Bc = Bc.reshape(B, G, N)
    Cc = Cc.reshape(B, G, N)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + lp["dt_bias"])
    dA = dt * (-jnp.exp(lp["A_log"]))[None, :]
    xdt = x_in * dt[..., None].astype(x_in.dtype)
    y, ssm = O.ssd_step(state[0], xdt, dA, Bc, Cc)
    return _mamba_post(lp, cfg, x_tok, y[:, None], x_in[:, None], z), \
        (ssm, new_conv)


# ---------------------------------------------------------- shared block


def shared_block_full(sp, cfg: ModelConfig, x, pos, budget):
    xn = L.rmsnorm(sp["ln1"], x)
    h = A.attend_full(sp["attn"], xn, pos, causal=True,
                      window=cfg.sliding_window, rope_theta=cfg.rope_theta)
    x = x + h
    xn2 = L.rmsnorm(sp["ln2"], x)
    if cfg.ff.enabled:
        y = FF.ff_masked_sequence(sp["ffn"], cfg, xn2, budget)
    else:
        y = FF.ff_dense(sp["ffn"], cfg, xn2)
    return x + y


# ----------------------------------------------------------------- model


def forward(params, cfg: ModelConfig, batch, budgets=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    keep = 1.0 - cfg.ff.sparsity

    def group_body(x, gp):
        def mamba_body(x, lp):
            x, _ = mamba_layer(lp, cfg, x)
            return x, None
        x, _ = jax.lax.scan(mamba_body, x, gp)
        x = shared_block_full(params["shared"], cfg, x, pos, keep)
        return x, None

    body_fn = jax.checkpoint(group_body) if cfg.remat else group_body
    x, _ = jax.lax.scan(body_fn, x, params["groups"])
    x = L.rmsnorm(params["ln_f"], x)
    return L.unembed(params["lm_head"], x), {}


# ------------------------------------------------------------------ cache


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    Dm, Di, H, P, G, N = _dims(cfg)
    g = n_groups(cfg)
    e = cfg.attn_every
    conv_dim = Di + 2 * G * N
    kv = (g, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    kv_ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {
        "ssm": PM.ParamSpec((g, e, batch, H, P, N),
                            ("layers", "layers2", "batch", None, None, None),
                            init="zeros", dtype=jnp.float32),
        "conv": PM.ParamSpec((g, e, batch, cfg.ssm_conv - 1, conv_dim),
                             ("layers", "layers2", "batch", None, "mlp"),
                             init="zeros", dtype=dtype),
        "k": PM.ParamSpec(kv, kv_ax, init="zeros", dtype=dtype),
        "v": PM.ParamSpec(kv, kv_ax, init="zeros", dtype=dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, cache_len, dtype),
                        is_leaf=PM.is_spec)


# ---------------------------------------------------------------- prefill


def prefill(params, cfg: ModelConfig, batch, cache, shards: int = 1):
    """Blockwise prefill: scan over prompt blocks; Mamba2 states carry
    across blocks, shared attention appends to its per-site KV cache."""
    tokens = batch["tokens"]
    ff = cfg.ff
    B, T = tokens.shape
    Nb = ff.block_size
    nb = T // Nb
    blocks = tokens.reshape(B, nb, Nb).transpose(1, 0, 2)
    plan = FF.resolve_plan(cfg, shards=shards) if ff.enabled else None
    window = cfg.sliding_window

    def block_step(cache, blk_in):
        blk_idx, tok_blk = blk_in
        pos0 = blk_idx * Nb
        x = L.embed(params["embed"], tok_blk).astype(cfg.dtype)
        positions = pos0 + jnp.arange(Nb)[None, :]
        is_dense = jnp.zeros((), bool)
        if ff.dense_first_block:
            is_dense = is_dense | (blk_idx == 0)
        if ff.dense_last_block:
            is_dense = is_dense | (blk_idx == nb - 1)

        def group_body(x, gin):
            gp, ssm_g, conv_g, kc, vc = gin

            def mamba_body(carry, lin):
                x = carry
                lp, s0, c0 = lin
                x, (s1, c1) = mamba_layer(lp, cfg, x, state=(s0, c0))
                return x, (s1, c1)

            x, (ssm1, conv1) = jax.lax.scan(mamba_body, x,
                                            (gp, ssm_g, conv_g))
            sp = params["shared"]
            xn = L.rmsnorm(sp["ln1"], x)
            k_new, v_new = A.project_kv(sp["attn"], xn, positions,
                                        cfg.rope_theta)
            kc, vc = A.write_kv_block(kc, vc, k_new, v_new, pos0)
            h = A.attend_block_cached(sp["attn"], xn, kc, vc, pos0,
                                      window=window,
                                      rope_theta=cfg.rope_theta)
            x = x + h
            xn2 = L.rmsnorm(sp["ln2"], x)
            if plan is not None:
                y = FF.ff_block_sparse(sp["ffn"], cfg, xn2, plan,
                                       shards, is_dense)
            else:
                y = FF.ff_dense(sp["ffn"], cfg, xn2)
            return x + y, (ssm1, conv1.astype(cache["conv"].dtype), kc, vc)

        x, (ssm, conv, ks, vs) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["ssm"], cache["conv"],
             cache["k"], cache["v"]))
        return {"ssm": ssm, "conv": conv, "k": ks, "v": vs}, x[:, -1, :]

    cache, lasts = jax.lax.scan(block_step, cache, (jnp.arange(nb), blocks))
    xl = L.rmsnorm(params["ln_f"], lasts[-1])
    return cache, L.unembed(params["lm_head"], xl)


def decode_step(params, cfg: ModelConfig, token, cache, position,
                shards: int = 1, window=None):
    ff = cfg.ff
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    positions = jnp.full((B, 1), position)
    plan = (FF.resolve_plan(cfg, shards=shards)
            if (ff.enabled and ff.apply_to_decode) else None)

    def group_body(x, gin):
        gp, ssm_g, conv_g, kc, vc = gin

        def mamba_body(x, lin):
            lp, s0, c0 = lin
            x, (s1, c1) = mamba_step(lp, cfg, x, (s0, c0))
            return x, (s1, c1)

        x, (ssm1, conv1) = jax.lax.scan(mamba_body, x, (gp, ssm_g, conv_g))
        sp = params["shared"]
        xn = L.rmsnorm(sp["ln1"], x)
        k_new, v_new = A.project_kv(sp["attn"], xn, positions,
                                    cfg.rope_theta)
        if window:
            kc, vc = A.write_kv_ring(kc, vc, k_new, v_new, position, window)
        else:
            kc, vc = A.write_kv_block(kc, vc, k_new, v_new, position)
        h = A.attend_decode(sp["attn"], xn, kc, vc, position, window=window,
                            rope_theta=cfg.rope_theta)
        x = x + h
        xn2 = L.rmsnorm(sp["ln2"], x)
        if plan is not None:
            y = FF.ff_decode_sparse(sp["ffn"], cfg, xn2, plan, shards)
        else:
            y = FF.ff_dense(sp["ffn"], cfg, xn2)
        return x + y, (ssm1, conv1.astype(cache["conv"].dtype), kc, vc)

    x, (ssm, conv, ks, vs) = jax.lax.scan(
        group_body, x, (params["groups"], cache["ssm"], cache["conv"],
                        cache["k"], cache["v"]))
    xl = L.rmsnorm(params["ln_f"], x[:, 0, :])
    return L.unembed(params["lm_head"], xl), \
        {"ssm": ssm, "conv": conv, "k": ks, "v": vs}
