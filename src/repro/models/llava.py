"""LLaVA-NeXT style VLM: Mistral-7B language backbone consuming stubbed
anyres vision embeddings (the ViT/SigLIP tower + projector is a STUB per
the assignment: `input_specs` provides projected patch embeddings
[B, n_patches, d_model] directly).

Prompt layout: [patch embeddings | text tokens]. Image-region blocks are
kept dense by FastForward (treated like sink blocks — cross-modal mixing
concentrates there; DESIGN.md §4). The backbone honors Mistral's native
sliding window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.nn import layers as L
from repro.core import fastforward as FF
from repro.models import dense as D

specs = D.specs
cache_spec = D.cache_spec
init_cache = D.init_cache
decode_step = D.decode_step


def fuse_inputs(params, cfg: ModelConfig, batch):
    """[B, n_patches + T_text, D] fused embedding sequence."""
    patches = batch["patch_embed"].astype(cfg.dtype)      # [B, P, D]
    tok_embed = L.embed(params["embed"], batch["tokens"]).astype(cfg.dtype)
    return jnp.concatenate([patches, tok_embed], axis=1)


def forward(params, cfg: ModelConfig, batch, budgets=None):
    """batch: {"patch_embed": [B,P,D], "tokens": [B,T_text]}.
    Returns logits over the FULL fused sequence [B, P+T_text, V]; the
    caller masks image-region labels."""
    x = fuse_inputs(params, cfg, batch)
    return D.forward(params, cfg, {"tokens": batch["tokens"],
                                   "inputs_embeds": x}, budgets)


def prefill(params, cfg: ModelConfig, batch, cache, shards: int = 1,
            mesh=None):
    """Blockwise prefill over the fused sequence. Reuses the dense-model
    scan but feeds embeddings instead of token ids, so the image region
    flows through the same 128-token blocks (kept dense: the image spans
    the first ceil(P/N) blocks; FastForward's dense_first_block covers
    block 0 and we extend density over all image blocks)."""
    x = fuse_inputs(params, cfg, batch)
    ff = cfg.ff
    B, T, _ = x.shape
    N = ff.block_size
    nb = T // N
    n_img_blocks = -(-cfg.n_patches // N)
    blocks = x.reshape(B, nb, N, -1).transpose(1, 0, 2, 3)  # [nb,B,N,D]
    plan = FF.resolve_plan(cfg, shards=shards) if ff.enabled else None
    from repro.nn import attention as A

    def block_step(cache, blk_in):
        blk_idx, x_blk = blk_in
        pos0 = blk_idx * N
        positions = pos0 + jnp.arange(N)[None, :]
        is_dense = (blk_idx < n_img_blocks) if ff.dense_first_block \
            else jnp.zeros((), bool)
        if ff.dense_last_block:
            is_dense = is_dense | (blk_idx == nb - 1)
        xx = x_blk

        def layer_body(xx, layer_in):
            lp, kc, vc = layer_in
            xn = D.apply_norm(cfg, lp["ln1"], xx)
            k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                        cfg.rope_theta)
            kc, vc = A.write_kv_block(kc, vc, k_new, v_new, pos0)
            h = A.attend_block_cached(lp["attn"], xn, kc, vc, pos0,
                                      window=cfg.sliding_window,
                                      rope_theta=cfg.rope_theta)
            xx = xx + h
            xn2 = D.apply_norm(cfg, lp["ln2"], xx)
            if ff.enabled and cfg.shardmap_ffn and mesh is not None:
                from repro.core.sparse_ffn import ffn_block_sparse_shardmap
                y = jax.lax.cond(
                    is_dense,
                    lambda xa: FF.ff_dense(lp["ffn"], cfg, xa),
                    lambda xa: ffn_block_sparse_shardmap(
                        lp["ffn"], cfg, xa, plan.k_max, mesh), xn2)
            elif plan is not None:
                y = FF.ff_block_sparse(lp["ffn"], cfg, xn2, plan,
                                       shards, is_dense)
            else:
                y = FF.ff_dense(lp["ffn"], cfg, xn2)
            return xx + y, (kc, vc)

        xx, (ks, vs) = jax.lax.scan(
            layer_body, xx, (params["layers"], cache["k"], cache["v"]))
        return {"k": ks, "v": vs}, xx[:, -1, :]

    cache, lasts = jax.lax.scan(block_step, cache, (jnp.arange(nb), blocks))
    x_last = D.apply_norm(cfg, params["ln_f"], lasts[-1])
    return cache, L.unembed(params["lm_head"], x_last)
