"""Model configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FastForwardConfig:
    """Configuration of the paper's technique (core contribution)."""

    enabled: bool = False
    sparsity: float = 0.5          # fraction of d_ff neurons dropped
    block_size: int = 128          # prompt block length (paper §3.1)
    tile: int = 128                # neuron tile granularity (TPU adaptation)
    predictor_dim: int = 0         # r  (0 -> d_model/16 rounded up to pow2)
    compensator_dim: int = 0       # r' (0 -> d_model/8)
    # Algorithm 1: resolved into a SparsityPlan (per-layer integer tile
    # counts) that drives the mask path AND the FLOP-reducing
    # gather/Pallas paths — see the DESIGN note in core/fastforward.py
    # (resolution, [L] count padding, serving batching-key membership).
    # Configs that only set `sparsity` resolve to SparsityPlan.uniform
    # (bit-identical to the legacy k_tiles_for scalar).
    layerwise_schedule: bool = True
    dense_first_block: bool = True
    dense_last_block: bool = True
    apply_to_decode: bool = True   # paper Table 3: reuse for generation
    use_compensator: bool = True
    # --- block-sparse prefill attention (dual-budget SparsityPlan) ---
    # Fraction of causally-valid KV blocks each 128-token query block
    # DROPS during blockwise prefill (0.0 = dense attention, the
    # pre-dual-budget behavior, bit-identical). Resolved alongside the
    # FFN budget into the same SparsityPlan: per-layer counts on a
    # virtual `attn_tiles` grid ride the layer scan as a second traced
    # k_valid. See the DESIGN note in core/fastforward.py.
    attn_sparsity: float = 0.0
    attn_tiles: int = 16           # virtual attention-budget grid per layer
    # Opt-in FlashPrefill-style ADAPTIVE block counts (0.0 = off): keep
    # the fewest top-scored KV blocks whose proxy-softmax mass reaches
    # this threshold, CAPPED by the plan's per-layer budget — the
    # budget stays the worst case, easy inputs spend less. 1.0 keeps
    # every candidate (bit-identical to the fixed-budget behavior).
    # See kernels/block_sparse_attention/ops.select_kv_blocks.
    attn_threshold: float = 0.0

    def predictor_r(self, d_model: int) -> int:
        if self.predictor_dim:
            return self.predictor_dim
        r = max(d_model // 16, 8)
        return 1 << (r - 1).bit_length()  # round up to pow2 (paper §3.2)

    def compensator_r(self, d_model: int) -> int:
        return self.compensator_dim or max(d_model // 8, 8)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str                      # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 -> d_model // n_heads
    act: str = "silu"
    gated: bool = True             # SwiGLU vs plain 2-layer FFN
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    sliding_window: Optional[int] = None   # native SW (mistral: 4096)
    long_window: int = 8192        # window for long_500k mode on dense archs
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # routed-expert dispatch: "dropless" (sort-based grouped dispatch,
    # dispatch-group invariant — blockwise prefill == full forward) or
    # "capacity" (GShard-style token-drop; opt-in training mode only:
    # capacity depends on the dispatch-group size, so chunked serving
    # paths would route differently than the full-sequence forward)
    moe_dispatch: str = "dropless"
    # --- SSM / hybrid ---
    ssm_state: int = 0             # N (mamba2 state dim)
    ssm_head_dim: int = 64         # P (mamba2) / xLSTM head width driver
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    attn_every: int = 6            # zamba2: shared block cadence
    # --- modality frontends (stubs per assignment) ---
    n_audio_frames: int = 0        # whisper encoder sequence
    n_encoder_layers: int = 0      # whisper encoder depth
    n_patches: int = 0             # llava vision tokens (anyres)
    # --- fastforward ---
    ff: FastForwardConfig = dataclasses.field(default_factory=FastForwardConfig)
    # --- performance knobs (EXPERIMENTS.md §Perf) ---
    attn_chunk: int = 0            # >0: online-softmax chunked attention
    fused_prefill: bool = False    # parallel-block prefill (beyond-paper)
    shardmap_ffn: bool = False     # shard_map tile-sparse FFN (local gather)
    # --- serving KV-cache layout (serving/page_pool.py) ---
    # "slot": one max-cache_len slot per request (KVSlotPool baseline);
    # "paged": block-granular PagedKVPool — requests hold page tables
    # into a shared fixed pool of [page_size]-token pages, grown lazily
    # per prefill block / decode token and released page-granularly
    kv_layout: str = "slot"
    kv_page_size: int = 0          # tokens per KV page (0 -> ff.block_size);
                                   # must divide ff.block_size
    kv_quant: bool = False         # paged-only: store K/V pages as int8
                                   # with per-(page, kv-head) f32 scales
                                   # (kernels/kv_quant); attention
                                   # dequantizes on the fly
    # --- numerics / misc ---
    param_dtype: str = "float32"
    optimizer: str = "adamw"       # adamw | adafactor
    remat: bool = True
    source: str = ""               # provenance citation

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def ffn_tiles(self) -> int:
        return max(self.d_ff // self.ff.tile, 1) if self.d_ff else 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_ff(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, ff=dataclasses.replace(self.ff, **kw))

    # ---- capabilities used by launch/shapes + dryrun skip logic ----

    @property
    def has_ffn(self) -> bool:
        return self.d_ff > 0 or (self.arch == "moe")

    @property
    def is_encdec(self) -> bool:
        return self.arch == "audio"

    @property
    def supports_long_decode(self) -> bool:
        """long_500k: SSM/hybrid natively; dense via sliding window; the
        encoder-decoder (whisper) is excluded (see DESIGN.md)."""
        return not self.is_encdec

    def decode_window(self, seq_len: int) -> int:
        """KV-cache length used at decode for a given context length."""
        if self.arch in ("ssm",):
            return 0  # no KV cache at all
        native = self.sliding_window
        if seq_len > 32768:  # long mode -> sub-quadratic variant required
            return min(native or self.long_window, self.long_window)
        if native is not None and native < seq_len:
            return native
        return seq_len
