"""Dense llama-family decoder LM (tinyllama, qwen2.5, granite, phi3, and
the paper's own llama3-8b), with FastForward FFN integration.

Three entry points, one per input-shape kind:
  forward      — full-sequence teacher-forced training forward
                 (FastForward mask path, Algorithm 1 budgets)
  prefill      — paper §3.1 blockwise prompt processing, scan over
                 128-token blocks (FastForward gather path)
  decode_step  — single-token generation with KV cache (ring buffer in
                 sliding-window/long mode)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig
from repro.nn import param as PM
from repro.nn import layers as L
from repro.nn import attention as A
from repro.core import fastforward as FF
from repro.models import chunked as CH
from repro.distributed.sharding import constrain


# ------------------------------------------------------------------ specs


def norm_spec(cfg: ModelConfig, dtype):
    return (L.layernorm_spec(cfg.d_model, dtype) if cfg.norm == "layernorm"
            else L.rmsnorm_spec(cfg.d_model, dtype))


def apply_norm(cfg: ModelConfig, params, x):
    return (L.layernorm(params, x) if cfg.norm == "layernorm"
            else L.rmsnorm(params, x))


def layer_spec(cfg: ModelConfig, dtype):
    return {
        "ln1": norm_spec(cfg, dtype),
        "attn": A.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, cfg.qkv_bias, dtype),
        "ln2": norm_spec(cfg, dtype),
        "ffn": FF.fastforward_ffn_spec(cfg, dtype=dtype),
    }


def specs(cfg: ModelConfig):
    dtype = cfg.dtype
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
        "layers": PM.stack_specs(layer_spec(cfg, dtype), cfg.n_layers),
        "ln_f": norm_spec(cfg, dtype),
        "lm_head": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
    }


# ---------------------------------------------------------------- forward


def _ffn_apply_masked(cfg: ModelConfig, fp, x, budget, k_tiles=None):
    if cfg.ff.enabled:
        return FF.ff_masked_sequence(fp, cfg, x, budget, k_tiles=k_tiles)
    return FF.ff_dense(fp, cfg, x)


def forward(params, cfg: ModelConfig, batch, budgets=None, plan=None):
    """batch: {"tokens": [B,T]} (+"inputs_embeds" for VLM reuse).
    budgets: optional [L] keep-fractions (mask path, Algorithm 1);
    plan: optional SparsityPlan — its exact integer per-layer counts
    ride the scan instead (the mask-path oracle of the plan-taking
    gather/kernel paths). Returns (logits [B,T,V], aux dict)."""
    tokens = batch["tokens"]
    if "inputs_embeds" in batch:
        x = batch["inputs_embeds"].astype(cfg.dtype)
    else:
        x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    B, T = x.shape[:2]
    x = constrain(x, ("batch", None, None))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    counts = None
    if plan is not None:
        counts = plan.counts_array()
        budgets = jnp.asarray(plan.keep_fracs, jnp.float32)
    elif budgets is None:
        budgets = jnp.asarray(FF.layer_budgets(cfg), jnp.float32)

    def body(x, layer_in):
        if counts is None:
            lp, budget = layer_in
            k_l = None
        else:
            lp, budget, k_l = layer_in
        xn = apply_norm(cfg, lp["ln1"], x)
        h = A.attend_full(lp["attn"], xn, pos, causal=True,
                          window=cfg.sliding_window,
                          rope_theta=cfg.rope_theta,
                          chunk=cfg.attn_chunk)
        x = x + h
        xn2 = apply_norm(cfg, lp["ln2"], x)
        y = _ffn_apply_masked(cfg, lp["ffn"], xn2, budget, k_tiles=k_l)
        x = constrain(x + y, ("batch", None, None))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = ((params["layers"], budgets) if counts is None
          else (params["layers"], budgets, counts))
    x, _ = jax.lax.scan(body, x, xs)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["lm_head"], x)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, {}


# ------------------------------------------------------------------ cache


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    kv = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {
        "k": PM.ParamSpec(kv, ax, init="zeros", dtype=dtype),
        "v": PM.ParamSpec(kv, ax, init="zeros", dtype=dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, cache_len, dtype),
                        is_leaf=PM.is_spec)


# ---------------------------------------------------------------- prefill


def prefill_block(params, cfg: ModelConfig, tok_blk, cache, pos0, *,
                  is_dense=None, lengths=None, shards: int = 1,
                  plan=None, k_tiles=None, mesh=None):
    """One N-token FastForward block at sequence offset `pos0`.

    This is the schedulable unit of prefill work used both by the
    full-prompt `prefill` scan below and by the continuous-batching
    runtime (repro.serving.runtime), which interleaves single blocks of
    different requests with batched decode.

    tok_blk: [B, N]; cache: KV pytree with leaves [L, B, S, Kv, dh];
    pos0: scalar int32 (may be traced) — every row processes the block
    at the same offset (per-request chunked prefill uses B == 1);
    is_dense: traced bool forcing the dense FFN path (paper's dense
    first/last block), None when FastForward is disabled;
    lengths: optional [B] true prompt lengths (right-pad masking);
    plan: SparsityPlan (static; None resolves the uniform cfg plan —
    the backward-compat shim; a layer-wise plan rides its [L] counts
    through the layer scan so each layer consumes its own K on the
    gather/kernel path); k_tiles: deprecated int shim.
    Returns (cache, hidden [B, N, D]) with hidden pre-final-norm."""
    ff = cfg.ff
    if plan is None and k_tiles is not None:
        plan = k_tiles
    plan = FF._as_plan(cfg, plan, shards=shards) if ff.enabled else None
    counts = (None if plan is None or plan.is_uniform
              else plan.counts_array())
    attn_counts = (plan.attn_counts_array()
                   if plan is not None and plan.has_attn else None)
    N = tok_blk.shape[1]
    x = L.embed(params["embed"], tok_blk).astype(cfg.dtype)
    positions = pos0 + jnp.arange(N)[None, :]

    def layer_body(x, layer_in):
        lp, kc, vc, *rest = layer_in
        rest = list(rest)
        k_l = rest.pop(0) if counts is not None else None
        a_l = rest.pop(0) if attn_counts is not None else None
        attn_sel = (None if a_l is None
                    else (plan.attn_k_max, plan.attn_tiles, a_l))
        xn = apply_norm(cfg, lp["ln1"], x)
        k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                    cfg.rope_theta)
        kc, vc = A.write_kv_block(kc, vc, k_new, v_new, pos0)
        h = A.attend_block_cached(lp["attn"], xn, kc, vc, pos0,
                                  window=cfg.sliding_window,
                                  rope_theta=cfg.rope_theta,
                                  lengths=lengths, attn_sel=attn_sel,
                                  attn_threshold=ff.attn_threshold or None)
        x = x + h
        xn2 = apply_norm(cfg, lp["ln2"], x)
        if plan is not None and cfg.shardmap_ffn and mesh is not None:
            from repro.core.sparse_ffn import ffn_block_sparse_shardmap
            # the shardmap gather is shard-balanced -> uniform width
            y = jax.lax.cond(
                is_dense,
                lambda xx: FF.ff_dense(lp["ffn"], cfg, xx),
                lambda xx: ffn_block_sparse_shardmap(
                    lp["ffn"], cfg, xx, plan.k_max, mesh), xn2)
        elif plan is not None:
            y = FF.ff_block_sparse(lp["ffn"], cfg, xn2, plan,
                                   shards, is_dense, k_valid=k_l)
        else:
            y = FF.ff_dense(lp["ffn"], cfg, xn2)
        return x + y, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"])
    if counts is not None:
        xs = xs + (counts,)
    if attn_counts is not None:
        xs = xs + (attn_counts,)
    x, (ks, vs) = jax.lax.scan(layer_body, x, xs)
    return {"k": ks, "v": vs}, x


def prefill_blocks(params, cfg: ModelConfig, tok_blks, cache, pos0s, *,
                   is_dense=None, lengths=None, active=None,
                   page_tables=None, shards: int = 1, plan=None,
                   k_tiles=None, mesh=None):
    """One N-token FastForward block of EACH of P distinct requests, at
    per-row sequence offsets — the batched schedulable prefill unit of
    the continuous-batching runtime (serving/runtime.py
    `prefill_blocks`).

    Unlike `prefill_block` (one request, scalar pos0), every per-row
    quantity is a vector: tok_blks [P, N]; cache: KV pytree with leaves
    [L, P, S, Kv, dh] (row p = request p's slot rows, gathered by the
    runtime); pos0s [P] int32 per-row block offsets (vectorized RoPE
    positions); is_dense [P] bool — the paper's dense first/last block
    PER SEQUENCE (rows mix dense and sparse within one call, see
    FF.ff_blocks_sparse); lengths [P] true prompt lengths (right-pad
    masking of the final partial block). active: optional [P] bool —
    in the slot layout dense rows are mutually independent, so inactive
    padding rows just compute garbage that the RUNTIME discards at
    scatter-back; the paged layout uses it to mask page writes.

    page_tables: optional [P, max_pages] int32 — switches to the PAGED
    KV layout: cache leaves are the whole page pool
    [L, n_pages, psz, Kv, dh], each row's block K/V scatters onto the
    pages its table owns, and attention gathers the table-mapped
    contiguous view (nn/attention paged variants; bit-identical math).

    plan: SparsityPlan (static — joins the scheduler's batching key, so
    every row of one call shares it; its [L] counts ride the layer scan
    when layer-wise); k_tiles: deprecated int shim.
    Returns (cache, hidden [P, N, D]) with hidden pre-final-norm."""
    if page_tables is None:
        del active  # rows are independent in the dense family
    ff = cfg.ff
    if plan is None and k_tiles is not None:
        plan = k_tiles
    plan = FF._as_plan(cfg, plan, shards=shards) if ff.enabled else None
    counts = (None if plan is None or plan.is_uniform
              else plan.counts_array())
    attn_counts = (plan.attn_counts_array()
                   if plan is not None and plan.has_attn else None)
    N = tok_blks.shape[1]
    x = L.embed(params["embed"], tok_blks).astype(cfg.dtype)

    def layer_body(x, layer_in):
        lp, kc, vc, *rest = layer_in
        rest = list(rest)
        k_l = rest.pop(0) if counts is not None else None
        a_l = rest.pop(0) if attn_counts is not None else None
        attn_sel = (None if a_l is None
                    else (plan.attn_k_max, plan.attn_tiles, a_l))
        xn = apply_norm(cfg, lp["ln1"], x)
        positions = pos0s[:, None] + jnp.arange(N)[None, :]
        k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                    cfg.rope_theta)
        if page_tables is None:
            kc, vc = A.write_kv_rows(kc, vc, k_new, v_new, pos0s)
            h = A.attend_block_rows(lp["attn"], xn, kc, vc, pos0s,
                                    window=cfg.sliding_window,
                                    rope_theta=cfg.rope_theta,
                                    lengths=lengths, attn_sel=attn_sel,
                                    attn_threshold=(ff.attn_threshold
                                                    or None))
        else:
            kc, vc = A.write_kv_rows_paged(kc, vc, k_new, v_new,
                                           page_tables, pos0s,
                                           active=active)
            h = A.attend_block_rows_paged(lp["attn"], xn, kc, vc,
                                          page_tables, pos0s,
                                          window=cfg.sliding_window,
                                          rope_theta=cfg.rope_theta,
                                          lengths=lengths,
                                          attn_sel=attn_sel,
                                          attn_threshold=(ff.attn_threshold
                                                          or None))
        x = x + h
        xn2 = apply_norm(cfg, lp["ln2"], x)
        if plan is not None:
            y = FF.ff_blocks_sparse(lp["ffn"], cfg, xn2, plan,
                                    shards, is_dense, k_valid=k_l)
        else:
            y = FF.ff_dense(lp["ffn"], cfg, xn2)
        return x + y, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"])
    if counts is not None:
        xs = xs + (counts,)
    if attn_counts is not None:
        xs = xs + (attn_counts,)
    x, (ks, vs) = jax.lax.scan(layer_body, x, xs)
    return {"k": ks, "v": vs}, x


def prefill(params, cfg: ModelConfig, batch, cache, shards: int = 1,
            lengths=None, collect_hidden: bool = False, plan=None,
            mesh=None):
    """Blockwise prompt processing (paper §3.1): scan over N-token blocks.

    batch: {"tokens": [B,T]}, T % block_size == 0. cache length >= T.
    lengths: optional [B] true prompt lengths for right-padded batches
    (positions beyond a row's length are never attended).
    collect_hidden: also return the full hidden sequence [B,T,D]
    (pre-final-norm) so the engine can read logits at lengths-1.
    plan: SparsityPlan (None -> uniform cfg plan, the compat shim).
    Returns (cache, logits_last) or (cache, logits_last, hidden)."""
    tokens = batch["tokens"]
    ff = cfg.ff
    B, T = tokens.shape
    N = ff.block_size
    nb = T // N
    blocks = tokens.reshape(B, nb, N).transpose(1, 0, 2)  # [nb, B, N]
    plan = FF._as_plan(cfg, plan, shards=shards) if ff.enabled else None

    def block_step(cache, blk_in):
        blk_idx, tok_blk = blk_in
        is_dense = jnp.zeros((), bool)
        if ff.dense_first_block:
            is_dense = is_dense | (blk_idx == 0)
        if ff.dense_last_block:
            is_dense = is_dense | (blk_idx == nb - 1)
        cache, x = prefill_block(
            params, cfg, tok_blk, cache, blk_idx * N, is_dense=is_dense,
            lengths=lengths, shards=shards, plan=plan, mesh=mesh)
        out = x if collect_hidden else x[:, -1, :]
        return cache, out

    cache, outs = jax.lax.scan(
        block_step, cache, (jnp.arange(nb), blocks))
    if collect_hidden:
        hidden = outs.transpose(1, 0, 2, 3).reshape(B, T, -1)
        x_last = apply_norm(cfg, params["ln_f"], hidden[:, -1, :])
        logits = L.unembed(params["lm_head"], x_last)
        return cache, logits, hidden
    x_last = apply_norm(cfg, params["ln_f"], outs[-1])
    logits = L.unembed(params["lm_head"], x_last)
    return cache, logits


# ------------------------------------------------- fused prefill (ours)


def prefill_fused(params, cfg: ModelConfig, batch, cache, shards: int = 1,
                  mesh=None):
    """Beyond-paper prefill (EXPERIMENTS.md §Perf): processes ALL prompt
    blocks in parallel instead of the paper's sequential 128-token scan.

    - attention: full-sequence causal, online-softmax chunked (no [T,S]
      score materialization, no 2x masked-block waste);
    - FFN: the same per-block FastForward gather path, vmapped over
      blocks instead of scanned (identical math, no serialization);
    - KV cache written wholesale per layer.
    """
    tokens = batch["tokens"]
    ff = cfg.ff
    B, T = tokens.shape
    N = ff.block_size
    nb = T // N
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    plan = FF._as_plan(cfg, None, shards=shards) if ff.enabled else None
    chunk = cfg.attn_chunk or 512

    def sparse_all_blocks(fp, xn2):
        xb = xn2.reshape(B * nb, N, -1)
        if cfg.shardmap_ffn and mesh is not None:
            from repro.core.sparse_ffn import ffn_block_sparse_shardmap
            y = ffn_block_sparse_shardmap(fp, cfg, xb, plan.k_max, mesh)
        else:
            y = FF.ff_block_sparse(fp, cfg, xb, plan, shards)
        y = y.reshape(B, nb, N, -1)
        # dense first/last block (paper ablation Table 5): recompute the
        # two boundary blocks densely — cheap relative to nb blocks.
        if ff.dense_first_block:
            y = y.at[:, 0].set(FF.ff_dense(fp, cfg, xn2[:, :N]))
        if ff.dense_last_block:
            y = y.at[:, -1].set(FF.ff_dense(fp, cfg, xn2[:, -N:]))
        return y.reshape(B, T, -1)

    def layer_body(x, lp):
        xn = apply_norm(cfg, lp["ln1"], x)
        h = A.attend_full(lp["attn"], xn, pos, causal=True,
                          window=cfg.sliding_window,
                          rope_theta=cfg.rope_theta, chunk=chunk)
        k_new, v_new = A.project_kv(lp["attn"], xn, pos, cfg.rope_theta)
        x = x + h
        xn2 = apply_norm(cfg, lp["ln2"], x)
        if ff.enabled:
            y = sparse_all_blocks(lp["ffn"], xn2)
        else:
            y = FF.ff_dense(lp["ffn"], cfg, xn2)
        return x + y, (k_new, v_new)

    x, (ks, vs) = jax.lax.scan(layer_body, x, params["layers"])
    S_cache = cache["k"].shape[2]
    if S_cache == T:
        cache = {"k": ks.astype(cache["k"].dtype),
                 "v": vs.astype(cache["v"].dtype)}
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], ks.astype(cache["k"].dtype), 0, axis=2),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vs.astype(cache["v"].dtype), 0, axis=2),
        }
    x_last = apply_norm(cfg, params["ln_f"], x[:, -1, :])
    logits = L.unembed(params["lm_head"], x_last)
    return cache, logits


# ------------------------------------------------------------ decode step


def decode_step(params, cfg: ModelConfig, token, cache, position,
                shards: int = 1, window: Optional[int] = None,
                active=None, page_table=None, plan=None, plan_ids=None):
    """token: [B] int32; cache from init_cache; position: scalar int32
    OR [B] int32 for ragged batches (per-sequence decode positions).
    window: ring-buffer size when the cache is a sliding window.
    active: optional [B] bool (ragged path only) — rows with
    active[b] == False never write their KV (their logits are garbage
    and must be ignored); used by the serving slot pool so one
    fixed-capacity jitted step serves a churning request set.
    page_table: optional [B, max_pages] int32 — paged KV layout (cache
    leaves [L, n_pages, psz, Kv, dh]): the token writes into the page
    covering its position and attention indexes the pool through the
    table (kernels/paged_attention dispatch). Implies ragged.

    plan: SparsityPlan, or a STATIC tuple of them for mixed-effort
    serving (None -> uniform cfg plan, the compat shim). plan_ids:
    optional traced [B] int32 indexing into the tuple — each row
    decodes under its OWN plan (per-request effort) through this one
    executable: the tile-id width is the max k_max across the tuple
    and per-row traced counts mask/skip the rest."""
    ff = cfg.ff
    B = token.shape[0]
    ragged = jnp.ndim(position) == 1
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    positions = (position[:, None] if ragged
                 else jnp.full((B, 1), position))
    if ff.enabled and ff.apply_to_decode:
        plans = (plan if isinstance(plan, tuple)
                 else (FF._as_plan(cfg, plan, shards=shards),))
        plans = tuple(p for p in plans if p is not None)
    else:
        plans = ()
    # single uniform plan -> counts_lp None: no counts ride, no masking
    # — the executable is the pre-plan decode step (bit-compat path)
    sel_plan, counts_lp = FF.decode_plan_setup(plans)

    def layer_body(x, layer_in):
        if counts_lp is None:
            lp, kc, vc = layer_in
            k_row = None
        else:
            lp, kc, vc, k_row = layer_in        # [n_plans] this layer
        xn = apply_norm(cfg, lp["ln1"], x)
        k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                    cfg.rope_theta)
        if page_table is not None:
            kc, vc = A.write_kv_tok_paged(kc, vc, k_new, v_new,
                                          page_table, position,
                                          active=active)
            h = A.attend_decode_ragged_paged(
                lp["attn"], xn, kc, vc, page_table, position,
                window=window, rope_theta=cfg.rope_theta)
        elif ragged:
            # full-length cache: `window` is an attention mask here, not
            # a ring-buffer size (writes stay at absolute positions)
            kc, vc = A.write_kv_tok(kc, vc, k_new, v_new, position,
                                    active=active)
            h = A.attend_decode_ragged(lp["attn"], xn, kc, vc, position,
                                       window=window,
                                       rope_theta=cfg.rope_theta)
        else:
            if window:
                kc, vc = A.write_kv_ring(kc, vc, k_new, v_new, position,
                                         window)
            else:
                kc, vc = A.write_kv_block(kc, vc, k_new, v_new, position)
            h = A.attend_decode(lp["attn"], xn, kc, vc, position,
                                window=window, rope_theta=cfg.rope_theta)
        x = x + h
        xn2 = apply_norm(cfg, lp["ln2"], x)
        if sel_plan is not None:
            y = FF.ff_decode_sparse(
                lp["ffn"], cfg, xn2, sel_plan, shards,
                k_valid=FF.decode_k_valid(k_row, plan_ids))
        else:
            y = FF.ff_dense(lp["ffn"], cfg, xn2)
        return x + y, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"])
    if counts_lp is not None:
        xs = xs + (counts_lp,)
    x, (ks, vs) = jax.lax.scan(layer_body, x, xs)
    x = apply_norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["lm_head"], x[:, 0, :])
    return logits, {"k": ks, "v": vs}


def decode_chunk(params, cfg: ModelConfig, tokens, cache, position, **kw):
    """Chunk-scored multi-token decode: a lax.scan over THIS module's
    decode_step (speculative verify entry — see models/chunked.py)."""
    return CH.chunk_scored(decode_step, params, cfg, tokens, cache,
                           position, **kw)


def decode_draft(params, cfg: ModelConfig, token, cache, position,
                 n_steps, **kw):
    """Argmax-feedback draft proposals over THIS module's decode_step
    (speculative draft entry — see models/chunked.py)."""
    return CH.draft_steps(decode_step, params, cfg, token, cache,
                          position, n_steps, **kw)
