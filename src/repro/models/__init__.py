from repro.models.base import ModelConfig, FastForwardConfig  # noqa: F401
