"""Mixture-of-Experts decoder LM (qwen2-moe, kimi-k2).

Routed experts: top-k routing with dropless sort-based grouped dispatch
(default, `cfg.moe_dispatch="dropless"`): every selected (token, expert)
pair is computed via grouped matmuls over expert-sorted segments
(kernels/grouped_matmul: Pallas on TPU, jax.lax.ragged_dot on XLA), so
a token's routed output depends only on that token — blockwise prefill,
batched multi-request blocks, ragged decode, and the full-sequence
forward are dispatch-group invariant. `cfg.moe_dispatch="capacity"`
keeps the GShard-style capacity scatter dispatch (position-in-expert
via cumsum, token drop beyond capacity) as an opt-in training mode.
Shared experts: a dense always-on FFN path; FastForward applies HERE
(the routed experts are already contextually sparse — see DESIGN.md §4).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig
from repro.nn import param as PM
from repro.nn import layers as L
from repro.nn import attention as A
from repro.core import fastforward as FF
from repro.core import sparse_ffn as S
from repro.kernels.grouped_matmul import ops as GM
from repro.models import chunked as CH
from repro.models import dense as D
from repro.distributed.sharding import constrain


# ------------------------------------------------------------------ specs


def moe_ffn_spec(cfg: ModelConfig, dtype):
    e, dff = cfg.n_experts, cfg.d_ff_expert
    d = cfg.d_model
    sp = {
        "router": PM.ParamSpec((d, e), ("embed", None), scale=1.0, dtype=dtype),
        "wg_e": PM.ParamSpec((e, d, dff), ("expert", "embed", "mlp_expert"), dtype=dtype),
        "wu_e": PM.ParamSpec((e, d, dff), ("expert", "embed", "mlp_expert"), dtype=dtype),
        "wd_e": PM.ParamSpec((e, dff, d), ("expert", "mlp_expert", "embed"), dtype=dtype),
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.n_shared_experts * cfg.d_ff_expert
        sp["shared"] = FF.fastforward_ffn_spec(cfg, d_ff=shared_ff, dtype=dtype)
        sp["shared_gate"] = PM.ParamSpec((d, 1), ("embed", None), dtype=dtype)
    return sp


def layer_spec(cfg: ModelConfig, dtype):
    return {
        "ln1": D.norm_spec(cfg, dtype),
        "attn": A.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim, cfg.qkv_bias, dtype),
        "ln2": D.norm_spec(cfg, dtype),
        "moe": moe_ffn_spec(cfg, dtype),
    }


def specs(cfg: ModelConfig):
    dtype = cfg.dtype
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
        "layers": PM.stack_specs(layer_spec(cfg, dtype), cfg.n_layers),
        "ln_f": D.norm_spec(cfg, dtype),
        "lm_head": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
    }


# --------------------------------------------------------------- routing


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def _route(params, cfg: ModelConfig, xf, live):
    """Shared router head: xf [N, D], live optional [N] bool ->
    (top_p [N, K] f32 renormalized, top_e [N, K] int32, aux scalar).

    The Switch-style load-balance loss excludes masked tokens from both
    statistics — inactive pad rows (dead KV slots, short prefill ticks)
    would otherwise skew me/ce toward whatever experts dead inputs
    happen to score highest."""
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("nd,de->ne", xf, params["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [N, E]
    top_p, top_e = jax.lax.top_k(probs, K)                       # [N, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style), live tokens only
    hot = jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=1)
    if live is None:
        me = jnp.mean(probs, axis=0)                             # [E]
        ce = jnp.mean(hot, axis=0)
    else:
        w = live.astype(jnp.float32)
        n_live = jnp.maximum(w.sum(), 1.0)
        me = jnp.sum(probs * w[:, None], axis=0) / n_live
        ce = jnp.sum(hot * w[:, None], axis=0) / n_live
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)
    return top_p, top_e, aux


def routed_experts(params, cfg: ModelConfig, x, token_mask=None):
    """x: [B, T, D] -> (y [B, T, D], aux_loss scalar).

    token_mask: optional [B, T] bool — masked-out tokens neither enter
    the dispatch nor receive routed output (serving: inactive KV slots
    ride along in fixed-shape batches).

    Dispatch mode (cfg.moe_dispatch): "dropless" computes every
    selected (token, expert) pair — dispatch-group invariant, the
    serving default; "capacity" is the GShard-style token-drop scatter
    path, kept as an opt-in training mode."""
    if cfg.moe_dispatch == "dropless":
        return _routed_dropless(params, cfg, x, token_mask)
    if cfg.moe_dispatch == "capacity":
        return _routed_capacity(params, cfg, x, token_mask)
    raise ValueError(f"unknown moe_dispatch={cfg.moe_dispatch!r}; "
                     f"expected 'dropless' or 'capacity'")


def _routed_dropless(params, cfg: ModelConfig, x, token_mask):
    """Dropless sort-based grouped dispatch: argsort the flattened
    (token, expert) assignments by expert id (stable), compute per-
    expert segment sizes, run grouped matmuls over the sorted rows
    (kernels.grouped_matmul: Pallas on TPU, jax.lax.ragged_dot on XLA
    — verified row-invariant to the surrounding group sizes), then
    unsort and combine in fixed top-k order. No token is ever dropped,
    so the routed output of a token is identical whichever
    batch/block/dispatch group it shipped with — the invariant the
    blockwise serving stack asserts against the full forward.

    Masked tokens route to a sentinel id E that sorts PAST every real
    expert segment: they contribute zero group length and fall in the
    leftover tail the grouped matmul zeroes out."""
    B, T, Dm = x.shape
    N = B * T
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(N, Dm)
    live = None if token_mask is None else token_mask.reshape(N)
    top_p, top_e, aux = _route(params, cfg, xf, live)

    flat_e = top_e.reshape(-1)                                   # [N*K]
    if live is not None:
        flat_e = jnp.where(jnp.repeat(live, K), flat_e, E)       # sentinel
    order = jnp.argsort(flat_e)        # stable: ties keep token order
    inv = jnp.argsort(order)           # inverse permutation (unsort)
    xs = xf[order // K]                                          # [N*K, D]
    # sentinel ids fall outside length=E and are dropped from the count
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

    h_g = GM.grouped_matmul_op(xs, params["wg_e"], group_sizes)
    h_u = GM.grouped_matmul_op(xs, params["wu_e"], group_sizes)
    h = L.swiglu(h_g.astype(x.dtype), h_u.astype(x.dtype))
    out = GM.grouped_matmul_op(h, params["wd_e"], group_sizes)   # [N*K, D]

    w = top_p.astype(jnp.float32)                                # [N, K]
    if live is not None:
        w = w * live.astype(jnp.float32)[:, None]
    y = jnp.sum(out[inv].reshape(N, K, Dm) * w[:, :, None], axis=1)
    return y.reshape(B, T, Dm).astype(x.dtype), aux


def _routed_capacity(params, cfg: ModelConfig, x, token_mask):
    """GShard-style scatter dispatch (opt-in via
    cfg.moe_dispatch="capacity"): position-in-expert via cumsum, tokens
    beyond capacity are DROPPED (their routed contribution is zero —
    the shared expert/residual still carries them). Capacity depends on
    the dispatch-group size, so this path is dispatch-group DEPENDENT:
    chunked/blockwise serving would route differently than the full
    forward. Training only."""
    B, T, Dm = x.shape
    N = B * T
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(N, cfg)
    xf = x.reshape(N, Dm)
    live = None if token_mask is None else token_mask.reshape(N)
    top_p, top_e, aux = _route(params, cfg, xf, live)

    flat_e = top_e.reshape(-1)                                   # [N*K]
    flat_w = top_p.reshape(-1).astype(jnp.float32)
    flat_tok = jnp.arange(N * K) // K

    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # [N*K, E]
    if live is not None:
        onehot = onehot * live[flat_tok].astype(jnp.int32)[:, None]
    # sharding probe (EXPERIMENTS.md §Perf K1): explicit constraint is a
    # no-op — GSPMD already keeps the bookkeeping token-sharded; the MoE
    # collective cost is the scatter-add into the [E,C,D] buffer below.
    onehot = constrain(onehot, ("batch", None))
    pos = jnp.cumsum(onehot, axis=0) * onehot                    # 1-based
    pos_in_e = jnp.max(pos, axis=-1) - 1                         # [N*K]
    keep = (pos_in_e >= 0) & (pos_in_e < C)
    slot = jnp.clip(pos_in_e, 0, C - 1)

    buf = jnp.zeros((E, C, Dm), x.dtype)
    contrib = jnp.where(keep[:, None], xf[flat_tok], 0).astype(x.dtype)
    buf = buf.at[flat_e, slot].add(contrib, mode="drop")
    buf = constrain(buf, ("expert", None, None))

    h_g = jnp.einsum("ecd,edf->ecf", buf, params["wg_e"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    h_u = jnp.einsum("ecd,edf->ecf", buf, params["wu_e"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    h = L.swiglu(h_g, h_u)
    out = jnp.einsum("ecf,efd->ecd", h, params["wd_e"],
                     preferred_element_type=jnp.float32).astype(x.dtype)

    gathered = out[flat_e, slot]                                 # [N*K, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((N, Dm), jnp.float32).at[flat_tok].add(
        gathered.astype(jnp.float32) * flat_w[:, None])
    return y.reshape(B, T, Dm).astype(x.dtype), aux


def moe_block(params, cfg: ModelConfig, x, budget=None, mode="train",
              plan=None, shards=1, is_dense=None, token_mask=None,
              k_valid=None, k_tiles=None):
    """Full MoE FFN: routed experts + (FastForward-sparsified) shared
    expert. mode: train (mask path) | block (gather path) | dense.
    plan: SparsityPlan resolved for the SHARED expert's FFN width (see
    `shared_plan`); k_valid: traced per-layer/per-row valid tile count;
    k_tiles: deprecated int shim."""
    y, aux = routed_experts(params, cfg, x, token_mask=token_mask)
    if cfg.n_shared_experts:
        sp = params["shared"]
        if plan is None and k_tiles:
            plan = FF._as_plan(cfg, int(k_tiles),
                               d_ff=_shared_ff_width(cfg))
        if cfg.ff.enabled and mode == "train":
            ys = FF.ff_masked_sequence(sp, cfg, x, budget,
                                       k_tiles=k_valid)
        elif cfg.ff.enabled and mode == "block" and plan is not None:
            ys = FF.ff_block_sparse(sp, cfg, x, plan, shards, is_dense,
                                    k_valid=k_valid)
        else:
            ys = FF.ff_dense(sp, cfg, x)
        gate = jax.nn.sigmoid(
            jnp.einsum("btd,do->bto", x, params["shared_gate"],
                       preferred_element_type=jnp.float32))
        y = y + (gate * ys.astype(jnp.float32)).astype(y.dtype)
    return y, aux


def _shared_ff_width(cfg: ModelConfig) -> int:
    return cfg.n_shared_experts * cfg.d_ff_expert


def shared_plan(cfg: ModelConfig, plan=None, shards: int = 1):
    """Resolve the SHARED expert's SparsityPlan. FastForward applies to
    the always-on shared expert only (the routed experts are already
    contextually sparse — DESIGN.md §4), whose FFN width differs from
    cfg.d_ff: a plan resolved for the model is re-derived onto the
    shared tile grid (`SparsityPlan.with_tiles` — the uniform shim
    reproduces the legacy `shared_k_tiles` count exactly)."""
    if not (cfg.ff.enabled and cfg.n_shared_experts):
        return None
    width = _shared_ff_width(cfg)
    if plan is None:
        return FF.resolve_plan(cfg, d_ff=width, shards=shards)
    if isinstance(plan, (int, np.integer)):
        return FF._as_plan(cfg, int(plan), d_ff=width)
    n_tiles = max(width // cfg.ff.tile, 1)
    return plan.with_tiles(n_tiles)


def shared_k_tiles(cfg: ModelConfig, shards: int = 1) -> int:
    """DEPRECATED shim: uniform shared-expert tile count (pre-plan)."""
    if not (cfg.ff.enabled and cfg.n_shared_experts):
        return 0
    return FF.k_tiles_for(cfg, d_ff=_shared_ff_width(cfg), shards=shards)


# ---------------------------------------------------------------- forward


def forward(params, cfg: ModelConfig, batch, budgets=None, plan=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    B, T = x.shape[:2]
    x = constrain(x, ("batch", None, None))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    counts = None
    splan = shared_plan(cfg, plan) if plan is not None else None
    if splan is not None:
        counts = splan.counts_array()
        budgets = jnp.asarray(splan.keep_fracs, jnp.float32)
    elif budgets is None:
        budgets = jnp.asarray(FF.layer_budgets(cfg), jnp.float32)

    def body(carry, layer_in):
        x, aux = carry
        if counts is None:
            lp, budget = layer_in
            k_l = None
        else:
            lp, budget, k_l = layer_in
        xn = D.apply_norm(cfg, lp["ln1"], x)
        h = A.attend_full(lp["attn"], xn, pos, causal=True,
                          window=cfg.sliding_window,
                          rope_theta=cfg.rope_theta,
                          chunk=cfg.attn_chunk)
        x = x + h
        xn2 = D.apply_norm(cfg, lp["ln2"], x)
        y, a = moe_block(lp["moe"], cfg, xn2, budget, mode="train",
                         k_valid=k_l)
        x = constrain(x + y, ("batch", None, None))
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = ((params["layers"], budgets) if counts is None
          else (params["layers"], budgets, counts))
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)), xs)
    x = D.apply_norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["lm_head"], x)
    logits = constrain(logits, ("batch", None, "vocab"))
    return logits, {"aux_loss": aux}


# ------------------------------------------------------- cache + serving


cache_spec = D.cache_spec
init_cache = D.init_cache


def prefill_block(params, cfg: ModelConfig, tok_blk, cache, pos0, *,
                  is_dense=None, lengths=None, shards: int = 1,
                  plan=None, k_tiles=None):
    """One N-token block at offset `pos0` (MoE twin of
    repro.models.dense.prefill_block — the schedulable prefill unit of
    the continuous-batching runtime). Dropless routed dispatch is
    dispatch-group invariant, so the blockwise scan reproduces the
    full-sequence `forward` routing token-for-token.
    plan: SparsityPlan (model-width; re-derived for the shared expert
    via `shared_plan`); k_tiles: deprecated int shim.
    Returns (cache, hidden [B, N, D]) pre-final-norm."""
    ff = cfg.ff
    if plan is None and k_tiles is not None:
        plan = k_tiles
    splan = shared_plan(cfg, plan, shards)
    counts = (None if splan is None or splan.is_uniform
              else splan.counts_array())
    attn_counts = (splan.attn_counts_array()
                   if splan is not None and splan.has_attn else None)
    N = tok_blk.shape[1]
    x = L.embed(params["embed"], tok_blk).astype(cfg.dtype)
    positions = pos0 + jnp.arange(N)[None, :]

    def layer_body(x, layer_in):
        lp, kc, vc, *rest = layer_in
        rest = list(rest)
        k_l = rest.pop(0) if counts is not None else None
        a_l = rest.pop(0) if attn_counts is not None else None
        attn_sel = (None if a_l is None
                    else (splan.attn_k_max, splan.attn_tiles, a_l))
        xn = D.apply_norm(cfg, lp["ln1"], x)
        k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                    cfg.rope_theta)
        kc, vc = A.write_kv_block(kc, vc, k_new, v_new, pos0)
        h = A.attend_block_cached(lp["attn"], xn, kc, vc, pos0,
                                  window=cfg.sliding_window,
                                  rope_theta=cfg.rope_theta,
                                  lengths=lengths, attn_sel=attn_sel,
                                  attn_threshold=ff.attn_threshold or None)
        x = x + h
        xn2 = D.apply_norm(cfg, lp["ln2"], x)
        y, _ = moe_block(lp["moe"], cfg, xn2, mode="block",
                         plan=splan, shards=shards,
                         is_dense=is_dense, k_valid=k_l)
        return x + y, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"])
    if counts is not None:
        xs = xs + (counts,)
    if attn_counts is not None:
        xs = xs + (attn_counts,)
    x, (ks, vs) = jax.lax.scan(layer_body, x, xs)
    return {"k": ks, "v": vs}, x


def prefill_blocks(params, cfg: ModelConfig, tok_blks, cache, pos0s, *,
                   is_dense=None, lengths=None, active=None,
                   page_tables=None, shards: int = 1, plan=None,
                   k_tiles=None):
    """Batched per-row-offset block prefill (MoE twin of
    repro.models.dense.prefill_blocks): one N-token block of EACH of P
    distinct requests per call. tok_blks [P, N]; cache leaves
    [L, P, S, Kv, dh]; pos0s/lengths [P]; is_dense [P] bool (per-row
    dense forcing of the shared expert — see FF.ff_blocks_sparse).

    active: optional [P] bool — inactive padding rows are routed to the
    dropless dispatch's sentinel group (zero group length), so they
    neither receive routed output nor perturb live rows, and they are
    excluded from the router's load-balance statistics. Their KV
    writes are discarded by the runtime at scatter-back (slot layout)
    or masked into null-page self-copies (paged layout).

    page_tables: optional [P, max_pages] int32 — paged KV layout: cache
    leaves are the whole page pool [L, n_pages, psz, Kv, dh], written
    and attended through the tables (see the dense twin).
    plan: SparsityPlan (model-width, static — the scheduler batches
    only same-plan rows); k_tiles: deprecated int shim.
    Returns (cache, hidden [P, N, D]) pre-final-norm."""
    ff = cfg.ff
    if plan is None and k_tiles is not None:
        plan = k_tiles
    splan = shared_plan(cfg, plan, shards)
    counts = (None if splan is None or splan.is_uniform
              else splan.counts_array())
    attn_counts = (splan.attn_counts_array()
                   if splan is not None and splan.has_attn else None)
    N = tok_blks.shape[1]
    x = L.embed(params["embed"], tok_blks).astype(cfg.dtype)
    token_mask = None if active is None else (
        jnp.broadcast_to(active[:, None], tok_blks.shape))

    def layer_body(x, layer_in):
        lp, kc, vc, *rest = layer_in
        rest = list(rest)
        k_l = rest.pop(0) if counts is not None else None
        a_l = rest.pop(0) if attn_counts is not None else None
        attn_sel = (None if a_l is None
                    else (splan.attn_k_max, splan.attn_tiles, a_l))
        xn = D.apply_norm(cfg, lp["ln1"], x)
        positions = pos0s[:, None] + jnp.arange(N)[None, :]
        k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                    cfg.rope_theta)
        if page_tables is None:
            kc, vc = A.write_kv_rows(kc, vc, k_new, v_new, pos0s)
            h = A.attend_block_rows(lp["attn"], xn, kc, vc, pos0s,
                                    window=cfg.sliding_window,
                                    rope_theta=cfg.rope_theta,
                                    lengths=lengths, attn_sel=attn_sel,
                                    attn_threshold=(ff.attn_threshold
                                                    or None))
        else:
            kc, vc = A.write_kv_rows_paged(kc, vc, k_new, v_new,
                                           page_tables, pos0s,
                                           active=active)
            h = A.attend_block_rows_paged(lp["attn"], xn, kc, vc,
                                          page_tables, pos0s,
                                          window=cfg.sliding_window,
                                          rope_theta=cfg.rope_theta,
                                          lengths=lengths,
                                          attn_sel=attn_sel,
                                          attn_threshold=(ff.attn_threshold
                                                          or None))
        x = x + h
        xn2 = D.apply_norm(cfg, lp["ln2"], x)
        y, _ = moe_block(lp["moe"], cfg, xn2, mode="block",
                         plan=splan, shards=shards,
                         is_dense=is_dense, token_mask=token_mask,
                         k_valid=k_l)
        return x + y, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"])
    if counts is not None:
        xs = xs + (counts,)
    if attn_counts is not None:
        xs = xs + (attn_counts,)
    x, (ks, vs) = jax.lax.scan(layer_body, x, xs)
    return {"k": ks, "v": vs}, x


def prefill(params, cfg: ModelConfig, batch, cache, shards: int = 1,
            lengths=None, collect_hidden: bool = False, plan=None):
    """Blockwise prompt processing (MoE twin of
    repro.models.dense.prefill). collect_hidden: also return the full
    hidden sequence [B, T, D] pre-final-norm so the static engine can
    read logits at lengths-1 for right-padded batches.
    plan: SparsityPlan (None -> uniform cfg plan, the compat shim)."""
    tokens = batch["tokens"]
    ff = cfg.ff
    B, T = tokens.shape
    N = ff.block_size
    nb = T // N
    blocks = tokens.reshape(B, nb, N).transpose(1, 0, 2)

    def block_step(cache, blk_in):
        blk_idx, tok_blk = blk_in
        is_dense = jnp.zeros((), bool)
        if ff.dense_first_block:
            is_dense = is_dense | (blk_idx == 0)
        if ff.dense_last_block:
            is_dense = is_dense | (blk_idx == nb - 1)
        cache, x = prefill_block(
            params, cfg, tok_blk, cache, blk_idx * N, is_dense=is_dense,
            lengths=lengths, shards=shards, plan=plan)
        out = x if collect_hidden else x[:, -1, :]
        return cache, out

    cache, outs = jax.lax.scan(block_step, cache, (jnp.arange(nb), blocks))
    if collect_hidden:
        hidden = outs.transpose(1, 0, 2, 3).reshape(B, T, -1)
        x_last = D.apply_norm(cfg, params["ln_f"], hidden[:, -1, :])
        return cache, L.unembed(params["lm_head"], x_last), hidden
    x_last = D.apply_norm(cfg, params["ln_f"], outs[-1])
    return cache, L.unembed(params["lm_head"], x_last)


def decode_step(params, cfg: ModelConfig, token, cache, position,
                shards: int = 1, window=None, active=None,
                page_table=None, plan=None, plan_ids=None):
    """position: scalar int32 OR [B] int32 (ragged per-sequence decode);
    active: optional [B] bool mask for the ragged path; page_table:
    optional [B, max_pages] int32 for the paged KV layout (see
    repro.models.dense.decode_step). plan/plan_ids: SparsityPlan — or a
    static tuple + traced [B] ids for mixed-effort serving (plans are
    re-derived onto the shared expert's tile grid; see the dense twin
    for the per-row count mechanism)."""
    ff = cfg.ff
    B = token.shape[0]
    ragged = jnp.ndim(position) == 1
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    positions = (position[:, None] if ragged
                 else jnp.full((B, 1), position))
    if ff.enabled and ff.apply_to_decode:
        raw = plan if isinstance(plan, tuple) else (plan,)
        plans = tuple(p for p in (shared_plan(cfg, p, shards)
                                  for p in raw) if p is not None)
    else:
        plans = ()
    # single uniform plan -> counts_lp None (pre-plan bit-compat path)
    sel_plan, counts_lp = FF.decode_plan_setup(plans)
    # inactive slots route to the dropless sentinel group: they receive
    # no routed output and stay out of the load-balance statistics
    token_mask = None if active is None else active[:, None]

    def layer_body(x, layer_in):
        if counts_lp is None:
            lp, kc, vc = layer_in
            k_row = None
        else:
            lp, kc, vc, k_row = layer_in
        xn = D.apply_norm(cfg, lp["ln1"], x)
        k_new, v_new = A.project_kv(lp["attn"], xn, positions,
                                    cfg.rope_theta)
        if page_table is not None:
            kc, vc = A.write_kv_tok_paged(kc, vc, k_new, v_new,
                                          page_table, position,
                                          active=active)
            h = A.attend_decode_ragged_paged(
                lp["attn"], xn, kc, vc, page_table, position,
                window=window, rope_theta=cfg.rope_theta)
        elif ragged:
            kc, vc = A.write_kv_tok(kc, vc, k_new, v_new, position,
                                    active=active)
            h = A.attend_decode_ragged(lp["attn"], xn, kc, vc, position,
                                       window=window,
                                       rope_theta=cfg.rope_theta)
        elif window:
            kc, vc = A.write_kv_ring(kc, vc, k_new, v_new, position, window)
            h = A.attend_decode(lp["attn"], xn, kc, vc, position,
                                window=window, rope_theta=cfg.rope_theta)
        else:
            kc, vc = A.write_kv_block(kc, vc, k_new, v_new, position)
            h = A.attend_decode(lp["attn"], xn, kc, vc, position,
                                window=window, rope_theta=cfg.rope_theta)
        x = x + h
        xn2 = D.apply_norm(cfg, lp["ln2"], x)
        if sel_plan is not None:
            y, _ = moe_block(lp["moe"], cfg, xn2, mode="block",
                             plan=sel_plan, shards=shards,
                             token_mask=token_mask,
                             k_valid=FF.decode_k_valid(k_row, plan_ids))
        else:
            y, _ = moe_block(lp["moe"], cfg, xn2, mode="dense",
                             shards=shards, token_mask=token_mask)
        return x + y, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"])
    if counts_lp is not None:
        xs = xs + (counts_lp,)
    x, (ks, vs) = jax.lax.scan(layer_body, x, xs)
    x = D.apply_norm(cfg, params["ln_f"], x)
    logits = L.unembed(params["lm_head"], x[:, 0, :])
    return logits, {"k": ks, "v": vs}


def decode_chunk(params, cfg: ModelConfig, tokens, cache, position, **kw):
    """Chunk-scored multi-token decode over THIS module's decode_step
    (speculative verify entry — MoE twin; see models/chunked.py)."""
    return CH.chunk_scored(decode_step, params, cfg, tokens, cache,
                           position, **kw)


def decode_draft(params, cfg: ModelConfig, token, cache, position,
                 n_steps, **kw):
    """Argmax-feedback draft proposals over THIS module's decode_step
    (speculative draft entry — MoE twin; see models/chunked.py)."""
    return CH.draft_steps(decode_step, params, cfg, token, cache,
                          position, n_steps, **kw)
