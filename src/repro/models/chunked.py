"""Multi-token chunk-scored decode built from a model's decode_step.

The speculative-decode protocol (serving/speculative.py) needs two
fixed-shape entries beyond the single-token decode step:

  * ``draft_steps``  — k argmax-feedback applications of decode_step
    under the draft plan, writing KV at positions p .. p+k-1;
  * ``verify_chunk`` — one chunk-scored pass feeding [t0, d_1 .. d_k]
    at positions p .. p+k under the verify plan, REWRITING the draft's
    KV so draft-plan state is never read by accepted computation.

Both are ``lax.scan`` loops over the model's OWN single-token
``decode_step`` body — not a reimplementation — so every per-step
computation (attention masks, per-row traced plan counts, masked KV
writes) is bit-identical to the sequential greedy loop by
construction.  models/{dense,moe}.py re-export thin wrappers bound to
their decode_step; serving/runtime.py jits those with a static chunk
width.

Per-row validity rides as a traced [B] int vector (``n_valid`` /
``n_draft``): step j of a row is live iff ``active[b] and
j < n_valid[b]``.  Dead steps take the existing masked-write path
(slot: self-copy; paged: null-page sink), so page-shortage fallback,
cache_len clamps, temperature rows (n_draft == 0), and k == 0
degeneration all fit one compiled shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["chunk_scored", "draft_steps"]


def chunk_scored(step_fn, params, cfg, tokens, cache, position, *,
                 shards: int = 1, window=None, active=None, n_valid=None,
                 page_table=None, plan=None, plan_ids=None):
    """Score a [B, T] token chunk with T applications of ``step_fn``.

    tokens[:, 0] is each row's committed next_token t0; tokens[:, 1:]
    are draft proposals. position: [B] int32 — row b writes KV at
    position[b] + j on step j (rewriting any draft-plan KV there).
    n_valid: optional traced [B] int — steps j >= n_valid[b] are
    masked (no KV write; their outputs are padding).

    Returns (logits0 [B, V], greedy [B, T] int32, cache): the step-0
    logits (exactly the single-token decode_step logits — used for
    sampling rows) and the per-step argmax g_0 .. g_{T-1}.
    """
    B, T = tokens.shape
    base = (jnp.ones((B,), dtype=bool) if active is None
            else jnp.asarray(active))

    def step(cache, inp):
        j, tok = inp
        live = base if n_valid is None else base & (j < n_valid)
        logits, cache = step_fn(params, cfg, tok, cache, position + j,
                                shards, window, active=live,
                                page_table=page_table, plan=plan,
                                plan_ids=plan_ids)
        return cache, (logits, jnp.argmax(logits, -1).astype(jnp.int32))

    cache, (logits_all, greedy) = jax.lax.scan(
        step, cache, (jnp.arange(T), jnp.swapaxes(tokens, 0, 1)))
    return logits_all[0], jnp.swapaxes(greedy, 0, 1), cache


def draft_steps(step_fn, params, cfg, token, cache, position, n_steps, *,
                shards: int = 1, window=None, active=None, n_draft=None,
                page_table=None, plan=None, plan_ids=None):
    """Propose ``n_steps`` tokens by argmax feedback of ``step_fn``.

    token: [B] int32 — each row's committed next_token t0.  Step j
    feeds the previous proposal at position[b] + j; rows with
    ``j >= n_draft[b]`` stop writing KV and freeze their feedback
    token (their remaining draft entries are padding the acceptance
    rule never reads).  n_steps is STATIC (one compile per draft
    length).  Returns (drafts [B, n_steps] int32, cache).
    """
    B = token.shape[0]
    base = (jnp.ones((B,), dtype=bool) if active is None
            else jnp.asarray(active))

    def step(carry, j):
        cache, tok = carry
        live = base if n_draft is None else base & (j < n_draft)
        logits, cache = step_fn(params, cfg, tok, cache, position + j,
                                shards, window, active=live,
                                page_table=page_table, plan=plan,
                                plan_ids=plan_ids)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        tok = jnp.where(live, nxt, tok)
        return (cache, tok), tok

    (cache, _), drafts = jax.lax.scan(step, (cache, jnp.asarray(token)),
                                      jnp.arange(n_steps))
    return jnp.swapaxes(drafts, 0, 1), cache
