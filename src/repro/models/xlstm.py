"""xLSTM LM (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

d_ff = 0 — there is NO feed-forward network in these blocks, so the
paper's FFN-sparsity technique is inapplicable (DESIGN.md
§Arch-applicability); the architecture runs dense. Attention-free:
long_500k decode is native (O(1) state).

Layers are scanned in (mLSTM, sLSTM) pairs: even layers mLSTM (matrix
memory, chunk-parallel), odd layers sLSTM (scalar memory, sequential).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.nn import param as PM
from repro.nn import layers as L
from repro.models import ssm_ops as O


def _dims(cfg: ModelConfig):
    D = cfg.d_model
    Di = cfg.ssm_expand * D
    H = cfg.n_heads
    return D, Di, H, Di // H


def mlstm_spec(cfg: ModelConfig, dtype):
    D, Di, H, dh = _dims(cfg)
    return {
        "ln": L.rmsnorm_spec(D, dtype),
        "w_up": PM.ParamSpec((D, 2 * Di), ("embed", "mlp"), dtype=dtype),
        "conv_w": PM.ParamSpec((cfg.ssm_conv, Di), (None, "mlp"),
                               init="normal", scale=0.1, dtype=dtype),
        "conv_b": PM.ParamSpec((Di,), ("mlp",), init="zeros", dtype=dtype),
        "wq": PM.ParamSpec((Di, Di), ("mlp", None), dtype=dtype),
        "wk": PM.ParamSpec((Di, Di), ("mlp", None), dtype=dtype),
        "wv": PM.ParamSpec((Di, Di), ("mlp", None), dtype=dtype),
        "w_i": PM.ParamSpec((Di, H), ("mlp", None), dtype=dtype),
        "b_i": PM.ParamSpec((H,), (None,), init="zeros", dtype=dtype),
        "w_f": PM.ParamSpec((Di, H), ("mlp", None), dtype=dtype),
        # positive forget-gate bias: start near "remember everything"
        "b_f": PM.ParamSpec((H,), (None,), init="ones", dtype=dtype),
        "ln_h": L.rmsnorm_spec(Di, dtype),
        "w_down": PM.ParamSpec((Di, D), ("mlp", "embed"), dtype=dtype),
    }


def slstm_spec(cfg: ModelConfig, dtype):
    D, _, H, _ = _dims(cfg)
    dh = D // H
    return {
        "ln": L.rmsnorm_spec(D, dtype),
        "w": PM.ParamSpec((D, 4 * D), ("embed", "mlp"), dtype=dtype),
        "b": PM.ParamSpec((4 * D,), ("mlp",), init="zeros", dtype=dtype),
        "r": PM.ParamSpec((H, dh, 4 * dh), (None, None, None),
                          init="normal", scale=0.05, dtype=dtype),
        "ln_h": L.rmsnorm_spec(D, dtype),
        "w_out": PM.ParamSpec((D, D), ("embed", None), dtype=dtype),
    }


def pair_spec(cfg: ModelConfig, dtype):
    return {"m": mlstm_spec(cfg, dtype), "s": slstm_spec(cfg, dtype)}


def specs(cfg: ModelConfig):
    dtype = cfg.dtype
    assert cfg.n_layers % 2 == 0, "xLSTM layers alternate mLSTM/sLSTM"
    return {
        "embed": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
        "pairs": PM.stack_specs(pair_spec(cfg, dtype), cfg.n_layers // 2),
        "ln_f": L.rmsnorm_spec(cfg.d_model, dtype),
        "lm_head": L.embedding_spec(cfg.vocab, cfg.d_model, dtype),
    }


# ------------------------------------------------------------- block fwd


def mlstm_block(lp, cfg: ModelConfig, x, state=None, chunk=None):
    """x: [B,T,D]. state: (C,n,m,conv) or None. Returns (y, state)."""
    D, Di, H, dh = _dims(cfg)
    xn = L.rmsnorm(lp["ln"], x)
    conv_state = None if state is None else state[3]

    def conv_fn(xm):
        if conv_state is not None:
            pad = jnp.concatenate([conv_state, xm], axis=1)
            return O.causal_conv1d(pad, lp["conv_w"], lp["conv_b"])[
                :, conv_state.shape[1]:]
        return O.causal_conv1d(xm, lp["conv_w"], lp["conv_b"])

    up = jnp.einsum("...d,dk->...k", xn, lp["w_up"],
                    preferred_element_type=jnp.float32).astype(xn.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    xc_raw = conv_fn(xm)
    xc = L.silu(xc_raw)
    T = x.shape[1]
    q = (xc @ lp["wq"]).reshape(x.shape[0], T, H, dh)
    k = (xc @ lp["wk"]).reshape(x.shape[0], T, H, dh)
    v = (xm @ lp["wv"]).reshape(x.shape[0], T, H, dh)
    ig = (xc @ lp["w_i"] + lp["b_i"]).astype(jnp.float32)
    fg = (xc @ lp["w_f"] + lp["b_f"]).astype(jnp.float32)
    cell_state = None if state is None else state[:3]
    h, (C, n, m) = O.mlstm_chunked(q, k, v, ig, fg,
                                   chunk or cfg.ssm_chunk, cell_state)
    h = L.rmsnorm(lp["ln_h"], h.reshape(x.shape[0], T, Di))
    h = h * L.silu(z)
    y = jnp.einsum("...k,kd->...d", h, lp["w_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    new_conv = xm[:, -(cfg.ssm_conv - 1):, :]
    if state is not None:
        # keep conv tail across short blocks
        pad = jnp.concatenate([state[3], xm], axis=1)
        new_conv = pad[:, -(cfg.ssm_conv - 1):, :]
    return x + y, (C, n, m, new_conv)


def slstm_block(lp, cfg: ModelConfig, x, state=None):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    B, T, _ = x.shape
    xn = L.rmsnorm(lp["ln"], x)
    g = (jnp.einsum("...d,dg->...g", xn, lp["w"],
                    preferred_element_type=jnp.float32)
         + lp["b"].astype(jnp.float32))
    g = g.reshape(B, T, 4, H, dh)
    zg, ig, fg, og = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
    cell = None if state is None else state
    hs, new_state = O.slstm_scan(zg, ig, fg, og, lp["r"], cell)
    h = L.rmsnorm(lp["ln_h"], hs.reshape(B, T, D).astype(x.dtype))
    y = jnp.einsum("...d,do->...o", h, lp["w_out"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return x + y, new_state


# ----------------------------------------------------------------- model


def forward(params, cfg: ModelConfig, batch, budgets=None):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)

    def body(x, pp):
        x, _ = mlstm_block(pp["m"], cfg, x)
        x, _ = slstm_block(pp["s"], cfg, x)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["pairs"])
    x = L.rmsnorm(params["ln_f"], x)
    return L.unembed(params["lm_head"], x), {}


# ------------------------------------------------------------------ cache


def cache_spec(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """State cache (no KV): cache_len is ignored (O(1) state)."""
    del cache_len
    D, Di, H, dh = _dims(cfg)
    np_ = cfg.n_layers // 2
    dh_s = cfg.d_model // H
    f32 = jnp.float32
    ax5 = ("layers", "batch", None, None, None)
    ax4 = ("layers", "batch", None, None)
    ax3 = ("layers", "batch", None)
    return {
        "mC": PM.ParamSpec((np_, batch, H, dh, dh), ax5, init="zeros", dtype=f32),
        "mn": PM.ParamSpec((np_, batch, H, dh), ax4, init="zeros", dtype=f32),
        "mm": PM.ParamSpec((np_, batch, H), ax3, init="zeros", dtype=f32),
        "mconv": PM.ParamSpec((np_, batch, cfg.ssm_conv - 1, Di), ax4,
                              init="zeros", dtype=dtype or cfg.dtype),
        "sc": PM.ParamSpec((np_, batch, H, dh_s), ax4, init="zeros", dtype=f32),
        "sn": PM.ParamSpec((np_, batch, H, dh_s), ax4, init="zeros", dtype=f32),
        "sh": PM.ParamSpec((np_, batch, H, dh_s), ax4, init="zeros", dtype=f32),
        "sm": PM.ParamSpec((np_, batch, H, dh_s), ax4, init="zeros", dtype=f32),
    }


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    return jax.tree.map(
        lambda s: (jnp.ones if s.init == "ones" else jnp.zeros)(s.shape, s.dtype),
        cache_spec(cfg, batch, cache_len, dtype), is_leaf=PM.is_spec)


def prefill(params, cfg: ModelConfig, batch, cache, shards: int = 1):
    """Chunk-parallel prefill over the whole prompt, carrying states."""
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)

    def body(x, pin):
        pp, mC, mn, mm, mconv, sc, sn, sh, sm = pin
        x, (C, n, m, cv) = mlstm_block(pp["m"], cfg, x,
                                       state=(mC, mn, mm, mconv))
        x, (c2, n2, h2, m2) = slstm_block(pp["s"], cfg, x,
                                          state=(sc, sn, sh, sm))
        return x, (C, n, m, cv, c2, n2, h2, m2)

    x, states = jax.lax.scan(
        body, x, (params["pairs"], cache["mC"], cache["mn"], cache["mm"],
                  cache["mconv"], cache["sc"], cache["sn"], cache["sh"],
                  cache["sm"]))
    cache = {"mC": states[0], "mn": states[1], "mm": states[2],
             "mconv": states[3].astype(cache["mconv"].dtype),
             "sc": states[4], "sn": states[5], "sh": states[6],
             "sm": states[7]}
    xl = L.rmsnorm(params["ln_f"], x[:, -1, :])
    return cache, L.unembed(params["lm_head"], xl)


def decode_step(params, cfg: ModelConfig, token, cache, position,
                shards: int = 1, window=None):
    del position, window
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)

    def body(x, pin):
        pp, mC, mn, mm, mconv, sc, sn, sh, sm = pin
        x, (C, n, m, cv) = mlstm_block(pp["m"], cfg, x,
                                       state=(mC, mn, mm, mconv), chunk=1)
        x, (c2, n2, h2, m2) = slstm_block(pp["s"], cfg, x,
                                          state=(sc, sn, sh, sm))
        return x, (C, n, m, cv, c2, n2, h2, m2)

    x, states = jax.lax.scan(
        body, x, (params["pairs"], cache["mC"], cache["mn"], cache["mm"],
                  cache["mconv"], cache["sc"], cache["sn"], cache["sh"],
                  cache["sm"]))
    cache = {"mC": states[0], "mn": states[1], "mm": states[2],
             "mconv": states[3].astype(cache["mconv"].dtype),
             "sc": states[4], "sn": states[5], "sh": states[6],
             "sm": states[7]}
    xl = L.rmsnorm(params["ln_f"], x[:, 0, :])
    return L.unembed(params["lm_head"], xl), cache
