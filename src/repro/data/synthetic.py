"""Deterministic synthetic LM data: Zipf-Markov token streams.

Stands in for Minipile (offline container). The distribution has real
structure — a sparse Markov transition graph with Zipfian fan-out — so
models trained on it show decreasing loss, flocking-like FFN activation
statistics, and non-trivial predictor/compensator distillation targets.
"""
from __future__ import annotations

import numpy as np


class ZipfMarkov:
    """Per-state Zipf sampling over a sparse random transition table."""

    def __init__(self, vocab: int, branch: int = 32, alpha: float = 1.2,
                 seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.branch = min(branch, vocab)
        self.table = rng.integers(0, vocab, size=(vocab, self.branch),
                                  dtype=np.int32)
        ranks = np.arange(1, self.branch + 1, dtype=np.float64)
        p = ranks ** (-alpha)
        self.probs = p / p.sum()

    def sample(self, rng: np.random.Generator, length: int,
               batch: int) -> np.ndarray:
        toks = np.empty((batch, length), np.int32)
        state = rng.integers(0, self.vocab, size=batch)
        for t in range(length):
            toks[:, t] = state
            choice = rng.choice(self.branch, size=batch, p=self.probs)
            state = self.table[state, choice]
        return toks


def batches(vocab: int, batch: int, seq_len: int, *, seed: int = 0,
            stream: int | None = None, branch: int = 32,
            alpha: float = 1.2):
    """Infinite iterator of {"tokens", "labels"} numpy batches.

    `seed` fixes the LANGUAGE (the Markov transition table); `stream`
    fixes the sampling stream within it (held-out eval = same seed,
    different stream). labels[t] = tokens[t+1]."""
    chain = ZipfMarkov(vocab, branch, alpha, seed)
    rng = np.random.default_rng(seed + 1 if stream is None else stream)
    while True:
        toks = chain.sample(rng, seq_len + 1, batch)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def block_stream(vocab: int, d_model: int, block: int, batch: int,
                 embed_fn, *, seed: int = 0):
    """Iterator of FFN-input blocks [batch, block, d_model] for
    FastForward distillation: samples tokens and maps through `embed_fn`
    (typically a frozen partial forward up to some layer)."""
    gen = batches(vocab, batch, block, seed=seed)
    for b in gen:
        yield embed_fn(b["tokens"])


def padded_prompts(vocab: int, lengths, block: int, *, seed: int = 0):
    """Batched prompts right-padded to a common multiple of `block`.
    Returns (tokens [B, L], lengths [B])."""
    chain = ZipfMarkov(vocab, seed=seed)
    rng = np.random.default_rng(seed + 7)
    L = int(-(-max(lengths) // block) * block)
    B = len(lengths)
    out = np.zeros((B, L), np.int32)
    for i, ln in enumerate(lengths):
        out[i, :ln] = chain.sample(rng, ln, 1)[0]
    return out, np.asarray(lengths, np.int32)
