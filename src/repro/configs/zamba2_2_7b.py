"""zamba2-2.7b [hybrid] — Mamba2 + shared attention blocks
[arXiv:2411.15242]. 54 Mamba2 layers; ONE weight-shared attention+MLP
block applied every 6 layers (9 sites)."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", arch="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    ssm_conv=4, attn_every=6,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="arXiv:2411.15242",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
    attn_every=2, param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
