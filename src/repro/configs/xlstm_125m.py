"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].
d_ff = 0: no FFN exists, FastForward is inapplicable (DESIGN.md §4)."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="xlstm-125m", arch="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, ssm_expand=2, ssm_chunk=128, ssm_conv=4,
    ff=FastForwardConfig(enabled=False),
    param_dtype="bfloat16", source="arXiv:2405.04517",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab=512,
    ssm_chunk=32, param_dtype="float32", remat=False,
)
