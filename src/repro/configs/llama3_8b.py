"""llama3-8b — the paper's own evaluation model (Llama-3.1-8B-Instruct
geometry, Grattafiori et al. 2024). Not part of the assigned pool; used
by the paper-claim benchmarks (FLOPs crossover at ~28K, Fig. 7)."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="llama3-8b", arch="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, rope_theta=500000.0,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="arXiv:2407.21783",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
