"""Config registry: assigned architecture ids -> ModelConfig."""
from repro.configs import (
    tinyllama_1_1b, whisper_tiny, qwen2_5_14b, kimi_k2_1t_a32b,
    llava_next_mistral_7b, xlstm_125m, qwen2_moe_a2_7b, zamba2_2_7b,
    granite_8b, phi3_mini_3_8b, llama3_8b,
)

_MODULES = {
    "tinyllama-1.1b": tinyllama_1_1b,
    "whisper-tiny": whisper_tiny,
    "qwen2.5-14b": qwen2_5_14b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "xlstm-125m": xlstm_125m,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "zamba2-2.7b": zamba2_2_7b,
    "granite-8b": granite_8b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "llama3-8b": llama3_8b,
}

ASSIGNED = [k for k in _MODULES if k != "llama3-8b"]
ALL = list(_MODULES)


def get_config(name: str, reduced: bool = False):
    mod = _MODULES[name]
    return mod.REDUCED if reduced else mod.CONFIG
