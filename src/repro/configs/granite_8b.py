"""granite-8b [dense] — llama-arch, code [arXiv:2405.04324]."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="granite-8b", arch="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, rope_theta=10000.0,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="arXiv:2405.04324",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
