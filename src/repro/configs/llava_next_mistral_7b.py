"""llava-next-mistral-7b [vlm] — anyres tiling, vision tower stubbed
[hf:llava-hf/llava-v1.6-mistral-7b-hf]. Mistral backbone keeps its
native sliding window (4096)."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", arch="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, sliding_window=4096,
    n_patches=2880,  # anyres: 576 base + 4 tiles x 576
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, sliding_window=64, n_patches=32,
    param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
