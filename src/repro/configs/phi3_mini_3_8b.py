"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", arch="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, rope_theta=10000.0,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="arXiv:2404.14219",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab=512, param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
