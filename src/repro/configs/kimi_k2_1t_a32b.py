"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384e top-8
[arXiv:2501.kimi2]. Experts shard over the data axis (DESIGN.md §5);
trains with Adafactor (Adam moments for 1T params would not fit)."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=0, vocab=163840,
    n_experts=384, top_k=8, n_shared_experts=1, d_ff_expert=2048,
    optimizer="adafactor",
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="arXiv:2501.kimi2",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=0,
    vocab=512, n_experts=4, top_k=2, n_shared_experts=1, d_ff_expert=128,
    param_dtype="float32", remat=False, optimizer="adamw",
).with_ff(block_size=32, tile=64)
