"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", arch="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1000000.0,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="hf:Qwen/Qwen2.5-0.5B",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
