"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385]."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", arch="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab=32000, rope_theta=10000.0,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="arXiv:2401.02385",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=512, param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
