"""whisper-tiny [audio] — enc-dec, conv frontend stub [arXiv:2212.04356]."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, act="gelu", gated=False, norm="layernorm",
    n_audio_frames=1500, n_encoder_layers=4,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="arXiv:2212.04356",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab=512, n_audio_frames=32, n_encoder_layers=2,
    param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
