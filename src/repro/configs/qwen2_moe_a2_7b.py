"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.base import ModelConfig, FastForwardConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", arch="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=151936, qkv_bias=True,
    n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408,
    ff=FastForwardConfig(enabled=True),
    param_dtype="bfloat16", source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

REDUCED = CONFIG.with_(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab=512,
    n_experts=4, top_k=2, n_shared_experts=2, d_ff_expert=128,
    param_dtype="float32", remat=False,
).with_ff(block_size=32, tile=64)
