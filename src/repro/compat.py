"""Version-tolerant shims over drifting JAX APIs.

The repo targets the jax_pallas toolchain baked into the image, but the
exact JAX release moves under us (0.4.x vs 0.5+/0.6+ renames). Every
site that touches a drifted symbol goes through this module so the fix
lives in one place:

  * ``tpu_compiler_params``  — ``pltpu.CompilerParams`` (new) vs
    ``pltpu.TPUCompilerParams`` (0.4.x).
  * ``cost_analysis``        — ``compiled.cost_analysis()`` returns a
    dict on new JAX but a one-element list of dicts on 0.4.x.
  * ``make_mesh``            — ``jax.make_mesh(..., axis_types=...)``
    grew the kwarg after 0.4.37; older releases reject it.
  * ``use_mesh``             — ``jax.sharding.set_mesh`` does not exist
    on 0.4.x; ``Mesh`` itself is a context manager there.
"""
from __future__ import annotations

import contextlib

import jax


def tpu_compiler_params(**kwargs):
    """Build Pallas-TPU compiler params across the CompilerParams /
    TPUCompilerParams rename (kwargs passed through, e.g.
    ``dimension_semantics=("parallel", "arbitrary")``)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` to a flat dict (older JAX
    returns a one-element list of per-computation dicts)."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with explicit-Auto axis types where supported;
    plain mesh (implicitly Auto) on older releases."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {} if devices is None else {"devices": devices}
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names), **kw)
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def use_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh
    (``jax.sharding.set_mesh`` on new JAX, ``with mesh:`` on 0.4.x)."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return contextlib.ExitStack() if mesh is None else mesh


def jit_cache_size(jitted):
    """Number of distinct compilations a ``jax.jit`` wrapper holds
    (used by the serving runtime's no-recompilation assertion), or
    None when this JAX exposes no cache-size API — callers must treat
    None as "check unavailable", NOT as a stable count (comparing two
    unavailable sentinels would make the assertion pass vacuously)."""
    try:
        return int(jitted._cache_size())
    except Exception:
        return None
