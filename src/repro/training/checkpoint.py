"""Checkpointing: param/opt trees -> sharded .npz + msgpack manifest.

No orbax offline; this is a self-contained, deterministic format:
  <dir>/manifest.msgpack   {path: {shape, dtype}} + metadata
  <dir>/arrays.npz         flat {path: ndarray}
"""
from __future__ import annotations

import os
from typing import Any

import msgpack
import numpy as np
import jax
import jax.numpy as jnp


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat):
    root: dict = {}
    for path, v in flat.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = v
    return root


def save_checkpoint(directory: str, tree, metadata: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    manifest = {"metadata": metadata or {}, "arrays": {}}
    for path, v in flat.items():
        arr = np.asarray(v)
        if arr.dtype == jnp.bfloat16:
            manifest["arrays"][path] = {"dtype": "bfloat16",
                                        "shape": list(arr.shape)}
            arrays[path] = arr.view(np.uint16)
        else:
            manifest["arrays"][path] = {"dtype": str(arr.dtype),
                                        "shape": list(arr.shape)}
            arrays[path] = arr
    with open(os.path.join(directory, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    np.savez(os.path.join(directory, "arrays.npz"), **arrays)


def load_checkpoint(directory: str):
    with open(os.path.join(directory, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(directory, "arrays.npz"))
    flat = {}
    for path, info in manifest["arrays"].items():
        arr = data[path]
        if info["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        flat[path] = jnp.asarray(arr)
    return _unflatten(flat), manifest["metadata"]
