"""Hand-rolled optimizers (no optax): Adam/AdamW and Adafactor.

Adafactor (factored second moment, no first moment by default) is used
for the trillion-parameter MoE config, where Adam moments would not fit
the mesh (see DESIGN.md §5). All states mirror the parameter tree, so
parameter shardings apply transitively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- Adam


def adam_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}


def adam_update(params, grads, state, step, lr=1e-3, b1=0.9, b2=0.999,
                eps=1e-8, weight_decay=0.0):
    step = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_m = treedef.unflatten([l[1] for l in leaves])
    new_v = treedef.unflatten([l[2] for l in leaves])
    return new_p, {"m": new_m, "v": new_v}


# ------------------------------------------------------------- Adafactor


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def st(p):
        if _factored(p.shape):
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"row": row, "col": col}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"f": jax.tree.map(st, params)}


def adafactor_update(params, grads, state, step, lr=1e-2, decay=0.8,
                     eps=1e-30, clip=1.0):
    step = step.astype(jnp.float32) + 1.0
    beta = 1.0 - step ** (-decay)

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p.shape):
            row = beta * s["row"] + (1 - beta) * jnp.mean(g2, axis=-1)
            col = beta * s["col"] + (1 - beta) * jnp.mean(g2, axis=-2)
            rmean = jnp.mean(row, axis=-1, keepdims=True)
            vhat = (row / jnp.maximum(rmean, eps))[..., None] * col[..., None, :]
            u = g32 / jnp.sqrt(jnp.maximum(vhat, eps))
            ns = {"row": row, "col": col}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            u = g32 / jnp.sqrt(jnp.maximum(v, eps))
            ns = {"v": v}
        # update clipping (RMS of update <= clip)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

    is_state = lambda x: isinstance(x, dict) and set(x) <= {"row", "col", "v"}
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = jax.tree.leaves(grads)
    s_leaves = jax.tree.leaves(state["f"], is_leaf=is_state)
    outs = [upd(p, g, s) for p, g, s in zip(p_leaves, g_leaves, s_leaves)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_f = treedef.unflatten([o[1] for o in outs])
    return new_p, {"f": new_f}


# -------------------------------------------- abstract state (dry-run)


def opt_state_specs(param_specs, kind: str):
    """ParamSpec tree describing optimizer state (for sharded dry-runs);
    mirrors the parameter logical axes so shardings apply transitively."""
    import dataclasses
    from repro.nn.param import ParamSpec, is_spec

    def f32(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, dtype=jnp.float32, init="zeros")

    if kind == "adamw":
        m = jax.tree.map(f32, param_specs, is_leaf=is_spec)
        v = jax.tree.map(f32, param_specs, is_leaf=is_spec)
        return {"m": m, "v": v}
    if kind == "adafactor":
        def st(s: ParamSpec):
            if _factored(s.shape):
                return {
                    "row": ParamSpec(s.shape[:-1], s.axes[:-1],
                                     init="zeros", dtype=jnp.float32),
                    "col": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                     s.axes[:-2] + s.axes[-1:],
                                     init="zeros", dtype=jnp.float32),
                }
            return {"v": f32(s)}

        return {"f": jax.tree.map(st, param_specs, is_leaf=is_spec)}
    raise ValueError(kind)


# ----------------------------------------------------------- dispatcher


def make_optimizer(kind: str, lr: float, weight_decay: float = 0.0):
    if kind == "adamw":
        return (adam_init,
                lambda p, g, s, t: adam_update(p, g, s, t, lr=lr,
                                               weight_decay=weight_decay))
    if kind == "adafactor":
        return (adafactor_init,
                lambda p, g, s, t: adafactor_update(p, g, s, t, lr=lr))
    raise ValueError(f"unknown optimizer {kind}")
