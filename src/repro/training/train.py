"""Training step factory: LM cross-entropy + optimizer update.

Works for every architecture via the model registry; MoE auxiliary
losses flow through the `aux` dict. Labels < 0 are masked (VLM image
regions, padding).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig
from repro.models.registry import get_model
from repro.training.optimizer import make_optimizer


def cross_entropy(logits, labels):
    """logits [B,T,V]; labels [B,T] int (−1 = masked)."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def make_loss_fn(cfg: ModelConfig):
    model = get_model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.forward(params, cfg, batch)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:
            # VLM: logits cover [image | text]; labels already padded by
            # input_specs to the fused length with image region masked.
            raise ValueError(
                f"label length {labels.shape[1]} != logits {logits.shape[1]}")
        ce = cross_entropy(logits, labels)
        loss = ce + aux.get("aux_loss", 0.0)
        metrics = {"loss": loss, "ce": ce}
        if "aux_loss" in aux:
            metrics["aux_loss"] = aux["aux_loss"]
        return loss, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, lr: float = 3e-4,
                    weight_decay: float = 0.0):
    """Returns (init_state, train_step). State: {params, opt, step}."""
    loss_fn = make_loss_fn(cfg)
    opt_init, opt_update = make_optimizer(cfg.optimizer, lr, weight_decay)

    def init_state(params):
        return {"params": params, "opt": opt_init(params),
                "step": jnp.zeros((), jnp.int32)}

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch)
        params, opt = opt_update(state["params"], grads, state["opt"],
                                 state["step"])
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, grad_norm=gnorm)
        return {"params": params, "opt": opt,
                "step": state["step"] + 1}, metrics

    return init_state, train_step


def eval_perplexity(cfg: ModelConfig, params, batches):
    """Average token perplexity over an iterable of batches."""
    loss_fn = make_loss_fn(cfg)
    jfn = jax.jit(lambda p, b: loss_fn(p, b)[1]["ce"])
    tot, n = 0.0, 0
    for b in batches:
        tot += float(jfn(params, b))
        n += 1
    return float(jnp.exp(tot / max(n, 1)))
