"""GQA attention: full/causal, sliding-window, cross, and cached decode.

The XLA (jnp) path here is the lowering path used for dry-runs and CPU
tests; the Pallas flash kernel in repro.kernels.flash_attention is the
TPU-target equivalent validated against ref oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec
from repro.nn.layers import apply_rope

NEG_INF = -1e30


def attention_spec(d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
                   bias: bool = False, dtype=jnp.float32):
    sp = {
        "wq": ParamSpec((d_model, n_heads, d_head), ("embed", "heads", "head_dim"), dtype=dtype),
        "wk": ParamSpec((d_model, n_kv_heads, d_head), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": ParamSpec((d_model, n_kv_heads, d_head), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": ParamSpec((n_heads, d_head, d_model), ("heads", "head_dim", "embed"), dtype=dtype),
    }
    if bias:
        sp["bq"] = ParamSpec((n_heads, d_head), ("heads", "head_dim"), init="zeros", dtype=dtype)
        sp["bk"] = ParamSpec((n_kv_heads, d_head), ("kv_heads", "head_dim"), init="zeros", dtype=dtype)
        sp["bv"] = ParamSpec((n_kv_heads, d_head), ("kv_heads", "head_dim"), init="zeros", dtype=dtype)
    return sp


def project_q(params, x, positions=None, rope_theta=None):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
    return q


def project_kv(params, x, positions=None, rope_theta=None):
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bk" in params:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if rope_theta is not None:
        k = apply_rope(k, positions, rope_theta)
    return k, v


def output_proj(params, o):
    y = jnp.einsum("bthk,hkd->btd", o, params["wo"],
                   preferred_element_type=jnp.float32)
    return y.astype(o.dtype)


def dot_attention(q, k, v, mask=None):
    """Grouped-query attention core.

    q: [B,T,H,dh]; k,v: [B,S,Kv,dh]; mask: broadcastable to [B,1,1,T,S]
    (True = attend). Softmax in f32.
    """
    B, T, H, dh = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    qg = q.reshape(B, T, Kv, rep, dh)
    scores = jnp.einsum("btgrk,bsgk->bgrts", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgrts,bsgk->btgrk", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(v.dtype)
    return o.reshape(B, T, H, dh)


# ------------------------------------------------------------------ masks


def causal_mask(t_q: int, t_k: int, q_offset=0):
    """[1,1,1,Tq,Tk] causal mask; query i at absolute pos q_offset+i."""
    qi = q_offset + jnp.arange(t_q)[:, None]
    kj = jnp.arange(t_k)[None, :]
    return (kj <= qi)[None, None, None]


def sliding_mask(t_q: int, t_k: int, window: int, q_offset=0):
    qi = q_offset + jnp.arange(t_q)[:, None]
    kj = jnp.arange(t_k)[None, :]
    return ((kj <= qi) & (kj > qi - window))[None, None, None]


def length_mask(lengths, t_k: int):
    """lengths: [B] valid key counts -> [B,1,1,1,Tk]."""
    kj = jnp.arange(t_k)[None, :]
    return (kj < lengths[:, None])[:, None, None, None, :]


# ------------------------------------------- chunked (online softmax)


def dot_attention_chunked(q, k, v, chunk: int, *, causal=True, window=None,
                          q_offset=0):
    """Flash-style online-softmax attention in pure XLA: scan over KV
    chunks carrying (m, l, acc). Never materializes the [T, S] score
    matrix — per-step transient is [B, Kv, rep, T, chunk]. Used by the
    memory-optimized train/prefill paths (EXPERIMENTS.md §Perf)."""
    B, T, H, dh = q.shape
    S = k.shape[1]
    Kv = k.shape[2]
    rep = H // Kv
    assert S % chunk == 0
    nc = S // chunk
    qg = (q.reshape(B, T, Kv, rep, dh).astype(jnp.float32)
          / jnp.sqrt(dh).astype(jnp.float32))
    kc = k.reshape(B, nc, chunk, Kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Kv, dh).transpose(1, 0, 2, 3, 4)
    qi = q_offset + jnp.arange(T)

    def step(carry, inp):
        m, l, acc = carry
        ci, kk, vv = inp
        s = jnp.einsum("btgrk,bsgk->bgrts", qg, kk.astype(jnp.float32))
        if causal:
            kj = ci * chunk + jnp.arange(chunk)
            mask = kj[None, :] <= qi[:, None]
            if window:
                mask = mask & (kj[None, :] > qi[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrts,bsgk->bgrtk", p, vv.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, Kv, rep, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, rep, T), jnp.float32)
    a0 = jnp.zeros((B, Kv, rep, T, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nc), kc, vc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, T, H, dh).astype(v.dtype)


# ---------------------------------------------------------- full forward


def attend_full(params, x, positions, *, causal=True, window=None,
                rope_theta=10000.0, use_rope=True, chunk=0):
    """Self-attention over the full sequence (training / fused prefill).
    chunk > 0 switches to the online-softmax chunked core (no [T,S]
    score materialization)."""
    theta = rope_theta if use_rope else None
    q = project_q(params, x, positions, theta)
    k, v = project_kv(params, x, positions, theta)
    T = x.shape[1]
    if chunk and T % chunk == 0 and T > chunk:
        o = dot_attention_chunked(q, k, v, chunk, causal=causal,
                                  window=window)
    else:
        if causal and window:
            mask = sliding_mask(T, T, window)
        elif causal:
            mask = causal_mask(T, T)
        else:
            mask = None
        o = dot_attention(q, k, v, mask)
    return output_proj(params, o)


def attend_block_cached(params, x_block, k_cache, v_cache, pos0, *,
                        window=None, rope_theta=10000.0, use_rope=True,
                        lengths=None):
    """Blockwise prefill: query block attends to cache[:pos0+block].

    x_block: [B,N,D]; k_cache/v_cache: [B,S,Kv,dh] with the current block
    already written at [pos0, pos0+N). lengths: optional [B] true prompt
    lengths (right-padded batches never attend past them). Returns [B,N,D].
    """
    B, N, _ = x_block.shape
    S = k_cache.shape[1]
    positions = pos0 + jnp.arange(N)[None, :]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_block, positions, theta)
    if window:
        mask = sliding_mask(N, S, window, q_offset=pos0)
    else:
        mask = causal_mask(N, S, q_offset=pos0)
    if lengths is not None:
        mask = mask & length_mask(lengths, S)
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


def attend_block_rows(params, x_block, k_cache, v_cache, pos0s, *,
                      window=None, rope_theta=10000.0, use_rope=True,
                      lengths=None):
    """Per-row-offset blockwise prefill: row b's query block sits at
    absolute positions [pos0s[b], pos0s[b]+N) of ITS OWN sequence.

    The batched twin of `attend_block_cached` used by the continuous-
    batching scheduler to prefill one block of B distinct requests in a
    single call: each row carries its own offset, so the causal /
    sliding-window / length masks are built per row. x_block: [B,N,D];
    k_cache/v_cache: [B,S,Kv,dh] (current block already written);
    pos0s: [B] int32; lengths: optional [B] true prompt lengths.
    Returns [B,N,D]."""
    B, N, _ = x_block.shape
    S = k_cache.shape[1]
    positions = pos0s[:, None] + jnp.arange(N)[None, :]       # [B, N]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_block, positions, theta)
    kj = jnp.arange(S)[None, None, :]
    valid = kj <= positions[:, :, None]                       # [B, N, S]
    if window:
        valid = valid & (kj > positions[:, :, None] - window)
    if lengths is not None:
        valid = valid & (kj < lengths[:, None, None])
    mask = valid[:, None, None]                               # [B,1,1,N,S]
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


def attend_decode(params, x_tok, k_cache, v_cache, position, *,
                  window=None, rope_theta=10000.0, use_rope=True):
    """One-token decode: x_tok [B,1,D]; cache holds `position` valid slots
    (ring-buffer semantics when window is set: cache length == window)."""
    B = x_tok.shape[0]
    S = k_cache.shape[1]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_tok, jnp.full((B, 1), position), theta)
    kj = jnp.arange(S)[None, :]
    if window:
        # ring buffer: every slot is valid once position >= window
        valid = kj < jnp.minimum(position + 1, S)
    else:
        valid = kj <= position
    mask = valid[:, None, None, None, :]
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


def write_kv_block(k_cache, v_cache, k_new, v_new, pos0):
    """Insert a block of K/V at [pos0, pos0+N) (static N, dynamic pos0)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos0, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos0, axis=1)
    return k_cache, v_cache


def write_kv_rows(k_cache, v_cache, k_new, v_new, pos0s):
    """Per-row block write: row b's [N] new K/V land at [pos0s[b],
    pos0s[b]+N) of row b (static N, dynamic per-row offsets). The
    batched twin of `write_kv_block` for multi-request prefill."""
    def row(kc, kn, p):
        return jax.lax.dynamic_update_slice_in_dim(
            kc, kn.astype(kc.dtype), p, axis=0)
    k_cache = jax.vmap(row)(k_cache, k_new, pos0s)
    v_cache = jax.vmap(row)(v_cache, v_new, pos0s)
    return k_cache, v_cache


def write_kv_tok(k_cache, v_cache, k_new, v_new, positions, active=None):
    """Per-sequence single-token write (ragged decode). positions: [B].
    active: optional [B] bool — inactive rows keep their cache unchanged
    (serving slot pool: freed/prefilling slots ride along in the fixed
    decode batch without corrupting their KV)."""
    B = k_cache.shape[0]
    bidx = jnp.arange(B)
    k_w = k_new[:, 0].astype(k_cache.dtype)
    v_w = v_new[:, 0].astype(v_cache.dtype)
    if active is not None:
        sel = active[:, None, None]
        k_w = jnp.where(sel, k_w, k_cache[bidx, positions])
        v_w = jnp.where(sel, v_w, v_cache[bidx, positions])
    k_cache = k_cache.at[bidx, positions].set(k_w)
    v_cache = v_cache.at[bidx, positions].set(v_w)
    return k_cache, v_cache


def attend_decode_ragged(params, x_tok, k_cache, v_cache, positions, *,
                         window=None, rope_theta=10000.0, use_rope=True):
    """Per-sequence decode positions [B]; cache row b valid through
    positions[b] (inclusive). window: optional sliding-window size —
    unlike the ring-buffer scalar path, the cache here is full-length
    (absolute positions), so the window is a pure attention mask."""
    S = k_cache.shape[1]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_tok, positions[:, None], theta)
    kj = jnp.arange(S)[None, :]
    valid = kj <= positions[:, None]
    if window:
        valid = valid & (kj > positions[:, None] - window)
    mask = valid[:, None, None, None, :]
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


def write_kv_ring(k_cache, v_cache, k_new, v_new, position, window: int):
    """Single-token ring-buffer write at position % window."""
    slot = jnp.mod(position, window)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    return k_cache, v_cache
