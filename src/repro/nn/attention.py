"""GQA attention: full/causal, sliding-window, cross, and cached decode.

The XLA (jnp) path here is the lowering path used for dry-runs and CPU
tests; the Pallas flash kernel in repro.kernels.flash_attention is the
TPU-target equivalent validated against ref oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.param import ParamSpec
from repro.nn.layers import apply_rope

NEG_INF = -1e30


def attention_spec(d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
                   bias: bool = False, dtype=jnp.float32):
    sp = {
        "wq": ParamSpec((d_model, n_heads, d_head), ("embed", "heads", "head_dim"), dtype=dtype),
        "wk": ParamSpec((d_model, n_kv_heads, d_head), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": ParamSpec((d_model, n_kv_heads, d_head), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": ParamSpec((n_heads, d_head, d_model), ("heads", "head_dim", "embed"), dtype=dtype),
    }
    if bias:
        sp["bq"] = ParamSpec((n_heads, d_head), ("heads", "head_dim"), init="zeros", dtype=dtype)
        sp["bk"] = ParamSpec((n_kv_heads, d_head), ("kv_heads", "head_dim"), init="zeros", dtype=dtype)
        sp["bv"] = ParamSpec((n_kv_heads, d_head), ("kv_heads", "head_dim"), init="zeros", dtype=dtype)
    return sp


def project_q(params, x, positions=None, rope_theta=None):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
    return q


def project_kv(params, x, positions=None, rope_theta=None):
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bk" in params:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if rope_theta is not None:
        k = apply_rope(k, positions, rope_theta)
    return k, v


def output_proj(params, o):
    y = jnp.einsum("bthk,hkd->btd", o, params["wo"],
                   preferred_element_type=jnp.float32)
    return y.astype(o.dtype)


def dot_attention(q, k, v, mask=None):
    """Grouped-query attention core.

    q: [B,T,H,dh]; k,v: [B,S,Kv,dh]; mask: broadcastable to [B,1,1,T,S]
    (True = attend). Softmax in f32.
    """
    B, T, H, dh = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    qg = q.reshape(B, T, Kv, rep, dh)
    scores = jnp.einsum("btgrk,bsgk->bgrts", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgrts,bsgk->btgrk", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(v.dtype)
    return o.reshape(B, T, H, dh)


# ------------------------------------------------------------------ masks


def causal_mask(t_q: int, t_k: int, q_offset=0):
    """[1,1,1,Tq,Tk] causal mask; query i at absolute pos q_offset+i."""
    qi = q_offset + jnp.arange(t_q)[:, None]
    kj = jnp.arange(t_k)[None, :]
    return (kj <= qi)[None, None, None]


def sliding_mask(t_q: int, t_k: int, window: int, q_offset=0):
    qi = q_offset + jnp.arange(t_q)[:, None]
    kj = jnp.arange(t_k)[None, :]
    return ((kj <= qi) & (kj > qi - window))[None, None, None]


def length_mask(lengths, t_k: int):
    """lengths: [B] valid key counts -> [B,1,1,1,Tk]."""
    kj = jnp.arange(t_k)[None, :]
    return (kj < lengths[:, None])[:, None, None, None, :]


# ------------------------------------------- chunked (online softmax)


def dot_attention_chunked(q, k, v, chunk: int, *, causal=True, window=None,
                          q_offset=0):
    """Flash-style online-softmax attention in pure XLA: scan over KV
    chunks carrying (m, l, acc). Never materializes the [T, S] score
    matrix — per-step transient is [B, Kv, rep, T, chunk]. Used by the
    memory-optimized train/prefill paths (EXPERIMENTS.md §Perf)."""
    B, T, H, dh = q.shape
    S = k.shape[1]
    Kv = k.shape[2]
    rep = H // Kv
    assert S % chunk == 0
    nc = S // chunk
    qg = (q.reshape(B, T, Kv, rep, dh).astype(jnp.float32)
          / jnp.sqrt(dh).astype(jnp.float32))
    kc = k.reshape(B, nc, chunk, Kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Kv, dh).transpose(1, 0, 2, 3, 4)
    qi = q_offset + jnp.arange(T)

    def step(carry, inp):
        m, l, acc = carry
        ci, kk, vv = inp
        s = jnp.einsum("btgrk,bsgk->bgrts", qg, kk.astype(jnp.float32))
        if causal:
            kj = ci * chunk + jnp.arange(chunk)
            mask = kj[None, :] <= qi[:, None]
            if window:
                mask = mask & (kj[None, :] > qi[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrts,bsgk->bgrtk", p, vv.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, Kv, rep, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kv, rep, T), jnp.float32)
    a0 = jnp.zeros((B, Kv, rep, T, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nc), kc, vc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, T, H, dh).astype(v.dtype)


# ---------------------------------------------------------- full forward


def attend_full(params, x, positions, *, causal=True, window=None,
                rope_theta=10000.0, use_rope=True, chunk=0):
    """Self-attention over the full sequence (training / fused prefill).
    chunk > 0 switches to the online-softmax chunked core (no [T,S]
    score materialization)."""
    theta = rope_theta if use_rope else None
    q = project_q(params, x, positions, theta)
    k, v = project_kv(params, x, positions, theta)
    T = x.shape[1]
    if chunk and T % chunk == 0 and T > chunk:
        o = dot_attention_chunked(q, k, v, chunk, causal=causal,
                                  window=window)
    else:
        if causal and window:
            mask = sliding_mask(T, T, window)
        elif causal:
            mask = causal_mask(T, T)
        else:
            mask = None
        o = dot_attention(q, k, v, mask)
    return output_proj(params, o)


def attend_block_cached(params, x_block, k_cache, v_cache, pos0, *,
                        window=None, rope_theta=10000.0, use_rope=True,
                        lengths=None, attn_sel=None, attn_threshold=None):
    """Blockwise prefill: query block attends to cache[:pos0+block].

    x_block: [B,N,D]; k_cache/v_cache: [B,S,Kv,dh] with the current block
    already written at [pos0, pos0+N). lengths: optional [B] true prompt
    lengths (right-padded batches never attend past them). attn_sel:
    optional block-sparse attention budget (see `attend_block_rows`) —
    delegates to the per-row path with a broadcast offset. Returns [B,N,D].
    """
    B, N, _ = x_block.shape
    S = k_cache.shape[1]
    if attn_sel is not None:
        pos0s = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32), (B,))
        return attend_block_rows(params, x_block, k_cache, v_cache,
                                 pos0s, window=window,
                                 rope_theta=rope_theta,
                                 use_rope=use_rope, lengths=lengths,
                                 attn_sel=attn_sel,
                                 attn_threshold=attn_threshold)
    positions = pos0 + jnp.arange(N)[None, :]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_block, positions, theta)
    from repro.kernels.flash_attention import ops as FA
    if FA.on_tpu():
        # kernel-backed dense baseline (gather/mask fallback off-TPU)
        lens = (lengths if lengths is not None
                else jnp.full((B,), S, jnp.int32))
        pos0s = jnp.broadcast_to(jnp.asarray(pos0, jnp.int32), (B,))
        o = FA.mha_flash_rows(q, k_cache, v_cache, pos0s, lens,
                              window=window)
        return output_proj(params, o.astype(v_cache.dtype))
    if window:
        mask = sliding_mask(N, S, window, q_offset=pos0)
    else:
        mask = causal_mask(N, S, q_offset=pos0)
    if lengths is not None:
        mask = mask & length_mask(lengths, S)
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


def attn_sel_width(attn_sel, n_blocks: int) -> int:
    """STATIC top-k selection width for a block-sparse attention budget:
    the plan's max per-layer count (virtual-grid units) scaled onto the
    cache's real block grid, floor 2 (forced sink + diagonal)."""
    attn_k_max, attn_tiles, _ = attn_sel
    k = -(-attn_k_max * n_blocks // attn_tiles)               # ceil
    return min(max(2, k), n_blocks)


def attend_block_rows(params, x_block, k_cache, v_cache, pos0s, *,
                      window=None, rope_theta=10000.0, use_rope=True,
                      lengths=None, attn_sel=None, attn_threshold=None):
    """Per-row-offset blockwise prefill: row b's query block sits at
    absolute positions [pos0s[b], pos0s[b]+N) of ITS OWN sequence.

    The batched twin of `attend_block_cached` used by the continuous-
    batching scheduler to prefill one block of B distinct requests in a
    single call: each row carries its own offset, so the causal /
    sliding-window / length masks are built per row. x_block: [B,N,D];
    k_cache/v_cache: [B,S,Kv,dh] (current block already written);
    pos0s: [B] int32; lengths: optional [B] true prompt lengths.

    attn_sel: optional (attn_k_max, attn_tiles, a_l) block-sparse
    attention budget from a dual-budget SparsityPlan — attn_k_max and
    attn_tiles are STATIC (join the plan's jit key), a_l is this
    layer's traced virtual-grid count riding the layer scan. When set,
    KV blocks are scored by the pooled-QK proxy and only the kept
    selection is attended (kernels/block_sparse_attention dispatch:
    Pallas kernel on TPU, membership-masked GQA core off TPU — the
    latter is bit-identical to the dense path at full budget).
    Returns [B,N,D]."""
    B, N, _ = x_block.shape
    S = k_cache.shape[1]
    positions = pos0s[:, None] + jnp.arange(N)[None, :]       # [B, N]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_block, positions, theta)
    if attn_sel is not None:
        from repro.kernels.block_sparse_attention import ops as BSA
        _, attn_tiles, a_l = attn_sel
        nc = -(-S // N)
        lens = (lengths if lengths is not None
                else jnp.full((B,), S, jnp.int32))
        ids, cnts = BSA.select_kv_blocks(
            q, BSA.pooled_block_keys(k_cache, N), pos0s, lens, blk=N,
            k_sel=attn_sel_width(attn_sel, nc), attn_tiles=attn_tiles,
            a_l=a_l, window=window, threshold=attn_threshold)
        o = BSA.block_sparse_prefill_op(q, k_cache, v_cache, ids, cnts,
                                        pos0s, lens, blk=N,
                                        window=window)
        return output_proj(params, o.astype(v_cache.dtype))
    from repro.kernels.flash_attention import ops as FA
    if FA.on_tpu():
        # kernel-backed dense baseline (gather/mask fallback off-TPU)
        lens = (lengths if lengths is not None
                else jnp.full((B,), S, jnp.int32))
        o = FA.mha_flash_rows(q, k_cache, v_cache, pos0s, lens,
                              window=window)
        return output_proj(params, o.astype(v_cache.dtype))
    kj = jnp.arange(S)[None, None, :]
    valid = kj <= positions[:, :, None]                       # [B, N, S]
    if window:
        valid = valid & (kj > positions[:, :, None] - window)
    if lengths is not None:
        valid = valid & (kj < lengths[:, None, None])
    mask = valid[:, None, None]                               # [B,1,1,N,S]
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


def attend_decode(params, x_tok, k_cache, v_cache, position, *,
                  window=None, rope_theta=10000.0, use_rope=True):
    """One-token decode: x_tok [B,1,D]; cache holds `position` valid slots
    (ring-buffer semantics when window is set: cache length == window)."""
    B = x_tok.shape[0]
    S = k_cache.shape[1]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_tok, jnp.full((B, 1), position), theta)
    kj = jnp.arange(S)[None, :]
    if window:
        # ring buffer: every slot is valid once position >= window
        valid = kj < jnp.minimum(position + 1, S)
    else:
        valid = kj <= position
    mask = valid[:, None, None, None, :]
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


def write_kv_block(k_cache, v_cache, k_new, v_new, pos0):
    """Insert a block of K/V at [pos0, pos0+N) (static N, dynamic pos0)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos0, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos0, axis=1)
    return k_cache, v_cache


def write_kv_rows(k_cache, v_cache, k_new, v_new, pos0s):
    """Per-row block write: row b's [N] new K/V land at [pos0s[b],
    pos0s[b]+N) of row b (static N, dynamic per-row offsets). The
    batched twin of `write_kv_block` for multi-request prefill."""
    def row(kc, kn, p):
        return jax.lax.dynamic_update_slice_in_dim(
            kc, kn.astype(kc.dtype), p, axis=0)
    k_cache = jax.vmap(row)(k_cache, k_new, pos0s)
    v_cache = jax.vmap(row)(v_cache, v_new, pos0s)
    return k_cache, v_cache


def write_kv_tok(k_cache, v_cache, k_new, v_new, positions, active=None):
    """Per-sequence single-token write (ragged decode). positions: [B].
    active: optional [B] bool — inactive rows keep their cache unchanged
    (serving slot pool: freed/prefilling slots ride along in the fixed
    decode batch without corrupting their KV)."""
    B = k_cache.shape[0]
    bidx = jnp.arange(B)
    k_w = k_new[:, 0].astype(k_cache.dtype)
    v_w = v_new[:, 0].astype(v_cache.dtype)
    if active is not None:
        sel = active[:, None, None]
        k_w = jnp.where(sel, k_w, k_cache[bidx, positions])
        v_w = jnp.where(sel, v_w, v_cache[bidx, positions])
    k_cache = k_cache.at[bidx, positions].set(k_w)
    v_cache = v_cache.at[bidx, positions].set(v_w)
    return k_cache, v_cache


def attend_decode_ragged(params, x_tok, k_cache, v_cache, positions, *,
                         window=None, rope_theta=10000.0, use_rope=True):
    """Per-sequence decode positions [B]; cache row b valid through
    positions[b] (inclusive). window: optional sliding-window size —
    unlike the ring-buffer scalar path, the cache here is full-length
    (absolute positions), so the window is a pure attention mask."""
    S = k_cache.shape[1]
    theta = rope_theta if use_rope else None
    q = project_q(params, x_tok, positions[:, None], theta)
    kj = jnp.arange(S)[None, :]
    valid = kj <= positions[:, None]
    if window:
        valid = valid & (kj > positions[:, None] - window)
    mask = valid[:, None, None, None, :]
    o = dot_attention(q, k_cache, v_cache, mask)
    return output_proj(params, o)


# --------------------------------------------------- paged KV (page pool)
#
# The paged layout replaces each request's contiguous [S, Kv, dh] slot
# rows with a page table into a pooled [n_pages, page_size, Kv, dh]
# buffer: table entry j of a row holds the page storing that row's
# absolute positions [j*psz, (j+1)*psz). Unallocated tail entries point
# at the reserved null page 0 — a shared write sink that no mask ever
# lets a query attend. Page tables are TRACED values (fixed
# [B, max_pages] int32 shapes), so churning tables never recompile.
#
# Ownership is refcounted (serving/page_pool.py): a page may appear in
# SEVERAL rows' tables when their prompts share a prefix. Writes stay
# race-free because shared pages are READ-ONLY until copy-on-write
# detaches them — every scatter below targets either (a) a page its
# row exclusively owns (fresh allocation or COW copy for the block
# being prefilled / the decode tail), (b) the null page, or (c) an
# inactive row's self-copy, which rewrites identical bytes. Pages a
# request publishes to the prefix index belong to COMPLETED blocks it
# never rewrites, so sharing adds readers, never writers.


def kv_page_size(pages):
    """Tokens per page for either heap representation: raw
    [n_pages, psz, Kv, dh] pages or the int8-quantized heap
    ({"q": int8 pages, "s": f32 [n_pages, Kv]}, kernels/kv_quant)."""
    return (pages["q"] if isinstance(pages, dict) else pages).shape[1]


def kv_dtype(pages):
    """Dtype attention outputs cast back to: the page dtype for the raw
    heap, the f32 compute dtype for the int8-quantized heap (int8 is a
    storage format, never a compute dtype)."""
    return (pages["s"] if isinstance(pages, dict) else pages).dtype


def gather_pages(pages, page_table):
    """pages: [n_pages, psz, ...] (or the quantized {"q", "s"} heap);
    page_table: [B, max_pages] int32 -> contiguous
    [B, max_pages * psz, ...] (page j of row b lands at positions
    [j*psz, (j+1)*psz)). The ONE table-directed gather both the prefill
    path and the decode oracle build on — the paged-vs-slot bit-identity
    contract hangs off this single implementation. The quantized heap
    dequantizes ON THE GATHERED VIEW (each row's pages only), never the
    whole pool."""
    B, mp = page_table.shape
    flat_ids = page_table.reshape(-1)
    if isinstance(pages, dict):
        q = jnp.take(pages["q"], flat_ids, axis=0)
        s = jnp.take(pages["s"], flat_ids, axis=0)
        flat = q.astype(jnp.float32) * s[:, None, :, None]
        psz = q.shape[1]
        return flat.reshape((B, mp * psz) + q.shape[2:])
    psz = pages.shape[1]
    flat = jnp.take(pages, flat_ids, axis=0)
    return flat.reshape((B, mp * psz) + pages.shape[2:])


def gather_kv_pages(k_pages, v_pages, page_table):
    """Gather each row's pages into contiguous [B, max_pages*psz, Kv, dh]
    views (the XLA page-table attention path: the gathered view holds
    bit-identical values to a slot-pool cache row at every attended
    position, so downstream attention math is unchanged)."""
    return (gather_pages(k_pages, page_table),
            gather_pages(v_pages, page_table))


def copy_kv_pages(cache, src_pages, dst_pages):
    """Copy-on-write detach: duplicate page payloads src -> dst across
    every cache leaf ([L, n_pages, psz, Kv, dh]; page axis 1). The
    device half of PagedKVPool.cow — a request admitted onto a shared
    prefix whose tail page it must overwrite (partial-block tail) gets
    a private bit-identical copy before any write lands.

    src_pages/dst_pages: [W] int32, FIXED width (the scheduler pads
    with 0 -> 0 null-page self-copies), so every COW batch hits one
    executable regardless of how many pages actually detach. dst
    entries are freshly-allocated distinct pages (plus padding zeros
    writing identical null bytes), so duplicate-index scatter order
    never matters."""
    return jax.tree.map(
        lambda a: a.at[:, dst_pages].set(a[:, src_pages]), cache)


def _write_pages_quant(pages, new, pids, active):
    """Scatter whole freshly-quantized pages into the int8 heap. new:
    [B, npb, psz, Kv, dh] f32 page payloads; pids: [B, npb] target
    pages. Each written page gets a FRESH scale from its own payload
    (the block covers the page end to end, so no stale bytes leak into
    absmax); inactive rows write their target pages' existing (q, s)
    back — an exact self-copy, so the null-page invariant holds."""
    from repro.kernels.kv_quant import ops as KQ
    B, npb = pids.shape
    q_w, s_w = KQ.quantize_pages_op(
        new.astype(jnp.float32).reshape((B * npb,) + new.shape[2:]))
    q_w = q_w.reshape((B, npb) + q_w.shape[1:])
    s_w = s_w.reshape((B, npb) + s_w.shape[1:])
    if active is not None:
        q_w = jnp.where(active[:, None, None, None, None], q_w,
                        pages["q"][pids])
        s_w = jnp.where(active[:, None, None], s_w, pages["s"][pids])
    flat = pids.reshape(-1)
    return {"q": pages["q"].at[flat].set(
                q_w.reshape((B * npb,) + q_w.shape[2:])),
            "s": pages["s"].at[flat].set(
                s_w.reshape((B * npb,) + s_w.shape[2:]))}


def write_kv_rows_paged(k_pages, v_pages, k_new, v_new, page_table, pos0s,
                        active=None):
    """Per-row paged block write: row b's [N] new K/V land on the
    N/psz pages its table maps for [pos0s[b], pos0s[b]+N). The paged
    twin of `write_kv_rows` — but it scatters straight into the POOL
    (the block being written is backed by exclusively-owned pages —
    shared prefix pages are read-only until COW — so live rows never
    collide), instead of updating a gathered per-row view.

    k_new/v_new: [B, N, Kv, dh]; page_table: [B, max_pages] int32;
    pos0s: [B] int32 block offsets (block-aligned, so psz | pos0).
    active: optional [B] bool — inactive pad rows carry all-null tables
    and write their target pages' own content back (a deterministic
    self-copy: every pad row writes the identical null-page payload).
    Requires psz | N. On the quantized heap each covered page is
    quantized whole with a fresh per-(page, kv-head) scale."""
    B, N = k_new.shape[:2]
    psz = kv_page_size(k_pages)
    npb = N // psz                        # pages written per block
    tpos = pos0s[:, None] // psz + jnp.arange(npb)[None, :]     # [B, npb]
    pids = jnp.take_along_axis(page_table, tpos, axis=1)        # [B, npb]
    if isinstance(k_pages, dict):
        k_r = k_new.reshape((B, npb, psz) + k_new.shape[2:])
        v_r = v_new.reshape((B, npb, psz) + v_new.shape[2:])
        return (_write_pages_quant(k_pages, k_r, pids, active),
                _write_pages_quant(v_pages, v_r, pids, active))
    k_w = k_new.astype(k_pages.dtype).reshape((B, npb, psz)
                                              + k_new.shape[2:])
    v_w = v_new.astype(v_pages.dtype).reshape((B, npb, psz)
                                              + v_new.shape[2:])
    if active is not None:
        sel = active[:, None, None, None, None]
        k_w = jnp.where(sel, k_w, k_pages[pids])
        v_w = jnp.where(sel, v_w, v_pages[pids])
    flat = pids.reshape(-1)
    k_pages = k_pages.at[flat].set(k_w.reshape((B * npb, psz)
                                               + k_new.shape[2:]))
    v_pages = v_pages.at[flat].set(v_w.reshape((B * npb, psz)
                                               + v_new.shape[2:]))
    return k_pages, v_pages


def write_kv_block_paged(k_pages, v_pages, k_new, v_new, page_table, pos0):
    """Single-request paged block write (scalar pos0, table [max_pages])
    — the paged twin of `write_kv_block`, a width-1 `write_kv_rows_paged`."""
    return write_kv_rows_paged(k_pages, v_pages, k_new, v_new,
                               page_table[None], jnp.reshape(pos0, (1,)))


def _write_tok_quant(pages, tok, pid, off, active):
    """Single-token insert into the int8 heap via
    dequantize -> insert -> zero-past-offset -> requantize. tok:
    [B, Kv, dh]; pid/off: [B]. Zeroing slots > off guarantees the fresh
    scale reflects only the valid prefix [0, off]; inactive rows keep
    their page's existing (q, s) bit-exactly (self-copy)."""
    from repro.kernels.kv_quant import ops as KQ
    q_old = pages["q"][pid]                          # [B, psz, Kv, dh]
    s_old = pages["s"][pid]                          # [B, Kv]
    page = q_old.astype(jnp.float32) * s_old[:, None, :, None]
    B, psz = page.shape[:2]
    page = page.at[jnp.arange(B), off].set(tok.astype(jnp.float32))
    slot = jnp.arange(psz)[None, :, None, None]
    page = jnp.where(slot <= off[:, None, None, None], page, 0.0)
    q_new, s_new = KQ.quantize_pages_op(page)
    if active is not None:
        q_new = jnp.where(active[:, None, None, None], q_new, q_old)
        s_new = jnp.where(active[:, None], s_new, s_old)
    return {"q": pages["q"].at[pid].set(q_new),
            "s": pages["s"].at[pid].set(s_new)}


def write_kv_tok_paged(k_pages, v_pages, k_new, v_new, page_table,
                       positions, active=None):
    """Per-sequence paged single-token write (ragged decode): row b's
    token lands at offset positions[b] % psz of page
    table[b, positions[b] // psz]. active: optional [B] bool — inactive
    rows write their target cell's own content back (prefilling /
    freed slots ride along in the fixed decode batch; their tables map
    distinct pages or the shared null page, so self-copies never race a
    live write).

    On the quantized heap: dequantize the target page, insert the token
    at its offset, ZERO every slot past the offset (stale bytes beyond
    the valid prefix must not poison the fresh absmax), requantize with
    a fresh scale, and scatter both (q, s) leaves. The scale therefore
    depends only on valid tokens; earlier tokens may requantize under
    the new scale with error within the documented
    0.5 * absmax / 127 contract (kernels/kv_quant/ref.py)."""
    psz = kv_page_size(k_pages)
    if isinstance(k_pages, dict):
        pid = jnp.take_along_axis(page_table, (positions // psz)[:, None],
                                  axis=1)[:, 0]                 # [B]
        off = positions % psz
        return (_write_tok_quant(k_pages, k_new[:, 0], pid, off, active),
                _write_tok_quant(v_pages, v_new[:, 0], pid, off, active))
    pid = jnp.take_along_axis(page_table, (positions // psz)[:, None],
                              axis=1)[:, 0]                     # [B]
    off = positions % psz
    k_w = k_new[:, 0].astype(k_pages.dtype)
    v_w = v_new[:, 0].astype(v_pages.dtype)
    if active is not None:
        sel = active[:, None, None]
        k_w = jnp.where(sel, k_w, k_pages[pid, off])
        v_w = jnp.where(sel, v_w, v_pages[pid, off])
    k_pages = k_pages.at[pid, off].set(k_w)
    v_pages = v_pages.at[pid, off].set(v_w)
    return k_pages, v_pages


def attend_block_rows_paged(params, x_block, k_pages, v_pages, page_table,
                            pos0s, *, window=None, rope_theta=10000.0,
                            use_rope=True, lengths=None, attn_sel=None,
                            attn_threshold=None):
    """Paged twin of `attend_block_rows`: per-row-offset blockwise
    prefill attention indexing the KV pool through page tables. Without
    a block-sparse budget the gathered contiguous views feed the
    identical masked GQA core, so output is bit-identical to the slot
    layout; with `attn_sel` the page-table-aware prefill kernel reads
    the selected slabs straight out of the raw page pool on TPU (the
    XLA branch masks the gathered view — same bit-identity contract)."""
    if attn_sel is not None:
        from repro.kernels.block_sparse_attention import ops as BSA
        B, N = x_block.shape[:2]
        S = page_table.shape[1] * kv_page_size(k_pages)
        positions = pos0s[:, None] + jnp.arange(N)[None, :]
        theta = rope_theta if use_rope else None
        q = project_q(params, x_block, positions, theta)
        _, attn_tiles, a_l = attn_sel
        lens = (lengths if lengths is not None
                else jnp.full((B,), S, jnp.int32))
        nc = -(-S // N)
        ids, cnts = BSA.select_kv_blocks(
            q, BSA.pooled_block_keys_paged(k_pages, page_table, N),
            pos0s, lens, blk=N, k_sel=attn_sel_width(attn_sel, nc),
            attn_tiles=attn_tiles, a_l=a_l, window=window,
            threshold=attn_threshold)
        o = BSA.block_sparse_prefill_paged_op(
            q, k_pages, v_pages, page_table, ids, cnts, pos0s, lens,
            blk=N, window=window)
        return output_proj(params, o.astype(kv_dtype(v_pages)))
    kc, vc = gather_kv_pages(k_pages, v_pages, page_table)
    return attend_block_rows(params, x_block, kc, vc, pos0s,
                             window=window, rope_theta=rope_theta,
                             use_rope=use_rope, lengths=lengths)


def attend_decode_ragged_paged(params, x_tok, k_pages, v_pages, page_table,
                               positions, *, window=None,
                               rope_theta=10000.0, use_rope=True,
                               use_kernel=None):
    """Paged twin of `attend_decode_ragged`, dispatched through
    kernels/paged_attention: TPU runs the Pallas kernel (scalar-
    prefetched page ids, no gathered copy), XLA runs the gather-based
    page-table path (bit-identical to the slot layout)."""
    from repro.kernels.paged_attention import ops as PA
    theta = rope_theta if use_rope else None
    q = project_q(params, x_tok, positions[:, None], theta)
    o = PA.paged_attention_op(q[:, 0], k_pages, v_pages, page_table,
                              positions, window=window,
                              use_kernel=use_kernel)
    return output_proj(params, o[:, None].astype(kv_dtype(v_pages)))


def write_kv_ring(k_cache, v_cache, k_new, v_new, position, window: int):
    """Single-token ring-buffer write at position % window."""
    slot = jnp.mod(position, window)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    return k_cache, v_cache
