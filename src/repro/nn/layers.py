"""Core NN layers: norms, linear/einsum application, embeddings, RoPE.

Pure-functional: every layer is (spec builder, apply fn) working on
plain dict param trees produced by repro.nn.param.init_params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.param import ParamSpec

# ---------------------------------------------------------------- dtypes


def compute_dtype(x):
    """All matmuls accumulate in f32; activations flow in x.dtype."""
    return x.dtype


# ----------------------------------------------------------------- norms


def rmsnorm_spec(d: int, dtype=jnp.float32):
    return {"scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(d: int, dtype=jnp.float32):
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype),
        "bias": ParamSpec((d,), ("embed",), init="zeros", dtype=dtype),
    }


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------- linear


def linear_spec(d_in: int, d_out: int, axes=("embed", "mlp"), bias: bool = False,
                dtype=jnp.float32, scale: float = 1.0):
    sp = {"w": ParamSpec((d_in, d_out), axes, init="scaled", scale=scale, dtype=dtype)}
    if bias:
        sp["b"] = ParamSpec((d_out,), (axes[1],), init="zeros", dtype=dtype)
    return sp


def linear(params, x):
    y = jnp.einsum("...i,io->...o", x, params["w"],
                   preferred_element_type=jnp.float32)
    if "b" in params:
        y = y + params["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------ embeddings


def embedding_spec(vocab: int, d: int, dtype=jnp.float32):
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"),
                               init="normal", scale=0.02, dtype=dtype)}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Logits against the (possibly separate) output table."""
    return jnp.einsum("...d,vd->...v", x, params["table"],
                      preferred_element_type=jnp.float32)


# ------------------------------------------------------------------ RoPE


def rope_frequencies(d_head: int, theta: float = 10000.0):
    exponents = np.arange(0, d_head, 2, dtype=np.float32) / d_head
    return 1.0 / (theta ** exponents)  # [d_head/2]


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., T, H, d_head]; positions: [..., T] (int)."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta))  # [d/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,T,d/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [...,T,1,d/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos, d_model):
    """Whisper-style sinusoidal embeddings, computed for any length."""
    pos = np.arange(n_pos)[:, None].astype(np.float32)
    dim = np.arange(d_model // 2)[None, :].astype(np.float32)
    angle = pos / np.power(10000.0, 2 * dim / d_model)
    return jnp.asarray(
        np.concatenate([np.sin(angle), np.cos(angle)], axis=-1), jnp.float32
    )


# ------------------------------------------------------------ activations


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu(gate, up):
    return silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


ACTIVATIONS = {
    "silu": silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}
