from repro.nn.param import (  # noqa: F401
    ParamSpec,
    init_params,
    abstract_params,
    stack_specs,
    cast_specs,
    count_params,
    flatten_specs,
)
