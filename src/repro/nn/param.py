"""Parameter substrate: declarative ParamSpec trees (no flax).

Models declare a nested-dict tree of ParamSpec leaves. From that single
declaration we derive: materialized parameters (init_params), abstract
ShapeDtypeStructs for dry-run lowering (abstract_params), and
NamedShardings via logical-axis rules (see repro.distributed.sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor.

    shape: tensor shape.
    axes:  logical axis names, one per dim (None = never sharded).
    init:  "normal" | "zeros" | "ones" | "scaled" (fan-in scaled normal).
    scale: multiplier for normal/scaled init std.
    dtype: parameter dtype.
    """

    shape: tuple
    axes: tuple
    init: str = "scaled"
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _spec_leaf(x):
    return is_spec(x)


def flatten_specs(specs):
    """Flatten a spec tree to [(path_str, spec)] sorted by path."""
    leaves = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_spec_leaf)[0]
    out = []
    for path, spec in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, spec))
    out.sort(key=lambda kv: kv[0])
    return out


def _init_one(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "scaled":
        # fan-in scaled: std = scale / sqrt(fan_in); fan_in = second-to-last
        # dim for matrices laid out [..., in, out]; last dim for vectors.
        if len(spec.shape) >= 2:
            fan_in = spec.shape[-2]
        else:
            fan_in = spec.shape[-1]
        std = spec.scale / np.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_params(specs, key):
    """Materialize a spec tree into a param tree, deterministically."""
    flat = flatten_specs(specs)
    keys = jax.random.split(key, max(len(flat), 1))
    by_path = {p: _init_one(k, s) for (p, s), k in zip(flat, keys)}

    def build(spec_subtree, prefix):
        if is_spec(spec_subtree):
            return by_path[prefix]
        if isinstance(spec_subtree, dict):
            return {
                k: build(v, f"{prefix}/{k}" if prefix else str(k))
                for k, v in spec_subtree.items()
            }
        if isinstance(spec_subtree, (list, tuple)):
            seq = [
                build(v, f"{prefix}/{i}" if prefix else str(i))
                for i, v in enumerate(spec_subtree)
            ]
            return type(spec_subtree)(seq)
        raise TypeError(type(spec_subtree))

    return build(specs, "")


def abstract_params(specs, shardings=None):
    """ShapeDtypeStruct tree (optionally with shardings) for .lower()."""

    def mk(spec, sh):
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sh)

    if shardings is None:
        return jax.tree.map(lambda s: mk(s, None), specs, is_leaf=_spec_leaf)
    return jax.tree.map(mk, specs, shardings, is_leaf=_spec_leaf)


def stack_specs(specs, n: int, axis_name: str = "layers"):
    """Prefix every spec with a leading stacked dim (scan-over-layers)."""

    def st(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(
            s, shape=(n,) + s.shape, axes=(axis_name,) + s.axes
        )

    return jax.tree.map(st, specs, is_leaf=_spec_leaf)


def cast_specs(specs, dtype):
    """Override dtype of every float spec (e.g. bf16 for dry-runs)."""

    def ct(s: ParamSpec) -> ParamSpec:
        if jnp.issubdtype(s.dtype, jnp.floating):
            return dataclasses.replace(s, dtype=dtype)
        return s

    return jax.tree.map(ct, specs, is_leaf=_spec_leaf)


def count_params(specs) -> int:
    return int(sum(np.prod(s.shape) for _, s in flatten_specs(specs)))


def tree_bytes(specs) -> int:
    return int(
        sum(
            np.prod(s.shape) * jnp.dtype(s.dtype).itemsize
            for _, s in flatten_specs(specs)
        )
    )
