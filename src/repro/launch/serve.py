"""Serving launcher: static batch or continuous-batching request stream.

Static one-shot batch (legacy behaviour):

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 4 --max-new 16

Continuous-batching stream simulator (Poisson arrivals; batched
multi-request prefill ticks; reports TTFT p50/p99, tokens/sec, slot
churn, and asserts zero jit recompilation after warmup):

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --requests 16 --rate 20 --slots 4 \
      --prefill-batch 4

EOS workload (--eos-id): every request stops the moment it greedily
emits that token — mid-generation — so slots free early and admission
churns under the batched prefill path:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --requests 16 --eos-id 7

Paged KV cache (--kv-layout paged): requests hold page tables into a
shared page heap instead of max-length slots — admission gates on free
pages, allocation is lazy per prefill block, and an oversubscribed heap
(--pool-pages) preempts the youngest request when dry:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --requests 16 --kv-layout paged \
      --page-size 16 --slots 8 --pool-pages 48

Prefix sharing (--prefix-cache, paged layout only): finished prompt
blocks are published to a host-side prefix index; later requests with
the same page-aligned (prompt-prefix, SparsityPlan) key map those pages
read-only (refcounted), are charged only their unshared footprint at
admission, and start prefill at the first unshared block. Greedy
outputs are bit-identical with sharing on or off:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --kv-layout paged --prefix-cache \
      --trace benchmarks/traces/sample_shared_prefix.jsonl

Real-traffic trace replay (--trace): arrival-time / prompt-len /
gen-len records (jsonl, see repro.serving.trace) drive the SAME stream
loop as the Poisson simulator:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --trace benchmarks/traces/sample_trace.jsonl

SLO-tiered sparsity (--effort): requests select a SparsityPlan effort
tier ("dense" / "balanced" / "turbo"); a comma list round-robins tiers
across the stream (mixed-effort traffic through the pre-compiled
per-plan executables — the no-recompilation assertion still holds),
and trace records may carry their own `effort` field:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --requests 16 --effort balanced,turbo

Overload resilience (--deadline-ms / --degrade / --chaos-seed):
requests carry deadlines (expiry frees resources mid-flight with
status="timed_out"; provably-unmeetable deadlines are shed at submit),
--degrade routes new admissions to sparser pre-compiled tiers while
load watermarks trip, and --chaos-seed runs the whole stream under
deterministic fault injection (forced preemptions, synthetic pool
pressure, slow ticks — serving/faults.py). A robustness line reports
per-status counts, goodput, and degradation/fault stats:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --requests 16 --rate 200 --deadline-ms 60000 \
      --degrade --chaos-seed 0

Tiered KV memory (--kv-quant / --swap-pages N, paged layout only):
--kv-quant stores paged K/V as int8 with per-(page, kv-head) f32
scales (kernels/kv_quant) — ~4x the pages at equal device bytes,
dequantized on the fly in the paged attention kernels. --swap-pages N
attaches a host-memory swap tier of N pages (serving/kv_tier.py):
page pressure swaps the youngest request's exclusive pages out to
host instead of preempt-and-recompute, and parked requests resume
bit-identically; preemption remains the fallback when the tier is
full. A tier stats line reports swap traffic and occupancy:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --requests 16 --kv-layout paged \
      --pool-pages 24 --kv-quant --swap-pages 64

Self-speculative decode (--speculate K[,draft_tier]): decode ticks
draft K tokens per active request under the (sparser) draft tier's
pre-compiled executables, then verify all K+1 positions in ONE chunked
call under each request's own plan, emitting the longest agreeing
prefix plus the verifier's bonus token. Greedy output is BIT-identical
to speculation off — the draft plan affects only latency. A stats line
reports per-tier acceptance rate and tokens per row-tick:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --stream --requests 16 --effort balanced,turbo \
      --speculate 4,turbo
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ALL, get_config
from repro.core import scheduler as SCHED
from repro.core.fastforward import EFFORT_TIERS, resolve_plan
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (AdmissionController, ContinuousBatchingScheduler,
                           FaultInjector, Request, StaticEngine,
                           drive_stream, load_trace, parse_speculate_arg)
from repro.serving.runtime import make_runtime
from repro.serving.trace import trace_stats
from repro.training.checkpoint import load_checkpoint


def build_params(cfg, checkpoint=None):
    model = get_model(cfg)
    if checkpoint:
        params, meta = load_checkpoint(checkpoint)
        print(f"loaded checkpoint ({meta})")
        return params
    return init_params(model.specs(cfg), jax.random.key(0))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _attn_probs_pass(params, cfg, tokens):
    """Jitted Eq. 23 capture: ONE compiled forward pass whose lax.scan
    over the stacked layer params emits every layer's post-softmax
    attention probs [L, B, H, T, T]. cfg is a frozen (hashable)
    dataclass, so it rides as a static argument; the moe/dense FFN
    branch is python-static (stacked param structure is uniform across
    layers). One compile per calibration prompt SHAPE — fine offline,
    and ~n_layers fewer dispatches per prompt than the old per-layer
    python loop."""
    from repro.models import dense as D
    from repro.nn import attention as A
    from repro.nn import layers as L
    from repro.core import fastforward as FF
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    mask = A.causal_mask(T, T)
    is_moe = "moe" in params["layers"]
    if is_moe:
        from repro.models import moe as M

    def layer(x, lp):
        xn = D.apply_norm(cfg, lp["ln1"], x)
        q = A.project_q(lp["attn"], xn, pos, cfg.rope_theta)
        k, v = A.project_kv(lp["attn"], xn, pos, cfg.rope_theta)
        Kv = k.shape[2]
        rep = q.shape[2] // Kv
        qg = q.reshape(B, T, Kv, rep, -1)
        s = jnp.einsum("btgrk,bsgk->bgrts", qg, k) / np.sqrt(q.shape[-1])
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)                  # [B,Kv,rep,T,T]
        o = jnp.einsum("bgrts,bsgk->btgrk", p.astype(v.dtype), v)
        o = o.reshape(B, T, q.shape[2], -1)
        x = x + A.output_proj(lp["attn"], o)
        xn2 = D.apply_norm(cfg, lp["ln2"], x)
        if is_moe:
            y, _ = M.moe_block(lp["moe"], cfg, xn2, mode="dense")
            x = x + y.astype(x.dtype)
        else:
            x = x + FF.ff_dense(lp["ffn"], cfg, xn2).astype(x.dtype)
        return x, p.reshape(B, -1, T, T)

    _, probs = jax.lax.scan(layer, x, params["layers"])
    return probs


def collect_attn_probs(params, cfg, tokens):
    """Per-layer post-softmax attention probs [L, B, H, T, T] — the
    Eq. 23 calibration input for `calibrate_layer_importance`. Thin
    wrapper over the jitted single-pass capture (`_attn_probs_pass`);
    offline only, never on the serving path."""
    return _attn_probs_pass(params, cfg, jnp.asarray(tokens))


def make_prompts(cfg, n, prompt_len, rng):
    return [list(rng.integers(0, cfg.vocab,
                              size=rng.integers(max(1, prompt_len // 2),
                                                prompt_len + 1)))
            for _ in range(n)]


def serve_static(cfg, params, args):
    rng = np.random.default_rng(0)
    prompts = make_prompts(cfg, args.requests, args.prompt_len, rng)
    eng = StaticEngine(cfg, params)
    res = eng.generate(prompts, max_new=args.max_new,
                       temperature=args.temperature)
    print(f"mode={'dense' if args.dense else 'fastforward'} "
          f"sparsity={0.0 if args.dense else cfg.ff.sparsity}")
    print(f"prefill: {res.prefill_seconds*1e3:.1f} ms "
          f"({res.prompt_tokens} prompt tokens)")
    print(f"decode:  {res.decode_seconds*1e3:.1f} ms "
          f"({res.generated_tokens} tokens)")
    for i, row in enumerate(res.tokens):
        print(f"req{i}: {row.tolist()}")


def serve_stream(cfg, params, args):
    """Request stream (Poisson plan or trace replay) through the
    continuous-batching scheduler."""
    rng = np.random.default_rng(args.seed)
    efforts = ([e.strip() for e in args.effort.split(",") if e.strip()]
               if args.effort and cfg.ff.enabled else [])
    N = cfg.ff.block_size

    if args.trace:
        # records without their own `effort` round-robin the CLI tiers
        requests = load_trace(args.trace, cfg.vocab, seed=args.seed,
                              eos_id=args.eos_id,
                              temperature=args.temperature,
                              deadline_ms=args.deadline_ms)
        for i, r in enumerate(requests):
            if r.effort is None and efforts:
                r.effort = efforts[i % len(efforts)]
        tstats = trace_stats(requests)
        print(f"trace {args.trace}: {tstats}")
        max_prompt = max(len(r.prompt) for r in requests)
        cache_len = (-(-max_prompt // N) * N
                     + max(max(r.max_new for r in requests), 2))
    else:
        prompts = make_prompts(cfg, args.requests, args.prompt_len, rng)
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                             size=args.requests))
        max_news = rng.integers(max(1, args.max_new // 4),
                                args.max_new + 1, size=args.requests)
        requests = [
            Request(rid=i, prompt=prompts[i], max_new=int(max_news[i]),
                    temperature=args.temperature, arrival_time=arrivals[i],
                    eos_id=args.eos_id, deadline_ms=args.deadline_ms,
                    effort=efforts[i % len(efforts)] if efforts else None)
            for i in range(args.requests)]
        max_blocks = -(-args.prompt_len // N)
        cache_len = max_blocks * N + max(args.max_new, 2)

    # register one SparsityPlan per effort tier in the stream. The
    # default ("balanced" == the cfg budget) is plans[0]; requests
    # without an effort take it. Every (plan, width bucket) pair is
    # pre-compiled by warmup, so the mixed-tier stream never recompiles.
    # --calibrate N: run Eq. 23 layer-importance calibration over the
    # first N prompts of the stream (dense offline forward passes) and
    # feed it to resolve_plan, so the registered plans carry Algorithm-1
    # layer-wise budgets instead of uniform ones.
    importance = None
    if args.calibrate and cfg.ff.enabled:
        first = sorted(requests, key=lambda r: r.arrival_time or 0.0)
        samples = [np.asarray(r.prompt, np.int32)[None]
                   for r in first[:args.calibrate]]
        importance = SCHED.calibrate_layer_importance(
            lambda t: collect_attn_probs(params, cfg, jnp.asarray(t)),
            samples, N)
        print(f"calibrated layer importance on {len(samples)} prompts: "
              f"{[round(float(s), 4) for s in importance]}")

    speculative = (parse_speculate_arg(args.speculate)
                   if args.speculate else None)

    plans = None
    if cfg.ff.enabled:
        names = ["balanced"] + [e for e in dict.fromkeys(
            r.effort for r in requests if r.effort) if e != "balanced"]
        if args.degrade:
            # degradation needs ladder room: register every tier (all
            # pre-compiled by warmup, so escalation costs zero compiles)
            names += [e for e in EFFORT_TIERS if e not in names]
        if speculative is not None and speculative.draft not in names:
            # the draft tier must be a registered (pre-compiled) plan
            names.append(speculative.draft)
        # register under the bare tier names: calibrated plans resolve
        # as "<tier>-layerwise", but requests address them by tier
        plans = tuple(
            dataclasses.replace(
                resolve_plan(cfg, effort=e, importance=importance), name=e)
            for e in names)
    runtime = make_runtime(cfg, params, plans=plans)

    admission = (AdmissionController(plans or ())
                 if args.degrade else None)
    faults = (FaultInjector(seed=args.chaos_seed)
              if args.chaos_seed is not None else None)
    sched = ContinuousBatchingScheduler(
        runtime, n_slots=args.slots, cache_len=cache_len, seed=args.seed,
        prefill_batch=args.prefill_batch, page_size=args.page_size,
        n_pages=args.pool_pages, admission=admission, faults=faults,
        prefix_cache=args.prefix_cache, speculative=speculative,
        swap_pages=args.swap_pages)

    # warmup compiles every entry point through the scheduler's own pool
    counts0 = sched.warmup()
    check_compiles = None not in counts0.values()
    print(f"warmup done, jit compile counts: {counts0}")

    wall = drive_stream(sched, requests)

    counts1 = runtime.compile_counts()
    if check_compiles and counts1 != counts0:
        raise AssertionError(
            f"jit recompilation during serving: {counts0} -> {counts1}")

    outs = sched.finished
    # latency stats over requests that produced a first token only —
    # shed/cancelled/timed-out-in-prefill outputs carry ttft None
    ttfts = np.array([o.ttft_seconds for o in outs.values()
                      if o.ttft_seconds is not None])
    gen = sum(len(o.tokens) for o in outs.values())
    offered = tstats["offered_rate_req_s"] if args.trace else args.rate
    print(f"served {len(outs)} requests in {wall:.2f}s wall "
          f"({offered:.1f} req/s offered)")
    if len(ttfts):
        print(f"TTFT p50 {np.percentile(ttfts, 50)*1e3:8.1f} ms | "
              f"p99 {np.percentile(ttfts, 99)*1e3:8.1f} ms "
              f"({len(ttfts)} of {len(outs)} produced a first token)")
    print(f"throughput {gen / wall:8.1f} generated tok/s "
          f"({gen} tokens)")
    # robustness line: terminal-status mix, goodput (deadline-met ok
    # fraction), degradation + fault stats
    n_ok = sum(o.status == "ok" for o in outs.values())
    deadlines = {r.rid: r.deadline_ms for r in requests}
    met = sum(o.status == "ok"
              and (deadlines.get(o.rid) is None
                   or o.finish_seconds <= deadlines[o.rid] / 1e3)
              for o in outs.values())
    print(f"robustness: ok {n_ok} | shed {sched.n_shed} | timed_out "
          f"{sched.n_timed_out} | cancelled {sched.n_cancelled} | "
          f"degraded {sched.n_degraded} | preemptions "
          f"{sched.n_preemptions} | goodput {met}/{len(outs)} "
          f"({met / max(len(outs), 1):.0%} finished ok within deadline)")
    if admission is not None:
        print(f"admission: {admission.stats()}")
    if faults is not None:
        print(f"faults: {faults.stats()}")
    reuse = max(0, sched.pool.total_acquires - args.slots)
    print(f"slots: {args.slots} | max in use {sched.pool.max_in_use} | "
          f"acquires {sched.pool.total_acquires} (slot reuse x{reuse})")
    if sched.paged:
        pool = sched.pool
        print(f"paged KV: {pool.n_pages - 1} usable pages x "
              f"{pool.page_size} tok | peak in use "
              f"{pool.max_pages_in_use} | allocs "
              f"{pool.total_page_allocs} / frees {pool.total_page_frees} "
              f"| stranded@peak {pool.stranded_tokens_at_peak} tok | "
              f"preemptions {sched.n_preemptions}")
        if args.kv_quant:
            print(f"kv quant: int8 pages + per-(page, kv-head) f32 "
                  f"scales (kernels/kv_quant)")
    ts = sched.tier_stats()
    if ts is not None:
        print(f"kv tier: {ts['capacity_pages']} host pages | swap outs "
              f"{ts['swap_outs']} ({ts['pages_swapped_out']} pages) / "
              f"ins {ts['swap_ins']} ({ts['pages_swapped_in']} pages) | "
              f"peak host used {ts['peak_used']} | host puts "
              f"{ts['total_host_puts']} / frees {ts['total_host_frees']} "
              f"| parked now {ts['parked']}")
    if sched.prefix_index is not None:
        ps = sched.prefix_stats()
        print(f"prefix sharing: hit rate {ps['hit_rate']:.0%} "
              f"({ps['hits']}/{ps['lookups']} admissions) | "
              f"{ps['requests_hit']} requests skipped "
              f"{ps['blocks_skipped']} prefill blocks | pages shared "
              f"{ps['pages_shared']} / published {ps['pages_published']} "
              f"/ cached now {ps['pages_cached']} | cow {ps['cow_pages']} "
              f"| evictions {ps['evictions']}")
    sp = sched.sparsity_stats()
    for row in sp["plans"]:
        print(f"sparsity[{row['name']}]: keep/layer "
              f"{row['keep_per_layer']} | ffn flop frac "
              f"{row['ffn_flop_frac']:.3f} | {row['prefill_blocks']} "
              f"prefill blocks, {row['decode_tokens']} decode tokens")
        if row["attn_flop_frac"] is not None:
            print(f"  attn[{row['name']}]: keep/layer "
                  f"{row['attn_keep_per_layer']} | attn block frac "
                  f"{row['attn_flop_frac']:.3f}")
    if sp["aggregate_ffn_flop_frac"] is not None:
        print(f"sparsity aggregate ffn flop frac (work-weighted): "
              f"{sp['aggregate_ffn_flop_frac']:.3f}")
    if sp.get("aggregate_attn_flop_frac") is not None:
        print(f"sparsity aggregate attn block frac (work-weighted): "
              f"{sp['aggregate_attn_flop_frac']:.3f}")
    ss = sched.speculative_stats()
    if ss is not None:
        print(f"speculation k={ss['k']} draft={ss['draft']}: "
              f"{ss['spec_ticks']} speculative decode ticks")
        for row in ss["plans"]:
            if row["row_ticks"] == 0:
                continue
            acc = (f"{row['acceptance_rate']:.2%} "
                   f"({row['accepted']}/{row['drafted']} drafts)"
                   if row["acceptance_rate"] is not None
                   else "n/a (0 drafts)")
            print(f"  spec[{row['name']}<-{row['draft_plan']}]: "
                  f"acceptance {acc} | "
                  f"{row['tokens_per_row_tick']:.2f} tok/row-tick "
                  f"({row['emitted']} emitted in {row['row_ticks']} "
                  f"row ticks)")
    print(f"ticks {sched.n_ticks} | prefill blocks "
          f"{sched.n_prefill_blocks} in {sched.n_prefill_ticks} prefill "
          f"ticks (P<={sched.prefill_batch}) | decode steps "
          f"{sched.n_decode_steps}")
    if args.eos_id is not None:
        print(f"eos_id={args.eos_id}: {sched.n_eos_stops} of {len(outs)} "
              f"requests stopped early (slots freed mid-generation)")
    if check_compiles:
        print(f"no recompilation after warmup: OK {counts1}")
    else:
        print("compile-count check unavailable on this JAX "
              "(no _cache_size) — recompilation NOT verified")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL, default="tinyllama-1.1b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=96)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--dense", action="store_true",
                   help="disable FastForward sparsity (baseline)")
    p.add_argument("--checkpoint", default=None)
    p.add_argument("--stream", action="store_true",
                   help="continuous-batching Poisson request stream")
    p.add_argument("--rate", type=float, default=20.0,
                   help="stream mode: mean arrival rate (req/s)")
    p.add_argument("--slots", type=int, default=4,
                   help="stream mode: KV slot pool capacity")
    p.add_argument("--prefill-batch", type=int, default=4,
                   help="stream mode: max requests advancing one "
                        "prefill block per tick in one jitted call "
                        "(1 = PR-1 single-block ticks)")
    p.add_argument("--eos-id", type=int, default=None,
                   help="stream mode: requests stop at this token "
                        "mid-generation, freeing their slot early "
                        "(EOS admission-churn workload)")
    p.add_argument("--kv-layout", choices=("slot", "paged"), default=None,
                   help="KV cache layout: one max-length slot per "
                        "request (default) or block-granular paged "
                        "allocation (PagedKVPool)")
    p.add_argument("--page-size", type=int, default=None,
                   help="paged layout: tokens per KV page (default "
                        "cfg.kv_page_size, then the prefill block size; "
                        "must divide the block size)")
    p.add_argument("--pool-pages", type=int, default=None,
                   help="paged layout: total heap pages incl. the "
                        "reserved null page (default: full backing — "
                        "smaller values oversubscribe and exercise "
                        "preemption)")
    p.add_argument("--kv-quant", action="store_true",
                   help="paged layout: store K/V pages as int8 with "
                        "per-(page, kv-head) f32 scales, dequantized "
                        "on the fly in the paged attention kernels "
                        "(kernels/kv_quant) — ~4x pages at equal "
                        "device bytes")
    p.add_argument("--swap-pages", type=int, default=0, metavar="N",
                   help="paged stream mode: host swap tier capacity in "
                        "pages (serving/kv_tier.py) — page pressure "
                        "swaps the youngest request's exclusive pages "
                        "to host instead of preempt-and-recompute; "
                        "0 disables tiering")
    p.add_argument("--prefix-cache", action="store_true",
                   help="paged layout: refcounted prefix sharing — "
                        "admission maps the longest cached page-aligned "
                        "(prompt, plan) prefix read-only into new "
                        "requests, charges only the unshared footprint, "
                        "and skips the covered prefill blocks "
                        "(serving/prefix_index.py)")
    p.add_argument("--trace", default=None,
                   help="stream mode: replay a jsonl arrival trace "
                        "(see repro.serving.trace) instead of the "
                        "Poisson plan; --requests/--rate/--prompt-len/"
                        "--max-new are ignored")
    p.add_argument("--effort", default=None,
                   help="stream mode: SparsityPlan effort tier(s) — "
                        f"one of {'/'.join(EFFORT_TIERS)} or a comma "
                        "list round-robined across requests "
                        "(SLO-tiered sparsity; trace records may carry "
                        "their own 'effort')")
    p.add_argument("--calibrate", type=int, default=0, metavar="N",
                   help="stream mode: calibrate Eq. 23 layer importance "
                        "on the first N prompts (offline dense passes) "
                        "and resolve Algorithm-1 layer-wise plans from "
                        "it instead of uniform budgets")
    p.add_argument("--attn-sparsity", type=float, default=None,
                   help="enable the block-sparse prefill attention "
                        "budget (fraction of KV blocks dropped at "
                        "'balanced'); plans become dual-budget and "
                        "effort tiers scale both")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="stream mode: end-to-end deadline per request "
                        "(trace records carrying their own deadline_ms "
                        "keep it); expiry frees resources mid-flight "
                        "with status=timed_out, provably-unmeetable "
                        "deadlines are shed at submit")
    p.add_argument("--degrade", action="store_true",
                   help="stream mode: hysteretic graceful degradation — "
                        "route new admissions to sparser effort tiers "
                        "while queue/free-space watermarks are tripped "
                        "(AdmissionController; all tiers pre-compiled)")
    p.add_argument("--speculate", default=None, metavar="K[,TIER]",
                   help="stream mode: self-speculative decode — draft "
                        "K tokens per tick under the (sparser) TIER "
                        "plan (default turbo), verify all K+1 in one "
                        "chunked call under each request's own plan. "
                        "Greedy output is bit-identical to speculation "
                        "off; trace records may cap it per-request "
                        "with a 'speculate' field")
    p.add_argument("--attn-threshold", type=float, default=None,
                   help="opt-in FlashPrefill-style adaptive attention "
                        "block counts: keep the fewest top-scored KV "
                        "blocks reaching this proxy-softmax mass, "
                        "capped by the plan budget (1.0 = keep all, "
                        "bit-identical to the fixed budget)")
    p.add_argument("--chaos-seed", type=int, default=None,
                   help="stream mode: run under deterministic fault "
                        "injection with this seed (forced preemptions, "
                        "synthetic pool pressure, slow ticks — "
                        "serving/faults.py)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    if args.max_new < 1:
        p.error("--max-new must be >= 1")
    if args.requests < 1:
        p.error("--requests must be >= 1")

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.dense:
        cfg = cfg.with_ff(enabled=False)
    if args.attn_sparsity is not None:
        cfg = cfg.with_ff(attn_sparsity=args.attn_sparsity)
    if args.attn_threshold is not None:
        cfg = cfg.with_ff(attn_threshold=args.attn_threshold)
    if args.kv_layout:
        cfg = cfg.with_(kv_layout=args.kv_layout)
    if args.trace and not args.stream:
        p.error("--trace requires --stream")
    if args.calibrate and not args.stream:
        p.error("--calibrate requires --stream")
    if args.kv_quant:
        if cfg.kv_layout != "paged":
            p.error("--kv-quant requires --kv-layout paged")
        cfg = cfg.with_(kv_quant=True)
    if args.swap_pages:
        if args.swap_pages < 0:
            p.error("--swap-pages must be >= 0")
        if cfg.kv_layout != "paged":
            p.error("--swap-pages requires --kv-layout paged")
        if not args.stream:
            p.error("--swap-pages requires --stream")
    if args.prefix_cache and cfg.kv_layout != "paged":
        p.error("--prefix-cache requires --kv-layout paged")
    if args.prefix_cache and not args.stream:
        p.error("--prefix-cache requires --stream")
    if ((args.deadline_ms is not None or args.degrade
         or args.chaos_seed is not None) and not args.stream):
        p.error("--deadline-ms/--degrade/--chaos-seed require --stream")
    if args.speculate is not None:
        if not args.stream:
            p.error("--speculate requires --stream")
        if not cfg.ff.enabled:
            p.error("--speculate needs SparsityPlan tiers "
                    "(incompatible with --dense)")
    params = build_params(cfg, args.checkpoint)
    if args.stream:
        serve_stream(cfg, params, args)
    else:
        serve_static(cfg, params, args)


if __name__ == "__main__":
    main()
