"""Serving launcher: batched requests through the FastForward engine.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 4 --max-new 16
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

from repro.configs import ALL, get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving.engine import Engine
from repro.training.checkpoint import load_checkpoint


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL, default="tinyllama-1.1b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=96)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--dense", action="store_true",
                   help="disable FastForward sparsity (baseline)")
    p.add_argument("--checkpoint", default=None)
    args = p.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.dense:
        cfg = cfg.with_ff(enabled=False)
    model = get_model(cfg)
    if args.checkpoint:
        params, meta = load_checkpoint(args.checkpoint)
        print(f"loaded checkpoint ({meta})")
    else:
        params = init_params(model.specs(cfg), jax.random.key(0))

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab,
                                 size=rng.integers(args.prompt_len // 2,
                                                   args.prompt_len + 1)))
               for _ in range(args.requests)]
    eng = Engine(cfg, params)
    res = eng.generate(prompts, max_new=args.max_new,
                       temperature=args.temperature)
    print(f"mode={'dense' if args.dense else 'fastforward'} "
          f"sparsity={0.0 if args.dense else cfg.ff.sparsity}")
    print(f"prefill: {res.prefill_seconds*1e3:.1f} ms "
          f"({res.prompt_tokens} prompt tokens)")
    print(f"decode:  {res.decode_seconds*1e3:.1f} ms "
          f"({res.generated_tokens} tokens)")
    for i, row in enumerate(res.tokens):
        print(f"req{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
