"""Assigned input shapes and abstract input specs for the dry-run.

Four shapes (assignment):
  train_4k     seq 4096   global_batch 256   -> train_step
  prefill_32k  seq 32768  global_batch 32    -> prefill_step (paper §3.1)
  decode_32k   seq 32768  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288 global_batch 1     -> serve_step, sub-quadratic

`input_specs` returns weak-type-correct ShapeDtypeStructs (no device
allocation), sharded when a mesh is given.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.base import ModelConfig
from repro.models.registry import get_model
from repro.nn import param as PM
from repro.distributed.sharding import (
    param_shardings, pspec_for, data_axes)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if supported, else a skip reason (recorded in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return ("encoder-decoder without a sub-quadratic decoder variant "
                "(whisper) — skipped per assignment rules")
    return None


def _sds(shape, dtype, mesh=None, axes=None):
    sh = None
    if mesh is not None and axes is not None:
        sh = NamedSharding(mesh, pspec_for(axes, shape, mesh))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh=None):
    """Token/label/frontend-embedding specs for train & prefill."""
    B, T = shape.global_batch, shape.seq_len
    tok_ax = ("batch", "seq")
    out = {}
    if cfg.arch == "audio":
        out["audio_embed"] = _sds((B, cfg.n_audio_frames, cfg.d_model),
                                  cfg.dtype, mesh, ("batch", "seq", None))
        out["tokens"] = _sds((B, T), jnp.int32, mesh, tok_ax)
        if shape.kind == "train":
            out["labels"] = _sds((B, T), jnp.int32, mesh, tok_ax)
    elif cfg.arch == "vlm":
        t_text = T - cfg.n_patches
        assert t_text > 0, "sequence shorter than the image region"
        out["patch_embed"] = _sds((B, cfg.n_patches, cfg.d_model),
                                  cfg.dtype, mesh, ("batch", "seq", None))
        out["tokens"] = _sds((B, t_text), jnp.int32, mesh, tok_ax)
        if shape.kind == "train":
            out["labels"] = _sds((B, T), jnp.int32, mesh, tok_ax)
    else:
        out["tokens"] = _sds((B, T), jnp.int32, mesh, tok_ax)
        if shape.kind == "train":
            out["labels"] = _sds((B, T), jnp.int32, mesh, tok_ax)
    return out


def cache_abstract(cfg: ModelConfig, shape: ShapeSpec, mesh=None):
    """Abstract KV/state cache for decode/prefill shapes."""
    model = get_model(cfg)
    if shape.kind == "prefill":
        cache_len = shape.seq_len
        if cfg.sliding_window and cfg.sliding_window < cache_len:
            cache_len = shape.seq_len  # prefill cache holds full prompt
    else:
        cache_len = cfg.decode_window(shape.seq_len) or 1
    spec_tree = model.cache_spec(cfg, shape.global_batch, cache_len)
    if mesh is None:
        return PM.abstract_params(spec_tree)
    sh = param_shardings(spec_tree, mesh)
    return PM.abstract_params(spec_tree, sh)


def token_specs_decode(cfg: ModelConfig, shape: ShapeSpec, mesh=None):
    B = shape.global_batch
    return {
        "token": _sds((B,), jnp.int32, mesh, ("batch",)),
        "position": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape_name: str, mesh=None):
    """All abstract inputs for (arch, shape): the dry-run contract."""
    shape = SHAPES[shape_name]
    out = {"shape": shape}
    if shape.kind in ("train", "prefill"):
        out["batch"] = batch_specs(cfg, shape, mesh)
    if shape.kind in ("prefill", "decode"):
        out["cache"] = cache_abstract(cfg, shape, mesh)
    if shape.kind == "decode":
        out.update(token_specs_decode(cfg, shape, mesh))
    return out
