"""Post-optimization HLO analysis with while-loop trip-count scaling.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, which
under-reports every scan-over-layers/blocks model by the trip count.
This module parses `compiled.as_text()` into a computation graph and
walks it from ENTRY:

  * dot FLOPs: 2 * prod(result_shape) * prod(contraction_dims), using a
    per-computation name->shape table for operands;
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (the payload that
    crosses the ICI);
  * memory traffic: sum of (result + operand) bytes of top-level ops —
    an upper-bound proxy for HBM traffic after fusion;

all scaled by `known_trip_count` through nested while loops, taking the
max across conditional branches (the dense branch dominates).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional

DTYPE_BYTES = {"pred": 0.125, "s2": 0.25, "u2": 0.25, "s4": 0.5,
               "u4": 0.5, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
               "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4,
               "u32": 4, "f32": 4, "f64": 8, "u64": 8, "s64": 8,
               "c64": 8, "c128": 16, "token": 0, "opaque": 0}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_op_line(stripped: str):
    """Parse '%name = TYPE opcode(...)' robustly: tuple result types may
    contain '/*index=N*/' comments, so the type is read by bracket
    matching rather than regex. Returns (name, type, opcode) or None."""
    m = _NAME_RE.match(stripped)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(stripped):
        return None
    if stripped[i] == "(":       # tuple type
        depth = 0
        j = i
        while j < len(stripped):
            if stripped[j] == "(":
                depth += 1
            elif stripped[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        rtype = stripped[i:j + 1]
        i = j + 1
    else:
        j = stripped.find(" ", i)
        if j < 0:
            return None
        rtype = stripped[i:j]
        i = j
    rest = stripped[i:].lstrip()
    m2 = re.match(r"([\w\-]+)\(", rest)
    if not m2:
        return None
    return name, rtype, m2.group(1)
_TRIP_RE = re.compile(r'known_trip_count"?\s*[=:]\s*\{\s*"?n"?\s*[=:]\s*"?(\d+)')
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply|called_computations|true_computation|"
    r"false_computation|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    line: str


def _parse_operands(line: str, opcode: str) -> List[str]:
    """Names inside the first (...) group after the opcode."""
    idx = line.find(opcode + "(")
    if idx < 0:
        return []
    start = idx + len(opcode)
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = line[start + 1:end]
    return re.findall(r"%([\w.\-]+)", args)


def parse_hlo(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$",
                          stripped)
        if header and "=" not in stripped.split("(")[0]:
            current = header.group(2)
            comps[current] = []
            if header.group(1):
                entry = current
            continue
        if stripped == "}":
            current = None
            continue
        if current is None:
            continue
        parsed = _parse_op_line(stripped)
        if not parsed:
            continue
        name, rtype, opcode = parsed
        ops = _parse_operands(stripped, opcode)
        comps[current].append(Op(name, rtype, opcode, ops, stripped))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore
    return comps


@dataclasses.dataclass
class Metrics:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Metrics", scale: float = 1.0):
        self.flops += other.flops * scale
        self.traffic_bytes += other.traffic_bytes * scale
        for k in COLLECTIVES:
            self.collective_bytes[k] += other.collective_bytes[k] * scale
            self.collective_counts[k] += other.collective_counts[k] * scale

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out = _shape_elems(op.result_type)
    if out is None:
        return 0.0
    n_out = math.prod(out) if out else 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if m and op.operands:
        lhs_type = shapes.get(op.operands[0], "")
        dims = _shape_elems(lhs_type)
        if dims is not None and m.group(1):
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(dims):
                    contract *= dims[di]
    return 2.0 * n_out * contract


def analyze_computation(comp: str, comps, memo) -> Metrics:
    if comp in memo:
        return memo[comp]
    memo[comp] = Metrics()  # break cycles defensively
    total = Metrics()
    ops = comps.get(comp, [])
    shapes = {op.name: op.result_type for op in ops}
    for op in ops:
        rbytes = _shape_bytes(op.result_type)
        if op.opcode == "while":
            trip = 1
            mt = _TRIP_RE.search(op.line)
            if mt:
                trip = int(mt.group(1))
            called = _CALLED_RE.findall(op.line)
            names = [n for grp in called for n in
                     re.findall(r"[\w.\-]+", grp)]
            body = re.search(r"body=%?([\w.\-]+)", op.line)
            cond = re.search(r"condition=%?([\w.\-]+)", op.line)
            if body:
                total.add(analyze_computation(body.group(1), comps, memo),
                          trip)
            if cond:
                total.add(analyze_computation(cond.group(1), comps, memo),
                          trip)
        elif op.opcode == "conditional":
            branches = re.search(
                r"(?:branch_computations|true_computation)=\{?%?([^,}]+(?:,\s*%?[\w.\-]+)*)\}?",
                op.line)
            names = []
            m_t = re.search(r"true_computation=%?([\w.\-]+)", op.line)
            m_f = re.search(r"false_computation=%?([\w.\-]+)", op.line)
            m_b = re.search(r"branch_computations=\{([^}]*)\}", op.line)
            if m_b:
                names = re.findall(r"%?([\w.\-]+)", m_b.group(1))
            else:
                names = [m.group(1) for m in (m_t, m_f) if m]
            if names:
                subs = [analyze_computation(n, comps, memo) for n in names]
                best = max(subs, key=lambda s: s.flops + s.traffic_bytes)
                total.add(best)
        elif op.opcode in ("fusion", "call", "async-start", "custom-call"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.line)
            if m:
                sub = analyze_computation(m.group(1), comps, memo)
                total.add(Metrics(flops=sub.flops,
                                  collective_bytes=sub.collective_bytes,
                                  collective_counts=sub.collective_counts))
            total.traffic_bytes += rbytes + sum(
                _shape_bytes(shapes.get(o, "")) for o in op.operands)
        elif op.opcode == "dot" or op.opcode.startswith("dot."):
            total.flops += _dot_flops(op, shapes)
            total.traffic_bytes += rbytes + sum(
                _shape_bytes(shapes.get(o, "")) for o in op.operands)
        elif any(op.opcode.startswith(c) for c in COLLECTIVES):
            kind = next(c for c in COLLECTIVES if op.opcode.startswith(c))
            if not op.opcode.endswith("-done"):
                total.collective_bytes[kind] += rbytes
                total.collective_counts[kind] += 1
                total.traffic_bytes += rbytes
        elif op.opcode in ("parameter", "constant", "iota", "tuple",
                           "get-tuple-element", "bitcast"):
            pass
        else:
            total.traffic_bytes += rbytes
    memo[comp] = total
    return total


def analyze_hlo(text: str) -> Metrics:
    comps = parse_hlo(text)
    entry = comps.get("__entry_name__")
    memo: dict = {}
    return analyze_computation(entry, comps, memo)
