"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh; report memory analysis, HLO cost analysis, and
collective bytes for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all --out results.jsonl
  python -m repro.launch.dryrun --all --multi-pod --out results_mp.jsonl
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first initialization). --xla_force_host_platform_
# device_count is dry-run-only: tests and benches see 1 device.

import argparse
import json
import re
import sys
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat as CP
from repro.compat import use_mesh
from repro.configs import ASSIGNED, ALL, get_config
from repro.models.registry import get_model
from repro.nn import param as PM
from repro.distributed.sharding import param_shardings
from repro.training.optimizer import opt_state_specs
from repro.training.train import make_loss_fn
from repro.training.optimizer import make_optimizer
from repro.launch.mesh import (make_production_mesh, PEAK_FLOPS_BF16,
                               HBM_BW, ICI_BW)
from repro.launch import shapes as SH

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"pred": 0.125, "s4": 0.5, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "f32": 4, "f64": 8, "u64": 8, "s64": 8, "c64": 8,
                "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str):
    """Sum operand bytes of every collective op in the (SPMD, per-device)
    HLO module. Returns {op_kind: bytes} + total."""
    out = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in COLLECTIVES:
            # match op invocations like: "... = bf16[..] all-gather(bf16[..] %x)"
            marker = f" {kind}("
            alt = f" {kind}-start("
            if marker in stripped or alt in stripped:
                idx = stripped.index(marker if marker in stripped else alt)
                operands = stripped[idx:]
                types = _SHAPE_RE.findall(operands)
                b = sum(_type_bytes(t, d) for t, d in types)
                out[kind] += b
                counts[kind] += 1
                break
    total = sum(out.values())
    return out, counts, total


def model_flops(cfg, shape: SH.ShapeSpec) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (global)."""
    model = get_model(cfg)
    n_params = PM.count_params(model.specs(cfg))
    if cfg.arch == "moe":
        # active params: replace full expert count with top_k (+shared)
        e, k = cfg.n_experts, cfg.top_k
        expert_p = 3 * cfg.d_model * cfg.d_ff_expert * cfg.n_layers
        n_params = n_params - e * expert_p + k * expert_p
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params * tokens
    return 2.0 * n_params * shape.global_batch  # decode: 1 token/seq


VARIANTS = {
    "baseline": lambda cfg: cfg,
    # §Perf iteration 1: online-softmax chunked attention (train)
    "opt_attn_chunk": lambda cfg: cfg.with_(attn_chunk=512),
    # §Perf iteration 2: shard_map tile-sparse FFN (prefill)
    "opt_shardmap_ffn": lambda cfg: cfg.with_(shardmap_ffn=True),
    # §Perf iteration 3 (beyond-paper): fused parallel-block prefill
    "opt_fused_prefill": lambda cfg: cfg.with_(fused_prefill=True,
                                               attn_chunk=512),
    "opt_fused_shardmap": lambda cfg: cfg.with_(fused_prefill=True,
                                                attn_chunk=512,
                                                shardmap_ffn=True),
    # §Perf (beyond-paper): shard_map flash-decode over seq-sharded KV
    "opt_flash_decode": lambda cfg: _with_flag(cfg, "_flash_decode"),
    "opt_microbatch4": lambda cfg: _with_micro(cfg, 4),
    "opt_microbatch16": lambda cfg: _with_micro(cfg, 16),
    "opt_micro16_chunk": lambda cfg: _with_micro(
        cfg.with_(attn_chunk=512), 16),
}


def _with_micro(cfg, n):
    object.__setattr__(cfg, "_n_microbatches", n)  # frozen dataclass aux
    return cfg


def _with_flag(cfg, name):
    object.__setattr__(cfg, name, True)
    return cfg


def build_lowering(cfg, shape_name: str, mesh, fused_prefill: bool = False):
    """Returns (lowered, meta) for the (arch, shape) pair on mesh."""
    shape = SH.SHAPES[shape_name]
    model = get_model(cfg)
    shards = mesh.shape.get("model", 1)
    expert_axis = "data" if cfg.arch == "moe" else None

    specs = model.specs(cfg)
    pshard = param_shardings(specs, mesh, expert_axis=expert_axis)
    aparams = PM.abstract_params(specs, pshard)

    if shape.kind == "train":
        loss_fn = make_loss_fn(cfg)
        _, opt_update = make_optimizer(cfg.optimizer, 1e-4)
        ospecs = opt_state_specs(specs, cfg.optimizer)
        oshard = param_shardings(ospecs, mesh, expert_axis=expert_axis)
        aopt = PM.abstract_params(ospecs, oshard)
        astep = jax.ShapeDtypeStruct((), jnp.int32)
        abatch = SH.batch_specs(cfg, shape, mesh)

        n_micro = getattr(cfg, "_n_microbatches", 1)

        def grads_of(params, batch):
            if n_micro <= 1:
                return jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                 batch)
            # §Perf: gradient accumulation — peak activation memory
            # scales with the microbatch, not the global batch.
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)

            def acc(carry, mb):
                g_sum, l_sum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g),
                    l_sum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, l), _ = jax.lax.scan(acc, (g0, jnp.float32(0)), micro)
            scale = 1.0 / n_micro
            g = jax.tree.map(lambda x: x * scale, g)
            return (l * scale, {"loss": l * scale}), g

        def step(state, batch):
            (loss, metrics), grads = grads_of(state["params"], batch)
            params, opt = opt_update(state["params"], grads, state["opt"],
                                     state["step"])
            return ({"params": params, "opt": opt,
                     "step": state["step"] + 1}, metrics)

        astate = {"params": aparams, "opt": aopt, "step": astep}
        out_sh = ({"params": pshard, "opt": oshard,
                   "step": None}, None)
        with use_mesh(mesh):
            lowered = jax.jit(step).lower(astate, abatch)
        return lowered, {"shape": shape}

    if shape.kind == "prefill":
        abatch = SH.batch_specs(cfg, shape, mesh)
        acache = SH.cache_abstract(cfg, shape, mesh)
        use_fused = cfg.fused_prefill and cfg.arch == "dense"
        kw = {}
        if cfg.shardmap_ffn and cfg.arch in ("dense", "vlm"):
            kw["mesh"] = mesh

        def step(params, batch, cache):
            fn = model.prefill_fused if use_fused else model.prefill
            return fn(params, cfg, batch, cache, shards=shards, **kw)

        with use_mesh(mesh):
            lowered = jax.jit(step).lower(aparams, abatch, acache)
        return lowered, {"shape": shape}

    # decode
    acache = SH.cache_abstract(cfg, shape, mesh)
    tok = SH.token_specs_decode(cfg, shape, mesh)
    window = cfg.decode_window(shape.seq_len) or None
    if cfg.arch == "ssm":
        window = None

    # flash-decode covers the non-ring case (full-context cache, i.e.
    # window None or == seq_len); the ring-buffer path keeps the baseline.
    use_flash = (getattr(cfg, "_flash_decode", False)
                 and cfg.arch == "dense"
                 and (not window or window == shape.seq_len))

    def step(params, token, cache, position):
        if use_flash:
            from repro.distributed.decode import decode_step_seqsharded
            return decode_step_seqsharded(params, cfg, token, cache,
                                          position, mesh, shards=shards)
        return model.decode_step(params, cfg, token, cache, position,
                                 shards=shards, window=window)

    with use_mesh(mesh):
        lowered = jax.jit(step).lower(aparams, tok["token"], acache,
                                      tok["position"])
    return lowered, {"shape": shape, "window": window}


def analyse(lowered, cfg, shape_name: str, mesh, compile_seconds=None):
    from repro.launch.hlo_analysis import analyze_hlo
    shape = SH.SHAPES[shape_name]
    compiled = lowered.compile()
    chips = int(np.prod(list(mesh.shape.values())))
    mem = compiled.memory_analysis()
    cost = CP.cost_analysis(compiled)
    text = compiled.as_text()
    # XLA's cost_analysis counts while bodies ONCE; analyze_hlo scales by
    # known_trip_count and derives dot flops / collective payload bytes
    # from the per-device SPMD module (see hlo_analysis.py).
    hm = analyze_hlo(text)
    flops_dev = hm.flops
    bytes_dev = hm.traffic_bytes
    coll_by_kind = hm.collective_bytes
    coll_counts = hm.collective_counts
    coll_dev = hm.collective_total

    compute_term = flops_dev / PEAK_FLOPS_BF16
    memory_term = bytes_dev / HBM_BW
    collective_term = coll_dev / ICI_BW
    terms = {"compute": compute_term, "memory": memory_term,
             "collective": collective_term}
    bottleneck = max(terms, key=terms.get)
    mflops = model_flops(cfg, shape)
    hlo_flops_global = flops_dev * chips

    rec = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "chips": chips,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_by_kind": coll_by_kind,
        "collective_counts": coll_counts,
        "arg_bytes_per_device": mem.argument_size_in_bytes,
        "out_bytes_per_device": mem.output_size_in_bytes,
        "temp_bytes_per_device": mem.temp_size_in_bytes,
        "peak_bytes_per_device": (mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes),
        "compute_term_s": compute_term,
        "memory_term_s": memory_term,
        "collective_term_s": collective_term,
        "bottleneck": bottleneck,
        "model_flops_global": mflops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": mflops / hlo_flops_global if hlo_flops_global else 0.0,
        "compile_seconds": compile_seconds,
    }
    return rec, compiled


def run_one(arch: str, shape_name: str, multi_pod: bool,
            variant: str = "baseline"):
    cfg = VARIANTS[variant](get_config(arch))
    shape = SH.SHAPES[shape_name]
    skip = SH.shape_supported(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "skipped": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, _ = build_lowering(cfg, shape_name, mesh)
    t1 = time.time()
    rec, compiled = analyse(lowered, cfg, shape_name, mesh)
    rec["variant"] = variant
    rec["lower_seconds"] = t1 - t0
    rec["compile_seconds"] = time.time() - t1
    return rec


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL)
    p.add_argument("--shape", choices=list(SH.SHAPES))
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    p.add_argument("--out", default=None)
    args = p.parse_args()

    pairs = []
    if args.all:
        for a in ASSIGNED:
            for s in SH.SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape
        pairs = [(args.arch, args.shape)]

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for arch, shape_name in pairs:
        try:
            rec = run_one(arch, shape_name, args.multi_pod, args.variant)
            status = rec.get("skipped") and "SKIP" or "OK"
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape_name,
                   "error": f"{type(e).__name__}: {e}"}
            status = "FAIL"
            n_fail += 1
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        rec["mesh"] = rec.get("mesh", mesh_tag)
        print(f"[{status}] {arch:24s} {shape_name:12s} mesh={mesh_tag} "
              + (f"bottleneck={rec.get('bottleneck')} "
                 f"peakMB={rec.get('peak_bytes_per_device', 0)/1e6:.0f} "
                 f"compile={rec.get('compile_seconds', 0):.0f}s"
                 if status == "OK" else rec.get("skipped", rec.get("error", ""))),
              flush=True)
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
            out_f.flush()
    if out_f:
        out_f.close()
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
