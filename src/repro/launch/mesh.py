"""Production meshes. v5e pod-slice numbers (DESIGN.md §5):
single pod = (data=16, model=16) = 256 chips; multi-pod adds a leading
pod axis: (pod=2, data=16, model=16) = 512 chips.

A FUNCTION, not a module constant: importing this module must never
touch jax device state.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh

# TPU v5e hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    return make_mesh((data, model), ("data", "model"))
