"""Training launcher: single-host training on synthetic data, or the
sharded production configuration when run on a real slice.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ALL, get_config
from repro.models.registry import get_model
from repro.nn.param import init_params, count_params
from repro.training.train import make_train_step
from repro.training.checkpoint import save_checkpoint
from repro.data.synthetic import batches


def add_frontend_stubs(cfg, batch, rng):
    """Attach stub modality embeddings (assignment: frontends are stubs)."""
    B = batch["tokens"].shape[0]
    if cfg.arch == "audio":
        batch["audio_embed"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    if cfg.arch == "vlm":
        batch["patch_embed"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)) * 0.02,
            jnp.float32)
        pad = -np.ones((B, cfg.n_patches), np.int32)
        batch["labels"] = jnp.concatenate(
            [jnp.asarray(pad), batch["labels"]], axis=1)
    return batch


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ALL, default="tinyllama-1.1b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--checkpoint", default=None)
    args = p.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = get_model(cfg)
    print(f"{cfg.name}: {count_params(model.specs(cfg))/1e6:.1f}M params, "
          f"optimizer={cfg.optimizer}")
    params = init_params(model.specs(cfg), jax.random.key(0))
    init_state, train_step = make_train_step(cfg, lr=args.lr)
    state = init_state(params)
    step_fn = jax.jit(train_step, donate_argnums=0)

    rng = np.random.default_rng(0)
    data = batches(cfg.vocab, args.batch, args.seq, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        batch = add_frontend_stubs(cfg, batch, rng)
        state, metrics = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"step {i:5d} loss={m['loss']:.4f} "
                  f"grad_norm={m['grad_norm']:.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, jax.device_get(state["params"]),
                        {"arch": cfg.name, "steps": args.steps})
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
