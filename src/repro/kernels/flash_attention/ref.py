"""Pure-jnp oracle for the causal flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, q_offset=0, window=None):
    """q: [T, dh]; k,v: [S, dh]. Query i sits at absolute position
    q_offset + i; keys at 0..S-1. Returns [T, dh] float32."""
    T, dh = q.shape
    S = k.shape[0]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / jnp.sqrt(
        jnp.float32(dh))
    if causal:
        qi = q_offset + jnp.arange(T)[:, None]
        kj = jnp.arange(S)[None, :]
        mask = kj <= qi
        if window:
            mask = mask & (kj > qi - window)
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return p @ v.astype(jnp.float32)
