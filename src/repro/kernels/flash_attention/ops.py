"""Jit'd wrappers: batched GQA flash attention over [B,T,H,dh] layouts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mha_flash(q, k, v, *, causal=True, q_offset=0, window=None,
              use_kernel: bool | None = None, interpret: bool | None = None):
    """q: [B,T,H,dh]; k,v: [B,S,Kv,dh] (GQA: H % Kv == 0). Returns
    [B,T,H,dh] f32."""
    B, T, H, dh = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    if use_kernel is None:
        use_kernel = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, dh)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, dh)
    if use_kernel:
        fn = lambda qq, kk, vv: K.flash_attention(
            qq, kk, vv, causal=causal, q_offset=q_offset, window=window,
            interpret=interpret)
    else:
        fn = lambda qq, kk, vv: R.attention_ref(
            qq, kk, vv, causal=causal, q_offset=q_offset, window=window)
    o = jax.vmap(fn)(qh, kh, vh)
    return o.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
