"""Jit'd wrappers: batched GQA flash attention over [B,T,H,dh] layouts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mha_flash_rows(q, k, v, pos0s, lengths, *, window=None,
                   use_kernel: bool | None = None,
                   interpret: bool | None = None):
    """Per-row-offset batched GQA block-prefill attention — the
    kernel-backed dense baseline of the serving path (`attend_block_
    rows` routes here on TPU; off-TPU its masked-gather math is the
    fallback). q: [B, N, H, dh] (RoPE applied); k, v: [B, S, Kv, dh];
    pos0s, lengths: [B] int32. Returns [B, N, H, dh] f32.

    S is padded to a block_k multiple for the kernel (padded keys are
    masked by `lengths`)."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    if use_kernel:
        block_k = q.shape[1]
        pad = (-k.shape[1]) % block_k
        if pad:
            cfgpad = ((0, 0), (0, pad), (0, 0), (0, 0))
            k = jnp.pad(k, cfgpad)
            v = jnp.pad(v, cfgpad)
        return K.flash_attention_rows(q, k, v, pos0s, lengths,
                                      block_k=block_k, window=window,
                                      interpret=interpret)
    # gather fallback: masked grouped-GQA softmax over the full cache
    B, N, H, dh = q.shape
    S, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    qg = q.astype(jnp.float32).reshape(B, N, Kv, rep, dh)
    s = jnp.einsum("bngrd,bsgd->bgrns", qg, k.astype(jnp.float32))
    s = s / (dh ** 0.5)
    qpos = pos0s[:, None] + jnp.arange(N)[None, :]
    kj = jnp.arange(S)[None, None, :]
    mask = (kj <= qpos[:, :, None]) & (kj < lengths[:, None, None])
    if window:
        mask = mask & (kj > qpos[:, :, None] - window)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrns,bsgd->bngrd", p, v.astype(jnp.float32))
    return o.reshape(B, N, H, dh)


def mha_flash(q, k, v, *, causal=True, q_offset=0, window=None,
              use_kernel: bool | None = None, interpret: bool | None = None):
    """q: [B,T,H,dh]; k,v: [B,S,Kv,dh] (GQA: H % Kv == 0). Returns
    [B,T,H,dh] f32."""
    B, T, H, dh = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    if use_kernel is None:
        use_kernel = on_tpu()
    if interpret is None:
        interpret = not on_tpu()
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, T, dh)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, dh)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, dh)
    if use_kernel:
        fn = lambda qq, kk, vv: K.flash_attention(
            qq, kk, vv, causal=causal, q_offset=q_offset, window=window,
            interpret=interpret)
    else:
        fn = lambda qq, kk, vv: R.attention_ref(
            qq, kk, vv, causal=causal, q_offset=q_offset, window=window)
    o = jax.vmap(fn)(qh, kh, vh)
    return o.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
