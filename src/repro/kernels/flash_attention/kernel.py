"""Pallas TPU kernel: causal flash attention (online softmax).

Used for the fused-prefill path (the beyond-paper baseline the blockwise
FastForward prefill is compared against) and for block-cached prefill
attention (q_offset > 0). One (q-block, k-block) grid with f32 running
max / sum / accumulator scratch in VMEM; k-blocks entirely above the
causal diagonal are skipped via pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_k, q_offset, causal, window):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos0 = q_offset + qi * block_q

    def compute():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_pos0 + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = cols <= rows
            if window:
                mask = mask & (cols > rows - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip k-blocks entirely above the diagonal (or beyond the window)
        first_row = q_pos0
        last_row = q_pos0 + block_q - 1
        k_lo = ki * block_k
        relevant = k_lo <= last_row
        if window:
            relevant = relevant & (k_lo + block_k - 1 > first_row - window)
        pl.when(relevant)(compute)
    else:
        compute()

    @pl.when(ki == pl.num_programs(1) - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_k", "causal", "q_offset", "window", "interpret"))
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, q_offset: int = 0,
                    window: int | None = None, interpret: bool = False):
    """q: [T, dh]; k, v: [S, dh] -> o [T, dh] (f32). T % block_q == 0,
    S % block_k == 0. vmap over (batch, head) from the ops wrapper."""
    T, dh = q.shape
    S = k.shape[0]
    assert T % block_q == 0 and S % block_k == 0
    scale = 1.0 / (dh ** 0.5)
    grid = (T // block_q, S // block_k)
    kernel = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, q_offset=q_offset,
                          causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, dh), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((block_k, dh), lambda qi, ki: (ki, 0)),
            pl.BlockSpec((block_k, dh), lambda qi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dh), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((T, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(q, k, v)
