"""Pallas TPU kernel: causal flash attention (online softmax).

Used for the fused-prefill path (the beyond-paper baseline the blockwise
FastForward prefill is compared against) and for block-cached prefill
attention (q_offset > 0). One (q-block, k-block) grid with f32 running
max / sum / accumulator scratch in VMEM; k-blocks entirely above the
causal diagonal are skipped via pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_k, q_offset, causal, window):
    qi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos0 = q_offset + qi * block_q

    def compute():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = q_pos0 + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = cols <= rows
            if window:
                mask = mask & (cols > rows - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # skip k-blocks entirely above the diagonal (or beyond the window)
        first_row = q_pos0
        last_row = q_pos0 + block_q - 1
        k_lo = ki * block_k
        relevant = k_lo <= last_row
        if window:
            relevant = relevant & (k_lo + block_k - 1 > first_row - window)
        pl.when(relevant)(compute)
    else:
        compute()

    @pl.when(ki == pl.num_programs(1) - 1)
    def _finish():
        o_ref[...] = (acc_scr[...] /
                      jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _flash_rows_kernel(p0_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, block_k, kv_heads,
                       scale, window):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos0 = p0_ref[b]
    N = q_ref.shape[1]

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [N, H, dh]
        H, dh = q.shape[1], q.shape[2]
        rep = H // kv_heads
        qg = q.reshape(N, kv_heads, rep, dh)
        k = k_ref[0].astype(jnp.float32)                  # [bk, Kv, dh]
        s = jnp.einsum("ngrd,tgd->grnt", qg, k)
        kpos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, N, block_k), 3)
        qpos = pos0 + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, N, block_k), 2)
        mask = (kpos <= qpos) & (kpos < len_ref[b])
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1).reshape(H, N)
        m_new = jnp.maximum(m_prev, m_cur)
        # where-guard: a fully-masked row in the first relevant k-block
        # would otherwise compute exp(NEG_INF - NEG_INF) == 1
        p = jnp.where(
            mask, jnp.exp(s - m_new.reshape(kv_heads, rep, N)[..., None]),
            0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1).reshape(H, N)
        v = v_ref[0].astype(jnp.float32)
        pv = jnp.einsum("grnt,tgd->grnd", p, v).reshape(H, N, dh)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv
        m_scr[...] = m_new

    # skip k-blocks entirely above this ROW's causal front (per-row
    # traced pos0s — the reason the static-q_offset kernel above can't
    # serve the batched multi-request prefill path)
    relevant = j * block_k <= pos0 + N - 1
    if window:
        relevant = relevant & ((j + 1) * block_k - 1 > pos0 - window)
    pl.when(relevant)(compute)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = o.transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_k", "window", "interpret"))
def flash_attention_rows(q, k, v, pos0s, lengths, *, block_k: int = 128,
                         window: int | None = None,
                         interpret: bool = False):
    """Per-row-offset batched GQA flash attention (the dense kernel
    behind multi-request block prefill): row b's query block sits at
    absolute positions [pos0s[b], pos0s[b]+N) of its own cache row.

    q: [B, N, H, dh] (RoPE applied); k, v: [B, S, Kv, dh]; pos0s,
    lengths: [B] int32 (scalar-prefetched — they drive the per-row
    causal k-block skip). S % block_k == 0. Returns [B, N, H, dh] f32."""
    B, N, H, dh = q.shape
    S, Kv = k.shape[1], k.shape[2]
    assert S % block_k == 0 and H % Kv == 0
    grid = (B, S // block_k)
    kernel = pl.pallas_call(
        functools.partial(_flash_rows_kernel, block_k=block_k,
                          kv_heads=Kv, scale=1.0 / (dh ** 0.5),
                          window=window),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, N, H, dh),
                             lambda b, j, p0, ln: (b, 0, 0, 0)),
                pl.BlockSpec((1, block_k, Kv, dh),
                             lambda b, j, p0, ln: (b, j, 0, 0)),
                pl.BlockSpec((1, block_k, Kv, dh),
                             lambda b, j, p0, ln: (b, j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, N, H, dh),
                                   lambda b, j, p0, ln: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, N), jnp.float32),
                pltpu.VMEM((H, N), jnp.float32),
                pltpu.VMEM((H, N, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, N, H, dh), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(jnp.asarray(pos0s, jnp.int32),
                  jnp.asarray(lengths, jnp.int32), q, k, v)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_k", "causal", "q_offset", "window", "interpret"))
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, q_offset: int = 0,
                    window: int | None = None, interpret: bool = False):
    """q: [T, dh]; k, v: [S, dh] -> o [T, dh] (f32). T % block_q == 0,
    S % block_k == 0. vmap over (batch, head) from the ops wrapper."""
    T, dh = q.shape
    S = k.shape[0]
    assert T % block_q == 0 and S % block_k == 0
    scale = 1.0 / (dh ** 0.5)
    grid = (T // block_q, S // block_k)
    kernel = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, q_offset=q_offset,
                          causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, dh), lambda qi, ki: (qi, 0)),
            pl.BlockSpec((block_k, dh), lambda qi, ki: (ki, 0)),
            pl.BlockSpec((block_k, dh), lambda qi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dh), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((T, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(q, k, v)
