"""Jit'd public wrapper around the paged-attention decode kernel.

Backend dispatch rule (mirrors kernels/sparse_ffn/ops.py and
kernels/grouped_matmul/ops.py — the paged serving decode path relies on
this):

  * TPU -> Pallas paged-decode kernel (page tables + decode positions
           scalar-prefetched, one K/V page slab DMA per grid step,
           online softmax over the page axis);
  * XLA -> gather-based page-table attention (``ref.paged_attention_ref``
           — gathers each row's pages into a contiguous view and runs
           the exact ragged-decode GQA core, so off-TPU the paged
           serving engine is bit-identical to the slot-pool engine);
  * ``use_kernel=True`` off-TPU forces the interpret-mode kernel (tests
           cross-check it against both oracles in ref.py).
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention import kernel as K
from repro.kernels.paged_attention import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_attention_op(q, k_pages, v_pages, page_table, positions, *,
                       window=None, use_kernel: bool | None = None):
    """Paged decode attention. q: [B, H, dh] (RoPE applied);
    k_pages/v_pages: [n_pages, psz, Kv, dh], or the int8-quantized heap
    ({"q": int8 pages, "s": f32 [n_pages, Kv]}, kernels/kv_quant) —
    the kernel branch dispatches the fused-dequant quant twin, the XLA
    branch dequantizes inside the table-directed gather
    (nn.attention.gather_pages); page_table: [B, max_pages] int32;
    positions: [B] int32. Returns [B, H, dh] float32."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        if isinstance(k_pages, dict):
            return K.paged_decode_attention_quant(
                q, k_pages["q"], k_pages["s"], v_pages["q"],
                v_pages["s"], page_table, positions, window=window,
                interpret=not on_tpu())
        return K.paged_decode_attention(q, k_pages, v_pages, page_table,
                                        positions, window=window,
                                        interpret=not on_tpu())
    return R.paged_attention_ref(q, k_pages, v_pages, page_table,
                                 positions, window=window)
