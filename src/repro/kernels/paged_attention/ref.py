"""Reference oracles for paged KV-cache decode attention.

Two oracles, one contract:

  * ``paged_attention_ref`` — the gather-based page-table path: gather
    each row's pages into a contiguous [B, S, Kv, dh] view (S =
    max_pages * page_size) and run the exact ragged-decode GQA core
    (``repro.nn.attention.dot_attention`` with the kj <= position /
    sliding-window masks). This IS the XLA serving path dispatched by
    ops.py off-TPU, and because the gathered view holds bit-identical
    values at every attended position, its output is bit-identical to
    the slot-pool ``attend_decode_ragged`` — the paged-vs-slot greedy
    equivalence the serving tests assert.
  * ``paged_attention_dense_ref`` — the masked dense oracle: attention
    over the RAW page pool with a per-(row, page, offset) validity mask
    built from the page table, never materializing a gathered view.
    Structurally independent of the gather path (no shared indexing
    code), so the two cross-check each other and the Pallas kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

# the gather is shared with the prefill path (nn.attention): ONE
# table-directed gather implementation backs the paged-vs-slot
# bit-identity contract on both the block and decode sides
from repro.nn.attention import NEG_INF, dot_attention, gather_pages


def _decode_mask(positions, S, window):
    """[B, 1, 1, 1, S] validity mask of the ragged decode step: key j of
    row b is attended iff j <= positions[b] (and inside the window)."""
    kj = jnp.arange(S)[None, :]
    valid = kj <= positions[:, None]
    if window:
        valid = valid & (kj > positions[:, None] - window)
    return valid[:, None, None, None, :]


def paged_attention_ref(q, k_pages, v_pages, page_table, positions, *,
                        window=None):
    """Gather-based page-table decode attention (the XLA serving path).

    q: [B, H, dh] (RoPE already applied); k_pages/v_pages:
    [n_pages, psz, Kv, dh]; page_table: [B, max_pages] int32 (unused
    tail entries point at the reserved null page — never attended, the
    position mask caps keys at positions[b]); positions: [B] int32
    (each row's decode position, inclusive). Returns [B, H, dh]."""
    kc = gather_pages(k_pages, page_table)
    vc = gather_pages(v_pages, page_table)
    mask = _decode_mask(positions, kc.shape[1], window)
    o = dot_attention(q[:, None], kc, vc, mask)
    return o[:, 0]


def paged_attention_dense_ref(q, k_pages, v_pages, page_table, positions,
                              *, window=None):
    """Masked dense oracle: softmax over ALL (page, offset) pairs of the
    raw pool, masked down to the pages each row's table actually owns.

    Builds scores [B, Kv, rep, n_pages * psz] directly against the pool
    and masks entry (p, t) of row b unless page_table[b, j] == p for the
    j covering absolute position j*psz + t <= positions[b]. O(B * pool)
    — validation only."""
    B, mp = page_table.shape
    n_pages, psz, Kv, dh = k_pages.shape
    H = q.shape[1]
    rep = H // Kv
    qg = q.reshape(B, Kv, rep, dh).astype(jnp.float32)
    kf = k_pages.reshape(n_pages * psz, Kv, dh)
    vf = v_pages.reshape(n_pages * psz, Kv, dh)
    scores = jnp.einsum("bgrk,sgk->bgrs", qg, kf.astype(jnp.float32))
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)

    # owner[b, p] = absolute position of page p's first token in row b's
    # sequence, or -1 when row b does not own page p
    owner = jnp.full((B, n_pages), -1, jnp.int32)
    rows = jnp.repeat(jnp.arange(B), mp)
    owner = owner.at[rows, page_table.reshape(-1)].set(
        jnp.tile(jnp.arange(mp, dtype=jnp.int32) * psz, B))
    # the null page (id 0) is a write sink shared by every table's
    # unallocated tail — nobody attends it
    owner = owner.at[:, 0].set(-1)
    base = jnp.repeat(owner, psz, axis=1)                  # [B, n_pages*psz]
    kpos = base + jnp.tile(jnp.arange(psz, dtype=jnp.int32), n_pages)[None]
    valid = (base >= 0) & (kpos <= positions[:, None])
    if window:
        valid = valid & (kpos > positions[:, None] - window)

    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o = jnp.einsum("bgrs,sgk->bgrk", probs, vf.astype(jnp.float32))
    return o.reshape(B, H, dh).astype(v_pages.dtype)
