"""Pallas TPU kernel: paged KV-cache decode attention.

vLLM-style PagedAttention adapted to the TPU dataflow (see
kernels/sparse_ffn for the pattern): the per-row page tables and decode
positions are scalar-prefetched, and each grid step's BlockSpec
index_map redirects the K/V slab DMA to page ``table[b, j]`` of the
pooled [n_pages, page_size, Kv, dh] buffers — the kernel never sees a
gathered contiguous cache, so decode attention reads exactly the pages
a row owns straight out of the shared pool.

Grid: (B, max_pages), online softmax over the page axis with running
max / sum / accumulator scratch in VMEM (flash-attention recurrence).
Pages entirely past a row's decode position (the unallocated null-page
tail) or fully behind the sliding window are DEAD: their compute is
skipped via pl.when AND their DMA is elided via the index-map clamp
used by the sparse-FFN / block-sparse-attention dead slots — a dead
grid step's K/V index map re-requests the nearest LIVE page's slab, and
Pallas skips the copy when consecutive steps ask for the same block.
Dead pages' bytes therefore never cross HBM->VMEM (the bit-test points
dead table entries at a poisoned page and the output is unchanged).
GQA is computed grouped: q [H, dh] reshaped to [Kv, rep, dh] against
the page's [psz, Kv, dh] keys.

VMEM working set per step: q (1, H, dh), one K page + one V page
(1, psz, Kv, dh), scratch m/l (H, 1) + acc (H, dh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_decode_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, psz, kv_heads, scale,
                         window):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [H, dh]
        H, dh = q.shape
        rep = H // kv_heads
        qg = q.reshape(kv_heads, rep, dh)
        k = k_ref[0].astype(jnp.float32)                  # [psz, Kv, dh]
        s = jnp.einsum("grd,tgd->grt", qg, k)             # [Kv, rep, psz]
        kpos = j * psz + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, psz), 2)
        valid = kpos <= pos
        if window:
            valid = valid & (kpos > pos - window)
        s = jnp.where(valid, s, NEG_INF).reshape(H, psz)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [H, psz]
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                  # [psz, Kv, dh]
        pv = jnp.einsum("grt,tgd->grd",
                        p.reshape(kv_heads, rep, psz), v)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(H, dh)
        m_scr[...] = m_new

    # skip pages whose first key is already past the decode position
    # (the row's unallocated null-page tail) or fully behind the window
    relevant = j * psz <= pos
    if window:
        relevant = relevant & ((j + 1) * psz - 1 > pos - window)
    pl.when(relevant)(compute)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel_quant(table_ref, pos_ref, q_ref, k_ref, ks_ref,
                               v_ref, vs_ref, o_ref, m_scr, l_scr,
                               acc_scr, *, psz, kv_heads, scale, window):
    """Int8 twin of _paged_decode_kernel: K/V slabs arrive as int8 pages
    plus their per-(page, kv-head) f32 scales (kernels/kv_quant scheme)
    and are dequantized IN VMEM right before the MXU contractions — the
    f32 page never exists in HBM, which is the bandwidth point of the
    quantized heap."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [H, dh]
        H, dh = q.shape
        rep = H // kv_heads
        qg = q.reshape(kv_heads, rep, dh)
        k = (k_ref[0].astype(jnp.float32)
             * ks_ref[0][None, :, None])                  # [psz, Kv, dh]
        s = jnp.einsum("grd,tgd->grt", qg, k)             # [Kv, rep, psz]
        kpos = j * psz + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, psz), 2)
        valid = kpos <= pos
        if window:
            valid = valid & (kpos > pos - window)
        s = jnp.where(valid, s, NEG_INF).reshape(H, psz)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [H, psz]
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        v = (v_ref[0].astype(jnp.float32)
             * vs_ref[0][None, :, None])                  # [psz, Kv, dh]
        pv = jnp.einsum("grt,tgd->grd",
                        p.reshape(kv_heads, rep, psz), v)
        acc_scr[...] = acc_scr[...] * corr + pv.reshape(H, dh)
        m_scr[...] = m_new

    relevant = j * psz <= pos
    if window:
        relevant = relevant & ((j + 1) * psz - 1 > pos - window)
    pl.when(relevant)(compute)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_table, positions, *,
                           window: int | None = None,
                           interpret: bool = False):
    """q: [B, H, dh] (RoPE applied); k_pages/v_pages:
    [n_pages, psz, Kv, dh]; page_table: [B, max_pages] int32 (page j of
    row b holds that row's absolute positions [j*psz, (j+1)*psz), unused
    tail entries point at the reserved null page 0); positions: [B]
    int32 decode positions (inclusive — the just-written token).
    Returns [B, H, dh] float32."""
    B, H, dh = q.shape
    n_pages, psz, Kv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    assert page_table.shape[0] == B and positions.shape == (B,)
    assert H % Kv == 0

    grid = (B, max_pages)

    def kv_index(b, j, tbl, pos):
        # DMA-skip dead pages (same clamp idiom as the sparse-FFN /
        # block-sparse-attention dead slots): clamp the page-axis step
        # into the row's LIVE range [first windowed page, pos // psz].
        # Dead steps re-request the boundary live page — consecutive
        # identical block indices elide the copy — so bytes of pages
        # past the decode position (null tail) or fully behind the
        # window never cross HBM->VMEM. Compute stays gated by the
        # matching pl.when(relevant) in the kernel body.
        live_hi = pos[b] // psz
        jj = jnp.minimum(j, live_hi)
        if window:
            live_lo = jnp.maximum((pos[b] - window + 1) // psz, 0)
            jj = jnp.maximum(jj, live_lo)
        return (tbl[b, jj], 0, 0, 0)

    kernel = pl.pallas_call(
        functools.partial(_paged_decode_kernel, psz=psz, kv_heads=Kv,
                          scale=1.0 / (dh ** 0.5), window=window),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, dh), lambda b, j, tbl, pos: (b, 0, 0)),
                pl.BlockSpec((1, psz, Kv, dh), kv_index),
                pl.BlockSpec((1, psz, Kv, dh), kv_index),
            ],
            out_specs=pl.BlockSpec((1, H, dh),
                                   lambda b, j, tbl, pos: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(jnp.asarray(page_table, jnp.int32),
                  jnp.asarray(positions, jnp.int32), q, k_pages, v_pages)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def paged_decode_attention_quant(q, k_pages, k_scales, v_pages, v_scales,
                                 page_table, positions, *,
                                 window: int | None = None,
                                 interpret: bool = False):
    """Quantized-heap twin of paged_decode_attention: k/v_pages are
    int8 [n_pages, psz, Kv, dh] with f32 scales [n_pages, Kv]
    (kernels/kv_quant scheme). The scale slabs ride the SAME clamped
    index map as their pages, so dead pages' scale bytes are DMA-elided
    together with their page bytes."""
    B, H, dh = q.shape
    n_pages, psz, Kv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    assert page_table.shape[0] == B and positions.shape == (B,)
    assert H % Kv == 0
    assert k_scales.shape == v_scales.shape == (n_pages, Kv)

    grid = (B, max_pages)

    def kv_index(b, j, tbl, pos):
        live_hi = pos[b] // psz
        jj = jnp.minimum(j, live_hi)
        if window:
            live_lo = jnp.maximum((pos[b] - window + 1) // psz, 0)
            jj = jnp.maximum(jj, live_lo)
        return (tbl[b, jj], 0, 0, 0)

    def scale_index(b, j, tbl, pos):
        return kv_index(b, j, tbl, pos)[:2]

    kernel = pl.pallas_call(
        functools.partial(_paged_decode_kernel_quant, psz=psz,
                          kv_heads=Kv, scale=1.0 / (dh ** 0.5),
                          window=window),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, dh), lambda b, j, tbl, pos: (b, 0, 0)),
                pl.BlockSpec((1, psz, Kv, dh), kv_index),
                pl.BlockSpec((1, Kv), scale_index),
                pl.BlockSpec((1, psz, Kv, dh), kv_index),
                pl.BlockSpec((1, Kv), scale_index),
            ],
            out_specs=pl.BlockSpec((1, H, dh),
                                   lambda b, j, tbl, pos: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(jnp.asarray(page_table, jnp.int32),
                  jnp.asarray(positions, jnp.int32), q,
                  k_pages, k_scales, v_pages, v_scales)
