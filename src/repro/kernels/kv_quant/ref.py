"""Reference oracles for the int8-quantized KV page heap.

Quantization scheme (the ONE scheme every consumer shares — the Pallas
kernels, the XLA twins, the attention dequant-gather paths, and the
host swap tier all round-trip these exact bytes):

  * symmetric int8 per (page, kv-head): for page p and KV head g,
    scale s[p, g] = absmax(x[p, :, g, :]) / 127, stored f32;
    q[p, t, g, d] = clip(round(x[p, t, g, d] / s[p, g]), -127, 127).
  * all-zero pages keep scale 0 (dequant gives exact zeros), so the
    reserved null page 0 stays provably all-zeros under quantization
    exactly as it does in the f32 heap.
  * dequant is q.astype(f32) * s — elementwise, no clipping, so a
    quantize -> dequantize round trip is STABLE: requantizing a
    dequantized page reproduces q bit-exactly and s to within one f32
    ulp (absmax of q*s is 127*s, whose rescale by the rounded
    reciprocal 1/127 rounds back to s up to the last mantissa bit).

Error contract (documented tolerance, asserted by tests/test_kv_quant):
each dequantized element differs from the source by at most
0.5 * absmax / 127 — about 0.4% of the page's per-head dynamic range.
Paged decode-token writes dequantize-modify-requantize a page, but the
round-trip stability above means previously-written tokens move by at
most an ulp per rewrite unless the page's absmax grows (a fresh token
sets a new scale); the drift per rescale stays bounded by the same
half-ULP, and a page is rewritten at most page_size times over its
life.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# the one scale constant every quantizer shares (see quantize_pages_ref)
INV_127 = np.float32(1.0 / 127.0)


def quantize_pages_ref(x):
    """[P, psz, Kv, dh] float -> (q int8 [P, psz, Kv, dh],
    s float32 [P, Kv]) symmetric per-(page, kv-head) quantization."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=(1, 3))            # [P, Kv]
    # explicit f32 reciprocal multiply (not `/ 127.0`): XLA rewrites
    # constant divisions to reciprocal multiplies inside fused jits but
    # not in eager ops, and bit-exact oracle/kernel agreement needs the
    # SAME rounding on both paths
    s = (absmax * INV_127).astype(jnp.float32)
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(xf / safe[:, None, :, None]), -127, 127)
    return q.astype(jnp.int8), s


def dequantize_pages_ref(q, s):
    """(q int8 [P, psz, Kv, dh], s f32 [P, Kv]) -> float32 pages."""
    return q.astype(jnp.float32) * s[:, None, :, None]
