"""Pallas TPU kernels: fused int8 page quantize / dequantize.

House pattern (see kernels/sparse_ffn): one grid step per page, the
whole [psz, Kv, dh] page slab resident in VMEM, scale reduction and
int8 cast fused in a single pass — the page never round-trips HBM
between the absmax reduction and the cast, which is the point of
fusing (an XLA twin materializes the f32 normalized page in HBM).

Quantization semantics are EXACTLY ref.quantize_pages_ref /
ref.dequantize_pages_ref (symmetric per-(page, kv-head), zero pages
keep scale 0); tests cross-check the interpret-mode kernels against
the oracles bit-exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.kernels.kv_quant.ref import INV_127


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)                  # [psz, Kv, dh]
    absmax = jnp.max(jnp.abs(x), axis=(0, 2))         # [Kv]
    s = absmax * INV_127          # reciprocal multiply, same as the ref
    safe = jnp.where(s > 0, s, 1.0)
    q = jnp.clip(jnp.round(x / safe[None, :, None]), -127, 127)
    q_ref[0] = q.astype(jnp.int8)
    s_ref[0] = s


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[0] = (q_ref[0].astype(jnp.float32)
                * s_ref[0][None, :, None])


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pages(x, *, interpret: bool = False):
    """[P, psz, Kv, dh] -> (q int8 [P, psz, Kv, dh], s f32 [P, Kv]),
    one fused absmax+cast pass per page."""
    P, psz, Kv, dh = x.shape
    kernel = pl.pallas_call(
        _quantize_kernel,
        grid=(P,),
        in_specs=[pl.BlockSpec((1, psz, Kv, dh), lambda p: (p, 0, 0, 0))],
        out_specs=(
            pl.BlockSpec((1, psz, Kv, dh), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, Kv), lambda p: (p, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((P, psz, Kv, dh), jnp.int8),
            jax.ShapeDtypeStruct((P, Kv), jnp.float32),
        ),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )
    return kernel(x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_pages(q, s, *, interpret: bool = False):
    """(q int8 [P, psz, Kv, dh], s f32 [P, Kv]) -> f32 pages, fused
    cast+scale per page."""
    P, psz, Kv, dh = q.shape
    kernel = pl.pallas_call(
        _dequantize_kernel,
        grid=(P,),
        in_specs=[
            pl.BlockSpec((1, psz, Kv, dh), lambda p: (p, 0, 0, 0)),
            pl.BlockSpec((1, Kv), lambda p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((1, psz, Kv, dh), lambda p: (p, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((P, psz, Kv, dh), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )
    return kernel(q, s)
