from repro.kernels.kv_quant.ops import (dequantize_pages_op,
                                        quantize_pages_op)
from repro.kernels.kv_quant.ref import (dequantize_pages_ref,
                                        quantize_pages_ref)

__all__ = ["dequantize_pages_op", "dequantize_pages_ref",
           "quantize_pages_op", "quantize_pages_ref"]
