"""Jit'd public wrappers around the KV-quantization kernels.

Backend dispatch rule (same as kernels/sparse_ffn/ops.py — the paged
serving write/gather paths rely on this):

  * TPU -> fused Pallas quantize/dequantize kernels (one VMEM pass per
           page, no HBM round trip between reduction and cast);
  * XLA -> ref oracles (interpret-mode Pallas is far slower than XLA
           on host, so off-TPU the oracle IS the serving path);
  * ``use_kernel=True`` off-TPU forces the interpret-mode kernel
    (tests cross-check it bit-exactly against the oracle).
"""
from __future__ import annotations

import jax

from repro.kernels.kv_quant import kernel as K
from repro.kernels.kv_quant import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize_pages_op(x, use_kernel: bool | None = None):
    """[P, psz, Kv, dh] -> (q int8, s f32 [P, Kv]); see ref.py for the
    quantization scheme and error contract."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        return K.quantize_pages(x, interpret=not on_tpu())
    return R.quantize_pages_ref(x)


def dequantize_pages_op(q, s, use_kernel: bool | None = None):
    """(q int8 [P, psz, Kv, dh], s f32 [P, Kv]) -> f32 pages."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        return K.dequantize_pages(q, s, interpret=not on_tpu())
    return R.dequantize_pages_ref(q, s)
