"""XLA reference oracle for the grouped (per-expert segment) matmul.

The dropless MoE dispatch sorts the (token, expert) pairs by expert id
and multiplies each contiguous segment of rows by its own expert's
weight matrix. This module is the portable fallback used when
``jax.lax.ragged_dot`` is unavailable and for cross-checking the Pallas
kernel: one masked dense matmul per expert (E x the active FLOPs — an
oracle, not a fast path).

Semantics match ``jax.lax.ragged_dot``: row m belongs to group g iff
``offsets[g] <= m < offsets[g+1]`` with ``offsets = [0, cumsum(sizes)]``,
and rows past ``sum(group_sizes)`` (sentinel-routed masked tokens,
padding) produce zeros.
"""
from __future__ import annotations

import jax.numpy as jnp


def grouped_matmul_ref(lhs, rhs, group_sizes):
    """lhs: [M, D] rows sorted by group; rhs: [E, D, F];
    group_sizes: [E] int32 (sum <= M). Returns [M, F] float32."""
    M = lhs.shape[0]
    E = rhs.shape[0]
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(M)
    out = jnp.zeros((M, rhs.shape[2]), jnp.float32)
    for e in range(E):
        keep = (row >= starts[e]) & (row < ends[e])
        y = jnp.einsum("md,df->mf", lhs, rhs[e],
                       preferred_element_type=jnp.float32)
        out = out + jnp.where(keep[:, None], y, 0.0)
    return out
