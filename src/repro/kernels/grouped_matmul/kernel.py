"""Pallas TPU kernel skeleton: grouped matmul for dropless MoE dispatch.

The dropless routed-expert path (repro.models.moe) sorts the flattened
(token, expert) assignments by expert id, so expert e owns the
contiguous row segment [offsets[e], offsets[e+1]) of the sorted
activations. This kernel walks grid (M/block_m, E): each step DMAs
expert e's [D, F] weight slab into VMEM, and — only when the row tile
overlaps e's segment (`pl.when` on the scalar-prefetched offsets) —
computes the tile's dot product on the MXU and accumulates the rows
inside the segment into the output block.

Skeleton status: correct (interpret-mode checked against the XLA
oracle + jax.lax.ragged_dot in tests) but not tuned — a production
grouped matmul would precompute a tile->group map so each row tile
visits only the experts it intersects (MegaBlocks-style) instead of
predicating over all E, and would skip the weight DMA for skipped
steps. ROADMAP open item: on-device validation.

VMEM working set per step:
  lhs block  [block_m, D]
  rhs slab   [D, F]        (one expert's weight matrix)
  out        [block_m, F]  (accumulator, revisited across the E axis)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _grouped_matmul_kernel(offs_ref, lhs_ref, rhs_ref, o_ref, *,
                           block_m: int):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    start = offs_ref[e]
    end = offs_ref[e + 1]
    m0 = pl.program_id(0) * block_m

    @pl.when((end > m0) & (start < m0 + block_m))
    def _compute():
        x = lhs_ref[...].astype(jnp.float32)
        y = jax.lax.dot(x, rhs_ref[0].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        rows = m0 + jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)
        keep = (rows >= start) & (rows < end)
        o_ref[...] += jnp.where(keep, y, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def grouped_matmul(lhs, rhs, group_sizes, *, block_m: int = 128,
                   interpret: bool = False):
    """lhs: [M, D] rows sorted by group; rhs: [E, D, F]; group_sizes:
    [E] int32. Returns [M, F] float32; rows past sum(group_sizes) yield
    zeros (matching jax.lax.ragged_dot). M % block_m == 0 — the ops
    wrapper pads ragged row counts up to the tile."""
    M, D = lhs.shape
    E, _, F = rhs.shape
    assert M % block_m == 0, (M, block_m)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(group_sizes).astype(jnp.int32)])
    grid = (M // block_m, E)

    kernel = pl.pallas_call(
        functools.partial(_grouped_matmul_kernel, block_m=block_m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, D), lambda m, e, offs: (m, 0)),
                pl.BlockSpec((1, D, F), lambda m, e, offs: (e, 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, F), lambda m, e, offs: (m, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, F), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(offs, lhs, rhs)
