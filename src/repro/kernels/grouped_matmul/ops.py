"""Jit'd public wrapper around the grouped-matmul kernel.

Backend dispatch rule (mirrors kernels/sparse_ffn/ops.py — the dropless
MoE routed-expert path relies on this):

  * TPU -> Pallas grouped-matmul kernel (segment offsets scalar-
           prefetched, one expert weight slab DMA per grid step);
  * XLA -> ``jax.lax.ragged_dot`` where this JAX exposes it (verified
           dispatch-group invariant: a row's output is bit-identical
           whatever group sizes surround it, which is exactly the
           blockwise-prefill == full-forward equivalence the serving
           stack asserts), masked-einsum oracle otherwise
           (`ref.grouped_matmul_ref`);
  * ``use_kernel=True`` off-TPU forces the interpret-mode kernel
           (tests cross-check it against both XLA paths).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.grouped_matmul import kernel as K
from repro.kernels.grouped_matmul import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def has_ragged_dot() -> bool:
    return hasattr(jax.lax, "ragged_dot")


def _block_m_for(M: int) -> int:
    return min(128, max(8, -(-M // 8) * 8))


def grouped_matmul_op(lhs, rhs, group_sizes, use_kernel: bool | None = None):
    """lhs: [M, D] rows sorted by group; rhs: [E, D, F]; group_sizes:
    [E] int32 (sum <= M; leftover rows — sentinel-routed masked tokens
    and tile padding — come out zero). Returns [M, F] float32."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        M = lhs.shape[0]
        bm = _block_m_for(M)
        pad = -M % bm
        if pad:
            lhs = jnp.concatenate(
                [lhs, jnp.zeros((pad, lhs.shape[1]), lhs.dtype)])
        y = K.grouped_matmul(lhs, rhs, group_sizes, block_m=bm,
                             interpret=not on_tpu())
        return y[:M] if pad else y
    if has_ragged_dot():
        return jax.lax.ragged_dot(lhs, rhs,
                                  jnp.asarray(group_sizes, jnp.int32),
                                  preferred_element_type=jnp.float32)
    return R.grouped_matmul_ref(lhs, rhs, group_sizes)
