"""Pallas TPU kernel: block-sparse flash-attention prefill.

The FLOP-bending half of the dual-budget SparsityPlan (see the DESIGN
note in core/fastforward.py): per 128-token query block, a cheap
pooled-QK proxy (computed in XLA, see ops.select_kv_blocks) picks the
KV blocks worth attending, and this kernel walks ONLY the selection —
scalar-prefetched block-id + count operands, one K/V slab DMA per grid
step, online softmax over the selected-block axis.

The kernel is layout-agnostic and thereby page-table-aware: it reads
[P, blk, Kv, dh] POOL slabs through prefetched pool ids, so the slot
layout passes its reshaped contiguous cache (pool id = row * n_blocks
+ block) and the paged layout passes the raw page pool with ids
resolved through each row's page table (slab granularity = page size).
Each selected slab also carries its absolute start position
(`blk_pos`), since pool ids do not encode sequence position.

Grid: (B, K). Selection slots past a row's live count are dead:
`pl.when` skips the whole MXU body AND the index_map clamps the slab
id to the last live block, so dead slots re-request an already-resident
slab instead of moving new bytes (the DMA-skip idiom, same as the
sparse-FFN kernels). GQA is computed grouped per program over all
heads, like kernels/paged_attention.

VMEM working set per step: q (1, N, H, dh), one K + one V slab
(1, blk, Kv, dh), scratch m/l (H, N) + acc (H, N, dh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _bsa_kernel(ids_ref, bpos_ref, cnt_ref, p0_ref, len_ref, q_ref,
                k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                kv_heads, scale, window):
    b = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [N, H, dh]
        N, H, dh = q.shape
        rep = H // kv_heads
        blk = k_ref.shape[1]
        qg = q.reshape(N, kv_heads, rep, dh)
        kb = k_ref[0].astype(jnp.float32)                 # [blk, Kv, dh]
        s = jnp.einsum("ngrd,tgd->grnt", qg, kb)          # [Kv,rep,N,blk]
        kpos = bpos_ref[b, k] + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, N, blk), 3)
        qpos = p0_ref[b] + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, N, blk), 2)
        mask = (kpos <= qpos) & (kpos < len_ref[b])
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # [H, N]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1).reshape(H, N)
        m_new = jnp.maximum(m_prev, m_cur)
        # the where-guard keeps fully-masked rows exact: without it a
        # row whose every key in this slab is masked while the running
        # max is still NEG_INF would compute exp(NEG_INF - NEG_INF)=1
        p = jnp.where(mask,
                      jnp.exp(s - m_new.reshape(kv_heads, rep, N)[..., None]),
                      0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1).reshape(H, N)
        v = v_ref[0].astype(jnp.float32)                  # [blk, Kv, dh]
        pv = jnp.einsum("grnt,tgd->grnd", p, v).reshape(H, N, dh)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv
        m_scr[...] = m_new

    # selection slots past this row's live count are dead grid steps:
    # no MXU work, and the index_map already clamped their slab DMA
    pl.when(k < cnt_ref[b])(compute)

    @pl.when(k == pl.num_programs(1) - 1)
    def _finish():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = o.transpose(1, 0, 2).astype(o_ref.dtype)  # [N, H, dh]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def block_sparse_prefill(q, kb, vb, pool_ids, blk_pos, counts, pos0s,
                         lengths, *, window: int | None = None,
                         interpret: bool = False):
    """q: [B, N, H, dh] (RoPE applied); kb/vb: [P, blk, Kv, dh] pooled
    K/V slabs; pool_ids: [B, K] int32 slab ids into the pool (live
    prefix first); blk_pos: [B, K] int32 absolute start position of
    each selected slab; counts: [B] int32 live selection slots;
    pos0s: [B] int32 query-block offsets; lengths: [B] int32 valid key
    counts. Returns [B, N, H, dh] float32."""
    B, N, H, dh = q.shape
    _, blk, Kv, _ = kb.shape
    K = pool_ids.shape[1]
    assert H % Kv == 0

    def clamp(ids, cnt, kk):
        return ids[jnp.minimum(kk, jnp.maximum(cnt - 1, 0))]

    grid = (B, K)
    kernel = pl.pallas_call(
        functools.partial(_bsa_kernel, kv_heads=Kv,
                          scale=1.0 / (dh ** 0.5), window=window),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, N, H, dh),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (b, 0, 0, 0)),
                pl.BlockSpec((1, blk, Kv, dh),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (clamp(ids[b], cnt[b], k), 0, 0, 0)),
                pl.BlockSpec((1, blk, Kv, dh),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (clamp(ids[b], cnt[b], k), 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, N, H, dh),
                                   lambda b, k, ids, bp, cnt, p0, ln:
                                   (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, N), jnp.float32),
                pltpu.VMEM((H, N), jnp.float32),
                pltpu.VMEM((H, N, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, N, H, dh), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(jnp.asarray(pool_ids, jnp.int32),
                  jnp.asarray(blk_pos, jnp.int32),
                  jnp.asarray(counts, jnp.int32),
                  jnp.asarray(pos0s, jnp.int32),
                  jnp.asarray(lengths, jnp.int32), q, kb, vb)


def _bsa_kernel_quant(ids_ref, bpos_ref, cnt_ref, p0_ref, len_ref, q_ref,
                      k_ref, ks_ref, v_ref, vs_ref, o_ref, m_scr, l_scr,
                      acc_scr, *, kv_heads, scale, window):
    """Int8 twin of _bsa_kernel: the K/V slabs arrive as int8 pages plus
    per-(page, kv-head) f32 scales (kernels/kv_quant scheme), dequantized
    in VMEM right before the MXU contractions — the quantized-heap
    PREFILL path never materializes an f32 page in HBM."""
    b = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale          # [N, H, dh]
        N, H, dh = q.shape
        rep = H // kv_heads
        blk = k_ref.shape[1]
        qg = q.reshape(N, kv_heads, rep, dh)
        kb = (k_ref[0].astype(jnp.float32)
              * ks_ref[0][None, :, None])                 # [blk, Kv, dh]
        s = jnp.einsum("ngrd,tgd->grnt", qg, kb)          # [Kv,rep,N,blk]
        kpos = bpos_ref[b, k] + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, N, blk), 3)
        qpos = p0_ref[b] + jax.lax.broadcasted_iota(
            jnp.int32, (kv_heads, rep, N, blk), 2)
        mask = (kpos <= qpos) & (kpos < len_ref[b])
        if window:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # [H, N]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1).reshape(H, N)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask,
                      jnp.exp(s - m_new.reshape(kv_heads, rep, N)[..., None]),
                      0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1).reshape(H, N)
        v = (v_ref[0].astype(jnp.float32)
             * vs_ref[0][None, :, None])                  # [blk, Kv, dh]
        pv = jnp.einsum("grnt,tgd->grnd", p, v).reshape(H, N, dh)
        acc_scr[...] = acc_scr[...] * corr[..., None] + pv
        m_scr[...] = m_new

    pl.when(k < cnt_ref[b])(compute)

    @pl.when(k == pl.num_programs(1) - 1)
    def _finish():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = o.transpose(1, 0, 2).astype(o_ref.dtype)  # [N, H, dh]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def block_sparse_prefill_quant(q, kb, ks, vb, vs, pool_ids, blk_pos,
                               counts, pos0s, lengths, *,
                               window: int | None = None,
                               interpret: bool = False):
    """Quantized-heap twin of block_sparse_prefill: kb/vb are int8
    [P, blk, Kv, dh] pooled slabs with f32 scales ks/vs [P, Kv]
    (kernels/kv_quant scheme, slab granularity = page size). The scale
    slabs ride the SAME clamped index map as their pages, so dead
    selection slots elide the scale DMA together with the page DMA."""
    B, N, H, dh = q.shape
    P, blk, Kv, _ = kb.shape
    K = pool_ids.shape[1]
    assert H % Kv == 0
    assert ks.shape == vs.shape == (P, Kv)

    def clamp(ids, cnt, kk):
        return ids[jnp.minimum(kk, jnp.maximum(cnt - 1, 0))]

    grid = (B, K)
    kernel = pl.pallas_call(
        functools.partial(_bsa_kernel_quant, kv_heads=Kv,
                          scale=1.0 / (dh ** 0.5), window=window),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, N, H, dh),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (b, 0, 0, 0)),
                pl.BlockSpec((1, blk, Kv, dh),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (clamp(ids[b], cnt[b], k), 0, 0, 0)),
                pl.BlockSpec((1, Kv),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (clamp(ids[b], cnt[b], k), 0)),
                pl.BlockSpec((1, blk, Kv, dh),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (clamp(ids[b], cnt[b], k), 0, 0, 0)),
                pl.BlockSpec((1, Kv),
                             lambda b, k, ids, bp, cnt, p0, ln:
                             (clamp(ids[b], cnt[b], k), 0)),
            ],
            out_specs=pl.BlockSpec((1, N, H, dh),
                                   lambda b, k, ids, bp, cnt, p0, ln:
                                   (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, N), jnp.float32),
                pltpu.VMEM((H, N), jnp.float32),
                pltpu.VMEM((H, N, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, N, H, dh), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(jnp.asarray(pool_ids, jnp.int32),
                  jnp.asarray(blk_pos, jnp.int32),
                  jnp.asarray(counts, jnp.int32),
                  jnp.asarray(pos0s, jnp.int32),
                  jnp.asarray(lengths, jnp.int32), q, kb, ks, vb, vs)
