"""Reference oracles for the block-sparse prefill-attention kernel.

Three oracles, each carrying a different half of the correctness
contract (mirrors kernels/paged_attention/ref.py):

  * ``block_sparse_attention_masked`` — the XLA SERVING path: the
    live block selection becomes a key-position membership mask ANDed
    into the exact causal/window/length mask `attend_block_rows`
    builds, feeding the same masked GQA core. At full budget the
    membership mask keeps every causally-valid position, so the output
    is BIT-identical to the dense path.
  * ``block_sparse_attention_twin`` — the masked-gather twin of the
    Pallas kernel: walks the same scalar selection in the same order
    with the same online-softmax recurrence (same op shapes, same
    where-guards), so interpret-mode kernel output must match it
    BITWISE. This is the FLOP-scaling XLA form: it only ever touches
    the selected slabs.
  * ``dense_oracle`` — structurally independent dense attention (plain
    softmax over the full cache, no shared helpers): the ground truth
    the full-budget checks allclose against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import attention as A

NEG_INF = -1e30


def selected_pos_mask(ids, counts, n_blocks: int, blk: int, n_keys: int):
    """Live block selection -> [B, n_keys] per-key-position membership.

    ids: [B, K] block indices; counts: [B] live slots (the first
    counts[b] slots of row b are its kept blocks). Key position s
    belongs to block s // blk."""
    live = jnp.arange(ids.shape[1])[None, :] < counts[:, None]
    hit = (ids[:, :, None] == jnp.arange(n_blocks)[None, None, :]) & \
        live[:, :, None]
    member = jnp.any(hit, axis=1)                         # [B, n_blocks]
    return jnp.repeat(member, blk, axis=1)[:, :n_keys]    # [B, n_keys]


def block_sparse_attention_masked(q, k_cache, v_cache, ids, counts,
                                  pos0s, lengths, *, blk: int,
                                  window=None):
    """Serving XLA path. q: [B, N, H, dh] (RoPE applied); k/v_cache:
    [B, S, Kv, dh]; ids: [B, K] block indices; counts: [B]; pos0s: [B];
    lengths: [B]. Returns [B, N, H, dh] in v_cache dtype (the masked
    GQA core's output dtype — identical to `attend_block_rows`)."""
    B, N = q.shape[:2]
    S = k_cache.shape[1]
    nc = -(-S // blk)
    positions = pos0s[:, None] + jnp.arange(N)[None, :]
    kj = jnp.arange(S)[None, None, :]
    valid = kj <= positions[:, :, None]
    if window:
        valid = valid & (kj > positions[:, :, None] - window)
    valid = valid & (kj < lengths[:, None, None])
    member = selected_pos_mask(ids, counts, nc, blk, S)
    valid = valid & member[:, None, :]
    return A.dot_attention(q, k_cache, v_cache, valid[:, None, None])


def block_sparse_attention_twin(q, kb, vb, pool_ids, blk_pos, counts,
                                pos0s, lengths, *, window=None):
    """Online-softmax gather twin of the kernel — same operands as
    kernel.block_sparse_prefill, bit-identical math: a scan over the K
    selection slots replicating the kernel recurrence (grouped-GQA
    einsums, masked where-guarded exp, dead-slot carry passthrough).
    Returns [B, N, H, dh] float32."""
    B, N, H, dh = q.shape
    blk, Kv = kb.shape[1], kb.shape[2]
    rep = H // Kv
    K = pool_ids.shape[1]
    scale = 1.0 / (dh ** 0.5)

    def one_row(qr, ids_r, bpos_r, cnt, pos0, length):
        qg = (qr.astype(jnp.float32) * scale).reshape(N, Kv, rep, dh)
        qpos = pos0 + jax.lax.broadcasted_iota(
            jnp.int32, (Kv, rep, N, blk), 2)

        def step(carry, inp):
            m_prev, l_prev, acc = carry
            slot, pid, bp0 = inp
            ks = kb[pid].astype(jnp.float32)              # [blk, Kv, dh]
            s = jnp.einsum("ngrd,tgd->grnt", qg, ks)
            kpos = bp0 + jax.lax.broadcasted_iota(
                jnp.int32, (Kv, rep, N, blk), 3)
            mask = (kpos <= qpos) & (kpos < length)
            if window:
                mask = mask & (kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)
            m_cur = jnp.max(s, axis=-1).reshape(H, N)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.where(
                mask,
                jnp.exp(s - m_new.reshape(Kv, rep, N)[..., None]), 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1).reshape(H, N)
            vs = vb[pid].astype(jnp.float32)
            pv = jnp.einsum("grnt,tgd->grnd", p, vs).reshape(H, N, dh)
            acc_new = acc * corr[..., None] + pv
            live = slot < cnt
            return (jnp.where(live, m_new, m_prev),
                    jnp.where(live, l_new, l_prev),
                    jnp.where(live, acc_new, acc)), None

        m0 = jnp.full((H, N), NEG_INF, jnp.float32)
        l0 = jnp.zeros((H, N), jnp.float32)
        a0 = jnp.zeros((H, N, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (jnp.arange(K), ids_r, bpos_r))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(1, 0, 2)                       # [N, H, dh]

    return jax.vmap(one_row)(q, pool_ids, blk_pos, counts, pos0s,
                             lengths)


def dense_oracle(q, k_cache, v_cache, pos0s, lengths, *, window=None):
    """Independent dense causal attention over the full cache (plain
    softmax, repeated-head GQA — no shared helpers with the paths under
    test). Returns [B, N, H, dh] float32."""
    B, N, H, dh = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Kv
    kf = jnp.repeat(k_cache.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v_cache.astype(jnp.float32), rep, axis=2)
    s = jnp.einsum("bnhd,bshd->bhns", q.astype(jnp.float32), kf)
    s = s / (dh ** 0.5)
    qpos = pos0s[:, None] + jnp.arange(N)[None, :]        # [B, N]
    kj = jnp.arange(S)[None, None, :]
    mask = (kj <= qpos[:, :, None]) & (kj < lengths[:, None, None])
    if window:
        mask = mask & (kj > qpos[:, :, None] - window)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhns,bshd->bnhd", p, vf)
