"""Selection math + backend dispatch for block-sparse prefill attention.

This is where the pooled-QK scoring proxy lives (the DESIGN note in
core/fastforward.py documents the semantics): `select_kv_blocks` turns
one query block + the pooled per-KV-block key means into a per-row
block selection (ids + live counts) under a SparsityPlan attention
budget, and the `block_sparse_prefill_op` twins dispatch it:

  * TPU  -> Pallas kernel (kernels/block_sparse_attention/kernel.py):
            scalar-prefetched slab ids, one K/V slab DMA per live
            selection slot, online softmax — FLOPs AND bytes scale
            with the budget. The paged twin resolves slab ids through
            the page table (slab granularity = page size) so the
            kernel reads the raw page pool: this is the paged PREFILL
            kernel the gather path previously stood in for.
  * XLA  -> ref.block_sparse_attention_masked — the selection as a
            membership mask over the full cache view, feeding the
            exact masked GQA core `attend_block_rows` uses, so the
            CPU serving path stays bit-identical to dense at full
            budget.
  * ``use_kernel=True`` off-TPU forces the interpret-mode kernel
    (tests cross-check it against the twin + dense oracle in ref.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention import kernel as K
from repro.kernels.block_sparse_attention import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------- pooled K means


def pooled_block_keys(k_cache, blk: int):
    """[B, S, Kv, dh] -> [B, nc, Kv, dh] per-KV-block key means
    (nc = ceil(S / blk); the tail block zero-pads). Scoring-only: the
    attention masks, not the pooling, carry correctness."""
    B, S, Kv, dh = k_cache.shape
    nc = -(-S // blk)
    pad = nc * blk - S
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k_cache.reshape(B, nc, blk, Kv, dh).mean(axis=2)


def pooled_block_keys_paged(k_pages, page_table, blk: int):
    """Paged twin: per-page means gathered through the table, then
    averaged page-groups per attention block (psz | blk, so a block's
    mean is the equal-weight mean of its pages' means). Accepts the
    int8-quantized heap ({"q": int8 pages, "s": f32 [n_pages, Kv]},
    kernels/kv_quant): the mean distributes over the per-page scale, so
    pooling the int8 values and scaling once is exact."""
    if isinstance(k_pages, dict):
        q, s = k_pages["q"], k_pages["s"]
        psz = q.shape[1]
        assert blk % psz == 0
        ppb = blk // psz
        page_means = q.astype(jnp.float32).mean(axis=1) * s[:, :, None]
        per_row = page_means[page_table]              # [B, mp, Kv, dh]
        B, mp = page_table.shape
        nc = -(-mp // ppb)
        pad = nc * ppb - mp
        if pad:
            per_row = jnp.pad(per_row,
                              ((0, 0), (0, pad), (0, 0), (0, 0)))
        return per_row.reshape((B, nc, ppb)
                               + per_row.shape[2:]).mean(axis=2)
    psz = k_pages.shape[1]
    assert blk % psz == 0
    ppb = blk // psz
    page_means = k_pages.mean(axis=1)                 # [n_pages, Kv, dh]
    per_row = page_means[page_table]                  # [B, mp, Kv, dh]
    B, mp = page_table.shape
    nc = -(-mp // ppb)
    pad = nc * ppb - mp
    if pad:
        per_row = jnp.pad(per_row, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return per_row.reshape((B, nc, ppb) + per_row.shape[2:]).mean(axis=2)


# ----------------------------------------------------------- selection


def select_kv_blocks(q, block_keys, pos0s, lengths, *, blk: int,
                     k_sel: int, attn_tiles: int, a_l, window=None,
                     threshold=None):
    """Pooled-QK proxy selection for one query block.

    q: [B, N, H, dh] (RoPE applied); block_keys: [B, nc, Kv, dh] pooled
    per-block key means; pos0s/lengths: [B] int32; k_sel: STATIC
    selection width (top-k size); attn_tiles: STATIC virtual budget
    grid; a_l: this layer's budget count in virtual-grid units (traced
    int32 scalar riding the layer scan, or a python int).

    Returns (ids [B, k_sel] int32, counts [B] int32): the first
    counts[b] slots of row b are its kept block indices in ASCENDING
    position order (so full-budget masked attention visits keys in
    dense order), the tail slots are don't-care ids the kernel skips.

    Selection is top-k on the proxy scores; the kept count is the
    budget fraction scaled onto the row's causally-valid block count
    nv: c = clip(ceil(a_l * nv / attn_tiles), min(2, nv), min(nv,
    k_sel)). The sink block 0 and the diagonal (current) block are
    force-included via score bias.

    threshold: opt-in FlashPrefill-style ADAPTIVE counts — keep the
    fewest top-scored blocks whose softmax mass over the k_sel
    candidates reaches `threshold` (computed on the RAW valid-masked
    proxy scores, before sink/diagonal forcing, which would saturate a
    softmax). The per-input count is CAPPED by the plan's budget count
    (the c above) — the budget stays the worst case, easy inputs spend
    less. threshold=1.0 keeps every candidate (the `1 +` below absorbs
    fp cumsum undershoot), so a full budget stays bit-identical to
    dense. None (default) = fixed budget counts, the pre-existing
    behavior."""
    B, N, H, dh = q.shape
    nc = block_keys.shape[1]
    Kv = block_keys.shape[2]
    rep = H // Kv
    k_sel = min(k_sel, nc)
    # pooled query: mean over the block's rows and the GQA head group
    qp = q.astype(jnp.float32).reshape(B, N, Kv, rep, dh).mean(
        axis=(1, 3))                                      # [B, Kv, dh]
    scores = jnp.einsum("bgd,bcgd->bc", qp,
                        block_keys.astype(jnp.float32))
    scores = scores / (Kv * (dh ** 0.5))                  # [B, nc]

    cur = (pos0s + N - 1) // blk                          # [B]
    bidx = jnp.arange(nc)[None, :]
    valid = bidx <= cur[:, None]
    if window:
        valid = valid & ((bidx + 1) * blk - 1 > pos0s[:, None] - window)
    big = jnp.float32(1e30)
    raw = jnp.where(valid, scores, -big)                  # pre-forcing
    scores = raw
    forced = (bidx == 0) | (bidx == cur[:, None])
    scores = jnp.where(forced & valid, big, scores)

    _, top_idx = jax.lax.top_k(scores, k_sel)             # [B, k_sel]
    nv = cur + 1
    a = jnp.asarray(a_l, jnp.int32)
    c = (a * nv + attn_tiles - 1) // attn_tiles
    c = jnp.clip(c, jnp.minimum(2, nv), jnp.minimum(nv, k_sel))
    if threshold is not None:
        # softmax mass of the candidates' RAW scores, best-first:
        # c_adaptive = smallest count whose inclusive mass reaches the
        # threshold. Invalid candidates carry exp(-inf) = 0 mass.
        top_raw = jnp.sort(
            jnp.take_along_axis(raw, top_idx, axis=-1), axis=-1)[:, ::-1]
        vmask = top_raw > -big / 2
        # floor valid weights above 0 so an extreme score gap cannot
        # underflow a candidate out of the mass entirely: at
        # threshold=1.0 the inclusive mass stays < 1.0 until the LAST
        # valid candidate, so every candidate is kept (dense at full
        # budget stays bit-identical)
        w = jnp.where(vmask,
                      jnp.maximum(jnp.exp(top_raw - top_raw[:, :1]),
                                  1e-30), 0.0)
        mass = jnp.cumsum(w, axis=-1) / jnp.maximum(
            jnp.sum(w, axis=-1, keepdims=True), 1e-30)
        thr = jnp.asarray(threshold, jnp.float32)
        c_adaptive = 1 + jnp.sum(mass < thr, axis=-1).astype(jnp.int32)
        c = jnp.minimum(c, jnp.clip(c_adaptive, jnp.minimum(2, nv),
                                    jnp.minimum(nv, k_sel)))
    # live prefix in ascending block order; dead slots keyed past nc so
    # a stable argsort pushes them to the tail
    slot = jnp.arange(k_sel)[None, :]
    sort_key = jnp.where(slot < c[:, None], top_idx, nc + slot)
    order = jnp.argsort(sort_key, axis=-1)
    ids = jnp.take_along_axis(top_idx, order, axis=-1)
    return ids.astype(jnp.int32), c.astype(jnp.int32)


# ------------------------------------------------------------ dispatch


def block_sparse_prefill_op(q, k_cache, v_cache, ids, counts, pos0s,
                            lengths, *, blk: int, window=None,
                            use_kernel: bool | None = None):
    """Slot-layout block-sparse prefill attention. q: [B, N, H, dh]
    (RoPE applied); k/v_cache: [B, S, Kv, dh]; ids/counts from
    `select_kv_blocks`. Returns [B, N, H, dh]."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if not use_kernel:
        return R.block_sparse_attention_masked(
            q, k_cache, v_cache, ids, counts, pos0s, lengths, blk=blk,
            window=window)
    B, S, Kv, dh = k_cache.shape
    pad = (-S) % blk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // blk
    kb = k_cache.reshape(B * nc, blk, Kv, dh)
    vb = v_cache.reshape(B * nc, blk, Kv, dh)
    pool_ids = ids + nc * jnp.arange(B, dtype=jnp.int32)[:, None]
    blk_pos = ids * blk
    return K.block_sparse_prefill(q, kb, vb, pool_ids, blk_pos, counts,
                                  pos0s, lengths, window=window,
                                  interpret=not on_tpu())


def block_sparse_prefill_paged_op(q, k_pages, v_pages, page_table, ids,
                                  counts, pos0s, lengths, *, blk: int,
                                  window=None,
                                  use_kernel: bool | None = None):
    """Paged twin: the kernel reads the RAW page pool through slab ids
    resolved from each row's page table (slab granularity = page
    size) — the paged PREFILL kernel. The XLA branch gathers the
    table-mapped contiguous view (positions == absolute positions) and
    reuses the slot masked path. Accepts the int8-quantized heap
    ({"q", "s"} dicts, kernels/kv_quant): the XLA branch dequantizes
    the gathered view on the fly, the kernel branch dispatches the
    fused-dequant quant kernel with the scale slabs riding the same
    table-resolved pool ids."""
    if use_kernel is None:
        use_kernel = on_tpu()
    quant = isinstance(k_pages, dict)
    if not use_kernel:
        B, mp = page_table.shape
        flat = page_table.reshape(-1)
        if quant:
            psz = k_pages["q"].shape[1]
            kc = (jnp.take(k_pages["q"], flat, axis=0)
                  .astype(jnp.float32)
                  * jnp.take(k_pages["s"], flat,
                             axis=0)[:, None, :, None])
            vc = (jnp.take(v_pages["q"], flat, axis=0)
                  .astype(jnp.float32)
                  * jnp.take(v_pages["s"], flat,
                             axis=0)[:, None, :, None])
            tail = kc.shape[2:]
        else:
            psz = k_pages.shape[1]
            kc = jnp.take(k_pages, flat, axis=0)
            vc = jnp.take(v_pages, flat, axis=0)
            tail = k_pages.shape[2:]
        kc = kc.reshape((B, mp * psz) + tail)
        vc = vc.reshape((B, mp * psz) + tail)
        return R.block_sparse_attention_masked(
            q, kc, vc, ids, counts, pos0s, lengths, blk=blk,
            window=window)
    psz = (k_pages["q"] if quant else k_pages).shape[1]
    assert blk % psz == 0
    ppb = blk // psz
    B, n_sel = ids.shape
    # selected block j -> its ppb table entries [j*ppb, (j+1)*ppb)
    tpos = ids[:, :, None] * ppb + jnp.arange(ppb)[None, None, :]
    tpos = tpos.reshape(B, n_sel * ppb)
    tpos = jnp.minimum(tpos, page_table.shape[1] - 1)
    pool_ids = jnp.take_along_axis(page_table, tpos, axis=1)
    blk_pos = (ids[:, :, None] * blk
               + jnp.arange(ppb)[None, None, :] * psz).reshape(B, -1)
    if quant:
        return K.block_sparse_prefill_quant(
            q, k_pages["q"], k_pages["s"], v_pages["q"], v_pages["s"],
            pool_ids, blk_pos, counts * ppb, pos0s, lengths,
            window=window, interpret=not on_tpu())
    return K.block_sparse_prefill(q, k_pages, v_pages, pool_ids,
                                  blk_pos, counts * ppb, pos0s, lengths,
                                  window=window,
                                  interpret=not on_tpu())
