"""Pallas TPU kernel: block-sparse gated FFN with scalar-prefetch tiles.

TPU adaptation of FastForward's CUDA row-gather (DESIGN.md §3): the
selected neuron-tile indices are scalar-prefetched; each grid step DMAs
one [D, tile] slab of W_gate/W_up and one [tile, D] slab of W_down from
HBM into VMEM (BlockSpec.index_map redirects by tile id), computes the
gated product for the token block on the MXU, and accumulates into a
single VMEM output block. FLOPs scale exactly with K/n_tiles.

Grid: (num_token_blocks, K). VMEM working set per step:
  x block   [bn, D]      (bn = token block rows, default 128)
  wg, wu    [D, tile]
  wd        [tile, D]
  out       [bn, D] (accumulator, revisited across the K axis)
All MXU dims are multiples of 128 when D and tile are.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _live(ids, cnt, k):
    """Index-map helper: clamp dead grid steps (k >= cnt) to the last
    live tile id so they re-request an already-resident slab — Pallas
    elides the repeat fetch, making dead steps DMA-free as well as
    (via pl.when) MXU-free. ids: [K], cnt: scalar, k: grid index."""
    return ids[jnp.minimum(k, jnp.maximum(cnt - 1, 0))]


def _sparse_ffn_kernel(ids_ref, cnt_ref, x_ref, wg_ref, wu_ref, wd_ref,
                       o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # SparsityPlan per-layer counts: tiles past this layer's count are
    # dead grid steps — the MXU body is skipped, and the index_map
    # clamps their slab requests to the last LIVE tile, so Pallas's
    # revisit-elision sees an unchanged block and moves no bytes
    @pl.when(k < cnt_ref[0])
    def _step():
        x = x_ref[...].astype(jnp.float32)
        hg = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        hu = jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        h = hg * jax.nn.sigmoid(hg) * hu
        y = jax.lax.dot(h, wd_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        o_ref[...] += y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "block_n", "interpret"))
def sparse_ffn(x, wg, wu, wd, tile_ids, k_valid=None, *, tile: int = 128,
               block_n: int = 128, interpret: bool = False):
    """x: [N, D]; wg/wu: [D, F]; wd: [F, D]; tile_ids: [K] int32 (global
    tile ids). Returns [N, D] float32. N % block_n == 0, F % tile == 0.

    k_valid: optional traced int32 scalar — only the first k_valid of
    the K selected tiles are computed (grid steps past it are
    `pl.when`-skipped). None keeps all K (uniform plans)."""
    N, D = x.shape
    F = wg.shape[1]
    K = tile_ids.shape[0]
    assert N % block_n == 0 and F % tile == 0
    cnt = (jnp.full((1,), K, jnp.int32) if k_valid is None
           else jnp.reshape(jnp.asarray(k_valid, jnp.int32), (1,)))

    grid = (N // block_n, K)

    kernel = pl.pallas_call(
        _sparse_ffn_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, D), lambda n, k, ids, cnt: (n, 0)),
                # dead steps (k >= cnt) clamp to the last live tile id:
                # the revisited slab is already resident, no DMA issued
                pl.BlockSpec((D, tile),
                             lambda n, k, ids, cnt: (0, _live(ids, cnt[0], k))),
                pl.BlockSpec((D, tile),
                             lambda n, k, ids, cnt: (0, _live(ids, cnt[0], k))),
                pl.BlockSpec((tile, D),
                             lambda n, k, ids, cnt: (_live(ids, cnt[0], k), 0)),
            ],
            out_specs=pl.BlockSpec((block_n, D),
                                   lambda n, k, ids, cnt: (n, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(tile_ids, cnt, x, wg, wu, wd)


def _sparse_ffn_batched_kernel(ids_ref, cnt_ref, x_ref, wg_ref, wu_ref,
                               wd_ref, o_ref):
    b = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # per-ROW valid counts (SparsityPlan layer counts during prefill,
    # per-request effort tiers at decode): row b's tiles past
    # cnt_ref[b] are dead grid steps — the MXU body is skipped and the
    # index_map pins their slab requests to row b's last live tile, so
    # the dead steps DMA nothing new
    @pl.when(k < cnt_ref[b])
    def _step():
        x = x_ref[0].astype(jnp.float32)
        hg = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        hu = jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        h = hg * jax.nn.sigmoid(hg) * hu
        y = jax.lax.dot(h, wd_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        o_ref[0] += y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "block_n", "interpret"))
def sparse_ffn_batched(x, wg, wu, wd, tile_ids, k_valid=None, *,
                       tile: int = 128, block_n: int = 128,
                       interpret: bool = False):
    """Batched twin of `sparse_ffn` for multi-request prefill: every
    batch row selects its OWN K weight tiles.

    x: [B, N, D]; wg/wu: [D, F]; wd: [F, D]; tile_ids: [B, K] int32
    (global tile ids, per row). Returns [B, N, D] float32.

    Grid (B, N//block_n, K): the whole [B, K] id matrix is scalar-
    prefetched, and each grid step's BlockSpec index_map reads
    ids[b, k] — so the W_gate/W_up/W_down slab DMAs are redirected per
    batch row, exactly the serving layout where the scheduler packs one
    128-token block of B distinct requests into one jitted call.

    k_valid: optional traced [B] int32 per-row valid tile counts — row
    b's grid steps with k >= k_valid[b] skip the MXU body (`pl.when`),
    so a layer-wise SparsityPlan's cheap layers and low-effort requests
    spend FLOPs proportional to their OWN counts while K stays static.
    None keeps all K tiles for every row (uniform plans).
    """
    B, N, D = x.shape
    F = wg.shape[1]
    K = tile_ids.shape[1]
    assert tile_ids.shape[0] == B
    assert N % block_n == 0 and F % tile == 0
    cnt = (jnp.full((B,), K, jnp.int32) if k_valid is None
           else jnp.broadcast_to(jnp.asarray(k_valid, jnp.int32), (B,)))

    grid = (B, N // block_n, K)

    kernel = pl.pallas_call(
        _sparse_ffn_batched_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_n, D),
                             lambda b, n, k, ids, cnt: (b, n, 0)),
                # dead steps clamp to row b's last live tile id — the
                # revisited slab is already resident, no DMA issued
                pl.BlockSpec((D, tile),
                             lambda b, n, k, ids, cnt:
                             (0, _live(ids[b], cnt[b], k))),
                pl.BlockSpec((D, tile),
                             lambda b, n, k, ids, cnt:
                             (0, _live(ids[b], cnt[b], k))),
                pl.BlockSpec((tile, D),
                             lambda b, n, k, ids, cnt:
                             (_live(ids[b], cnt[b], k), 0)),
            ],
            out_specs=pl.BlockSpec((1, block_n, D),
                                   lambda b, n, k, ids, cnt: (b, n, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, N, D), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(tile_ids, cnt, x, wg, wu, wd)


def _dense_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    hg = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    hu = jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    h = hg * jax.nn.sigmoid(hg) * hu
    o_ref[...] += jax.lax.dot(h, wd_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile", "block_n", "interpret"))
def dense_ffn(x, wg, wu, wd, *, tile: int = 512, block_n: int = 128,
              interpret: bool = False):
    """Dense gated-FFN twin of the sparse kernel (the paper's baseline);
    walks ALL F/tile tiles instead of a selected subset."""
    N, D = x.shape
    F = wg.shape[1]
    assert N % block_n == 0 and F % tile == 0
    grid = (N // block_n, F // tile)
    kernel = pl.pallas_call(
        _dense_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, D), lambda n, f: (n, 0)),
            pl.BlockSpec((D, tile), lambda n, f: (0, f)),
            pl.BlockSpec((D, tile), lambda n, f: (0, f)),
            pl.BlockSpec((tile, D), lambda n, f: (f, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, D), lambda n, f: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )
    return kernel(x, wg, wu, wd)
