"""Jit'd public wrappers around the sparse-FFN Pallas kernels.

Backend dispatch rule (the serving hot path relies on this):

  * TPU  -> Pallas kernels (Mosaic): `sparse_ffn` for a single [N, D]
           block, `sparse_ffn_batched` for the continuous-batching
           scheduler's [B, N, D] multi-request prefill batch (per-row
           scalar-prefetched tile ids, grid (B, n_token_blocks, K));
  * CPU  -> XLA gather path (ref oracles) — interpret-mode Pallas is
           orders of magnitude slower than XLA on host, so it is only
           used for validation (`use_kernel=True` off-TPU forces the
           interpret-mode kernel; tests cross-check it against the
           gather path).

`repro.core.sparse_ffn.ffn_sparse_batched` routes the models' gated
FFN through `sparse_ffn_batched_op`, so every model family hits the
kernel on TPU without touching model code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sparse_ffn import kernel as K
from repro.kernels.sparse_ffn import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _block_n_for(N: int) -> int:
    return N if N < 128 else 128


def sparse_ffn_op(x, wg, wu, wd, tile_ids, tile: int = 128,
                  use_kernel: bool | None = None, k_valid=None):
    """Dispatch: Pallas kernel on TPU, interpret-mode kernel if forced,
    jnp oracle otherwise. x: [N, D] or [B, N, D] (batched kernel).
    k_valid: optional traced valid-tile count (scalar for [N, D], [B]
    for batched) — a SparsityPlan's per-layer/per-row counts; the
    kernel `pl.when`-skips dead tiles, the oracle masks them."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if x.ndim == 3:
        return sparse_ffn_batched_op(x, wg, wu, wd, tile_ids, tile=tile,
                                     use_kernel=use_kernel,
                                     k_valid=k_valid)
    if use_kernel:
        interp = not on_tpu()
        return K.sparse_ffn(x, wg, wu, wd, tile_ids, k_valid, tile=tile,
                            block_n=_block_n_for(x.shape[0]),
                            interpret=interp)
    return R.sparse_ffn_ref(x, wg, wu, wd, tile_ids, tile,
                            k_valid=k_valid)


def sparse_ffn_batched_op(x, wg, wu, wd, tile_ids, tile: int = 128,
                          use_kernel: bool | None = None, k_valid=None):
    """Batched multi-request dispatch: x [B, N, D], tile_ids [B, K]
    (every row selects its own tiles) -> [B, N, D] float32.

    TPU: one `sparse_ffn_batched` Pallas call over the whole batch (NOT
    a vmap of B single-block kernels — the grid's batch axis keeps one
    kernel launch and lets Mosaic pipeline the per-row weight DMAs).
    CPU: reshape-free XLA gather path. `use_kernel=True` off-TPU runs the
    batched kernel in interpret mode (equivalence cross-check).

    k_valid: optional traced [B] int32 per-row valid tile counts (see
    kernel.sparse_ffn_batched) — the FLOP-reducing carrier of
    SparsityPlan layer counts and per-request effort tiers."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        interp = not on_tpu()
        return K.sparse_ffn_batched(x, wg, wu, wd, tile_ids, k_valid,
                                    tile=tile,
                                    block_n=_block_n_for(x.shape[1]),
                                    interpret=interp)
    return R.sparse_ffn_batched_ref(x, wg, wu, wd, tile_ids, tile,
                                    k_valid=k_valid)


def dense_ffn_op(x, wg, wu, wd, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        return K.dense_ffn(x, wg, wu, wd, interpret=not on_tpu())
    return R.dense_ffn_ref(x, wg, wu, wd)
