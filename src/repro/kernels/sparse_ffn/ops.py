"""Jit'd public wrappers around the sparse-FFN Pallas kernel.

`use_kernel=True` targets TPU (Mosaic); on this CPU container the kernel
runs in interpret mode for validation and the XLA fallback (ref path)
serves execution. The serving engine picks via repro.kernels.backend().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sparse_ffn import kernel as K
from repro.kernels.sparse_ffn import ref as R


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sparse_ffn_op(x, wg, wu, wd, tile_ids, tile: int = 128,
                  use_kernel: bool | None = None):
    """Dispatch: Pallas kernel on TPU, interpret-mode kernel if forced,
    jnp oracle otherwise. x: [N, D] or [B, N, D] (vmapped)."""
    if use_kernel is None:
        use_kernel = on_tpu()
    if x.ndim == 3:
        return jax.vmap(
            lambda xb, ids: sparse_ffn_op(xb, wg, wu, wd, ids, tile,
                                          use_kernel))(x, tile_ids)
    if use_kernel:
        interp = not on_tpu()
        return K.sparse_ffn(x, wg, wu, wd, tile_ids, tile=tile,
                            interpret=interp)
    return R.sparse_ffn_ref(x, wg, wu, wd, tile_ids, tile)


def dense_ffn_op(x, wg, wu, wd, use_kernel: bool | None = None):
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        return K.dense_ffn(x, wg, wu, wd, interpret=not on_tpu())
    return R.dense_ffn_ref(x, wg, wu, wd)
