"""Pure-jnp oracle for the block-sparse gated FFN kernel.

Semantics: given tokens x [N, D], full FFN weights, and a list of
selected neuron-tile ids [K] (tile width = kernel tile size), compute
the gated FFN restricted to the selected tiles:

    y = sum_k  silu(x @ Wg[:, tile_k]) * (x @ Wu[:, tile_k]) @ Wd[tile_k, :]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_ffn_ref(x, wg, wu, wd, tile_ids, tile: int, k_valid=None):
    """x: [N, D]; wg/wu: [D, F]; wd: [F, D]; tile_ids: [K] int32.
    Returns [N, D] in float32. k_valid: optional traced int32 scalar —
    only the first k_valid selected tiles contribute (SparsityPlan
    per-layer counts under a static K)."""
    D, F = wg.shape
    n_tiles = F // tile
    wg_t = wg.reshape(D, n_tiles, tile)
    wu_t = wu.reshape(D, n_tiles, tile)
    wd_t = wd.reshape(n_tiles, tile, D)
    g = jnp.take(wg_t, tile_ids, axis=1).reshape(D, -1)
    u = jnp.take(wu_t, tile_ids, axis=1).reshape(D, -1)
    d = jnp.take(wd_t, tile_ids, axis=0).reshape(-1, D)
    x32 = x.astype(jnp.float32)
    hg = x32 @ g.astype(jnp.float32)
    hu = x32 @ u.astype(jnp.float32)
    h = hg * jax.nn.sigmoid(hg) * hu
    if k_valid is not None:
        K = tile_ids.shape[-1]
        valid = jnp.arange(K) < jnp.asarray(k_valid, jnp.int32)
        h = h * jnp.repeat(valid, tile).astype(h.dtype)[None, :]
    return h @ d.astype(jnp.float32)


def sparse_ffn_batched_ref(x, wg, wu, wd, tile_ids, tile: int,
                           k_valid=None):
    """Batched oracle: x [B, N, D]; tile_ids [B, K] — each row selects
    its own tiles. Returns [B, N, D] float32.

    One take per weight over the whole [B, K] id matrix; the gathered
    tiles stay in [K, tile] layout — the einsums contract over (k, t)
    directly, no [D, K*tile] reshape copies. (Fusing wg|wu into one
    concatenated take materializes the full weights per call — measured
    slower; see repro.core.sparse_ffn.ffn_sparse_gather.)

    k_valid: optional traced [B] int32 — row b consumes only its first
    k_valid[b] selected tiles; the rest are masked out of the hidden
    activations (the XLA twin of the kernel's pl.when skip)."""
    D, F = wg.shape
    n_tiles = F // tile
    g = jnp.take(wg.reshape(D, n_tiles, tile), tile_ids,
                 axis=1).astype(jnp.float32)              # [D, B, K, tile]
    u = jnp.take(wu.reshape(D, n_tiles, tile), tile_ids,
                 axis=1).astype(jnp.float32)
    d = jnp.take(wd.reshape(n_tiles, tile, D), tile_ids,
                 axis=0).astype(jnp.float32)              # [B, K, tile, D]
    x32 = x.astype(jnp.float32)
    hg = jnp.einsum("bnd,dbkt->bnkt", x32, g)
    hu = jnp.einsum("bnd,dbkt->bnkt", x32, u)
    h = hg * jax.nn.sigmoid(hg) * hu
    if k_valid is not None:
        K = tile_ids.shape[-1]
        valid = (jnp.arange(K)[None, :]
                 < jnp.asarray(k_valid, jnp.int32)[:, None])   # [B, K]
        h = h * valid[:, None, :, None].astype(h.dtype)
    return jnp.einsum("bnkt,bktd->bnd", h, d)


def dense_ffn_ref(x, wg, wu, wd):
    """Full (non-sparse) gated FFN oracle, f32 accumulation."""
    x32 = x.astype(jnp.float32)
    hg = x32 @ wg.astype(jnp.float32)
    hu = x32 @ wu.astype(jnp.float32)
    h = hg * jax.nn.sigmoid(hg) * hu
    return h @ wd.astype(jnp.float32)
