"""Deterministic chaos suite: the scheduler survives seed-driven fault
injection (forced preemptions, synthetic pool pressure, slow ticks,
random aborts) on BOTH KV layouts with

  * no slot/page leaks — total_releases == total_acquires, free lists
    whole, page tables zeroed;
  * liveness — every submitted request reaches a terminal status
    (the oldest always progresses, so chaos runs drain);
  * output transparency — surviving requests' greedy outputs are
    bit-identical to the fault-free run;
  * flat compile counts — chaos churn never triggers recompilation;

plus the stall watchdog (a livelocked scheduler raises with a full
state dump instead of spinning) and schedule determinism (same seed
-> same fault schedule -> same outputs and stats)."""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, FaultInjector,
                           Request, SchedulerStallError)
from repro.serving.runtime import make_runtime

PAGE = 8


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def make_prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(n)).tolist() for n in lengths]


def build(dense_setup, kv_layout, faults=None, n_slots=3):
    cfg, params = dense_setup
    cfg = cfg.with_(kv_layout=kv_layout,
                    kv_page_size=PAGE if kv_layout == "paged" else None)
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=n_slots,
                                        cache_len=160, prefill_batch=2,
                                        faults=faults)
    counts0 = sched.warmup()
    return sched, counts0


def submit_all(sched, cfg):
    prompts = make_prompts(cfg, [40, 70, 33, 90, 64, 50, 25, 58])
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=6,
                             eos_id=(3 if i % 3 == 0 else None)))
    return len(prompts)


def assert_pools_whole(sched):
    pool = sched.pool
    assert pool.total_acquires == pool.total_releases, \
        f"slot leak: {pool.total_acquires} acquired, " \
        f"{pool.total_releases} released"
    assert pool.n_free == sched.n_slots
    if sched.paged:
        assert pool.total_page_allocs == pool.total_page_frees, \
            f"page leak: {pool.total_page_allocs} allocated, " \
            f"{pool.total_page_frees} freed"
        assert pool.n_free_pages == pool.n_pages - 1
        assert (pool.page_table == 0).all()
        assert (pool.allocated == 0).all()


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_chaos_run_invariants(dense_setup, kv_layout, seed):
    """Every seeded fault schedule must leave the scheduler's contract
    intact: terminal status for all, no leaks, survivors bit-identical
    to the fault-free run, compile counts flat."""
    cfg, _ = dense_setup
    # fault-free reference
    ref, _ = build(dense_setup, kv_layout)
    n = submit_all(ref, cfg)
    ref_outs = ref.run()
    # chaos run: aggressive probabilities so every fault class gets
    # real airtime within a short stream
    inj = FaultInjector(seed=seed, p_preempt=0.4, p_pressure=0.4,
                        p_slow=0.3, p_abort=0.15, pressure_frac=0.6,
                        pressure_hold_ticks=3, max_aborts=2)
    sched, counts0 = build(dense_setup, kv_layout, faults=inj)
    submit_all(sched, cfg)
    outs = sched.run()
    assert sorted(outs) == list(range(n))          # liveness: all finish
    for rid, out in outs.items():
        assert out.status in ("ok", "cancelled"), (rid, out.status)
        if out.status == "ok":
            # output transparency: preemption/pressure churn never
            # changes what a surviving request generates
            assert out.tokens == ref_outs[rid].tokens, rid
    assert {o.rid for o in outs.values()
            if o.status == "cancelled"} == set(inj.aborted_rids)
    assert_pools_whole(sched)
    assert inj.stats()["outstanding_stolen"] == 0
    counts1 = sched.runtime.compile_counts()
    if None not in counts0.values():
        assert counts1 == counts0, (counts0, counts1)


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_chaos_faults_actually_fire(dense_setup, kv_layout):
    """The invariants above are only meaningful if the injector is
    genuinely perturbing the run — with these probabilities over a
    long stream every fault class must fire at least once."""
    cfg, _ = dense_setup
    inj = FaultInjector(seed=7, p_preempt=0.5, p_pressure=0.5,
                        p_slow=0.5, p_abort=0.2, max_aborts=2)
    sched, _ = build(dense_setup, kv_layout, faults=inj)
    submit_all(sched, cfg)
    sched.run()
    s = inj.stats()
    assert s["forced_preempts"] > 0
    assert s["pressure_events"] > 0
    assert s["slow_ticks"] > 0
    assert s["aborts"] > 0
    assert sched.n_preemptions >= s["forced_preempts"]
    assert sched.n_cancelled == s["aborts"]


def test_chaos_schedule_is_deterministic(dense_setup):
    """Same seed -> bit-identical fault schedule, outputs, and stats
    (a failing chaos run replays exactly)."""
    cfg, _ = dense_setup

    def one(seed):
        inj = FaultInjector(seed=seed, p_preempt=0.4, p_pressure=0.4,
                            p_slow=0.3, p_abort=0.15, max_aborts=2)
        sched, _ = build(dense_setup, "paged", faults=inj)
        submit_all(sched, cfg)
        outs = sched.run()
        return ({r: (o.status, tuple(o.tokens)) for r, o in outs.items()},
                inj.stats())

    outs_a, stats_a = one(11)
    outs_b, stats_b = one(11)
    assert outs_a == outs_b
    assert stats_a == stats_b
    outs_c, stats_c = one(12)              # and the seed actually matters
    assert stats_c != stats_a or outs_c != outs_a


def test_warmup_suspends_fault_injection(dense_setup):
    """Chaos must not perturb compilation: the injector draws nothing
    during warmup, so warmup still pre-compiles every executable and
    the chaos stream starts from a clean, fault-free compile state."""
    inj = FaultInjector(seed=0, p_preempt=1.0, p_pressure=1.0,
                        p_slow=1.0, p_abort=1.0)
    sched, _ = build(dense_setup, "slot", faults=inj)
    assert inj.stats()["forced_preempts"] == 0
    assert inj.stats()["slow_ticks"] == 0
    assert inj.stats()["clock_offset_s"] == 0.0
    assert sched.faults is inj             # re-attached after warmup


def test_stall_watchdog_raises_with_state_dump(dense_setup):
    """A scheduler that can make no progress (here: every slot stolen,
    so admission starves forever) must raise SchedulerStallError with a
    full state dump instead of spinning."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=128,
                                        stall_ticks=8)
    stolen = sched.pool.steal_free_slots(1)
    sched.submit(Request(rid=0, prompt=[1] * 40, max_new=4))
    with pytest.raises(SchedulerStallError) as ei:
        sched.run()
    state = ei.value.state
    assert state["queue"][0]["rid"] == 0
    assert state["pool"]["n_free_slots"] == 0
    assert "no progress" in str(ei.value)
    sched.pool.restore_free_slots(stolen)
    sched.run()                            # unblocked: drains normally
    assert sched.finished[0].status == "ok"


def test_run_max_ticks_raises_with_state_dump(dense_setup):
    """run() exhausting its tick budget is the same loud failure."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=128)
    sched.submit(Request(rid=0, prompt=[1] * 40, max_new=50))
    with pytest.raises(SchedulerStallError) as ei:
        sched.run(max_ticks=3)
    assert ei.value.state["counters"]["finished"] == 0
    assert "not drained" in str(ei.value)
