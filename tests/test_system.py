"""End-to-end behaviour tests: distillation improves fidelity, the
engine serves FastForward-sparsified models, checkpoints round-trip,
and the ablation orderings the paper reports hold qualitatively."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.core import distill as DI
from repro.core import sparse_ffn as S
from repro.serving.engine import Engine
from repro.training.checkpoint import save_checkpoint, load_checkpoint
from repro.data.synthetic import batches


@pytest.fixture(scope="module")
def trained_ffn():
    """A small FFN with tile-structured weights (so flocking exists at
    the kernel's tile granularity) and a distilled predictor+compensator:
    tile t's gate weights respond to input direction t; each block's
    input lives in two of those directions."""
    cfg = get_config("tinyllama-1.1b", reduced=True)
    tile = cfg.ff.tile
    n_tiles = cfg.d_ff // tile
    from repro.core.fastforward import fastforward_ffn_spec
    ffn = init_params(fastforward_ffn_spec(cfg), jax.random.key(0))
    rng = np.random.default_rng(5)
    Q, _ = np.linalg.qr(rng.standard_normal((cfg.d_model, cfg.d_model)))
    basis = Q[:, :n_tiles].T
    wg = np.asarray(ffn["wg"]) * 0.3
    for t in range(n_tiles):
        wg[:, t * tile:(t + 1) * tile] += np.outer(
            basis[t], np.abs(rng.standard_normal(tile)) + 0.5) * 2.0
    ffn = dict(ffn)
    ffn["wg"] = jnp.asarray(wg, jnp.float32)

    def block_gen(seed=3):
        r = np.random.default_rng(seed)
        while True:
            g1 = r.integers(0, n_tiles, size=8)
            g2 = (g1 + 1 + r.integers(0, n_tiles - 1, size=8)) % n_tiles
            amp = 2.0 + r.standard_normal((8, cfg.ff.block_size, 1))
            sig = (basis[g1][:, None, :] + basis[g2][:, None, :]) * amp
            noise = r.standard_normal(
                (8, cfg.ff.block_size, cfg.d_model)) * 0.5
            yield jnp.asarray(sig + noise, jnp.float32)

    tp, hist = DI.train_fastforward_layer(
        ffn, block_gen(), cfg, jax.random.key(1), steps=150, lr=2e-3)
    return cfg, ffn, tp, hist, block_gen


def test_distillation_losses_decrease(trained_ffn):
    cfg, ffn, tp, hist, _ = trained_ffn
    first = np.mean([h["pred_bce"] for h in hist[:10]])
    last = np.mean([h["pred_bce"] for h in hist[-10:]])
    assert last < first, (first, last)
    # compensator: compare within the predicted-mask phase (the phase
    # switch at warmup_frac raises the raw error level by design)
    switch = int(len(hist) * 0.3)
    c_first = np.mean([h["comp_mse"] for h in hist[switch:switch + 10]])
    c_last = np.mean([h["comp_mse"] for h in hist[-10:]])
    assert c_last <= c_first * 1.1


def test_trained_predictor_beats_random(trained_ffn):
    cfg, ffn, tp, _, block_gen = trained_ffn
    gen = block_gen()
    x = next(gen)
    keep = 1.0 - cfg.ff.sparsity
    agree = float(DI.predictor_agreement(tp, ffn, x, keep, cfg.ff.tile))
    assert agree > 0.7, agree   # random selection would land near 0.5


def test_compensator_improves_fidelity(trained_ffn):
    cfg, ffn, tp, _, block_gen = trained_ffn
    from repro.core import compensator as C
    x = next(block_gen())
    keep = 1.0 - cfg.ff.sparsity
    mask = DI.predicted_mask(tp, x, keep, cfg.ff.tile)
    y_dense = S.ffn_dense(ffn, x, cfg.act)
    y_sparse = S.ffn_masked(ffn, x, mask[..., None, :], cfg.act)
    e_raw = float(jnp.mean((y_sparse - y_dense) ** 2))
    y_comp = y_sparse + C.compensate(tp["comp"], x)
    e_comp = float(jnp.mean((y_comp - y_dense) ** 2))
    assert e_comp < e_raw, (e_comp, e_raw)


def test_predictor_ordering_matches_paper(trained_ffn):
    """Paper Table 7 ordering: per-block dynamic (oracle) >= trained
    predictor > first-block static, measured as output fidelity."""
    cfg, ffn, tp, _, block_gen = trained_ffn
    gen = block_gen()
    keep = 1.0 - cfg.ff.sparsity
    tile = cfg.ff.tile

    def fid(mask_fn, n=8):
        errs = []
        first = next(gen)
        m_first, _ = DI.oracle_mask(ffn, first, keep, tile, cfg.act)
        for _ in range(n):
            x = next(gen)
            m = mask_fn(x, m_first)
            y_d = S.ffn_dense(ffn, x, cfg.act)
            y_s = S.ffn_masked(ffn, x, m[..., None, :], cfg.act)
            errs.append(float(jnp.mean((y_s - y_d) ** 2)
                              / jnp.mean(y_d ** 2)))
        return np.mean(errs)

    e_oracle = fid(lambda x, mf: DI.oracle_mask(ffn, x, keep, tile,
                                                cfg.act)[0])
    e_trained = fid(lambda x, mf: DI.predicted_mask(tp, x, keep, tile))
    e_static = fid(lambda x, mf: jnp.broadcast_to(mf[:1], mf.shape))
    assert e_oracle <= e_trained * 1.05
    assert e_trained < e_static, (e_trained, e_static)


def test_engine_sparse_and_dense_serve():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    prompts = [list(np.random.default_rng(1).integers(0, cfg.vocab, 70))]
    res_sparse = Engine(cfg, params).generate(prompts, max_new=4)
    res_dense = Engine(cfg.with_ff(enabled=False), params).generate(
        prompts, max_new=4)
    assert res_sparse.tokens.shape == res_dense.tokens.shape == (1, 4)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("granite-8b", reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.key(0))
    save_checkpoint(str(tmp_path / "ck"), params, {"arch": cfg.name})
    loaded, meta = load_checkpoint(str(tmp_path / "ck"))
    assert meta["arch"] == cfg.name
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(loaded)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_data_is_deterministic():
    g1 = batches(256, 2, 32, seed=5)
    g2 = batches(256, 2, 32, seed=5)
    b1, b2 = next(g1), next(g2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
