"""Tiered KV memory (serving/kv_tier.HostKVTier + the scheduler swap
path): host-tier bookkeeping, swap-instead-of-preempt bit-identity of
greedy output (dense + MoE, with and without chaos), the preemption
fallback when the host tier cannot hold a victim, prefix-sharing
interaction (shared/cached pages are never swapped), cancel/abort of
parked requests, exact cross-tier page accounting after drain, and the
zero-recompilation invariant with swap traffic in the stream."""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, FaultInjector,
                           HostKVTier, PagedKVPool, Request)
from repro.serving.runtime import make_runtime

PAGE = 8                       # divides the reduced block size (32)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def paged_runtime(dense_setup):
    cfg, params = dense_setup
    return make_runtime(cfg.with_(kv_layout="paged", kv_page_size=PAGE),
                        params)


def make_prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(n)).tolist() for n in lengths]


def run_stream(runtime, prompts, max_new=40, n_pages=13, swap_pages=0,
               faults=None, n_slots=4, cache_len=96, **kw):
    sched = ContinuousBatchingScheduler(
        runtime, n_slots=n_slots, cache_len=cache_len, page_size=PAGE,
        n_pages=n_pages, swap_pages=swap_pages, faults=faults, **kw)
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new=max_new))
    outs = sched.run()
    return {r: o.tokens for r, o in outs.items()}, sched


def assert_tiers_clean(sched):
    """Drained-stream invariants across BOTH tiers: exact device
    alloc/free parity, empty host tier with put/free parity, no parked
    stragglers, and internal consistency."""
    pool = sched.pool
    assert not sched.parked
    assert pool.total_page_allocs == pool.total_page_frees
    assert pool.n_swapped_pages == 0
    pool.check_consistency()
    tier = sched.host_tier
    if tier is not None:
        assert tier.n_used == 0
        assert tier.total_host_puts == tier.total_host_frees
        tier.check_consistency()


# ------------------------------------------------------ host tier unit


def test_host_tier_bookkeeping():
    tier = HostKVTier(capacity_pages=8)
    assert tier.n_free == 8 and tier.can_hold(8)
    h1 = tier.put([{"k": np.ones(3)}, {"k": np.zeros(3)}])
    h2 = tier.put([{"k": np.full(3, 2.0)}] * 5)
    assert tier.n_used == 7 and tier.n_free == 1
    assert tier.pages_of(h1) == 2 and tier.pages_of(h2) == 5
    assert not tier.can_hold(2)
    with pytest.raises(Exception):
        tier.put([{"k": np.zeros(3)}] * 2)          # overflow refused
    got = tier.get(h1)
    assert len(got) == 2 and float(got[0]["k"][0]) == 1.0
    assert tier.free(h1) == 2
    assert tier.n_used == 5 and tier.total_host_frees == 2
    # fault-injection surface: stolen capacity shrinks n_free only
    assert tier.steal_free_pages(2) == 2
    assert tier.n_free == 1 and tier.n_used == 5
    assert tier.steal_free_pages(9) == 1            # clamped to free
    assert tier.n_free == 0
    tier.restore_free_pages(3)
    assert tier.n_free == 3
    tier.check_consistency()
    assert tier.free(h2) == 5
    assert tier.n_used == 0
    assert tier.total_host_puts == tier.total_host_frees == 7
    assert tier.peak_used == 7
    tier.check_consistency()


# ------------------------------------------------- swap bit-identity


def test_swap_instead_of_preempt_bit_identical_dense(dense_setup,
                                                     paged_runtime):
    """The headline contract: under the SAME tight heap, a host tier
    turns preempt-and-recompute into swap-and-resume — zero
    preemptions, >= 1 swap cycle — and greedy output stays
    bit-identical to both the ample-heap and the preempting run."""
    cfg, _ = dense_setup
    prompts = make_prompts(cfg, [40, 36, 33, 20, 18])
    ample, s0 = run_stream(paged_runtime, prompts, n_pages=None)
    tight, s1 = run_stream(paged_runtime, prompts, n_pages=13)
    swap, s2 = run_stream(paged_runtime, prompts, n_pages=13,
                          swap_pages=64)
    assert s0.n_preemptions == 0 and s0.n_swap_outs == 0
    assert s1.n_preemptions >= 1           # the heap really was tight
    assert s2.n_swap_outs >= 1 and s2.n_swap_ins == s2.n_swap_outs
    assert s2.n_preemptions == 0           # swap replaced every preempt
    assert ample == tight == swap
    for s in (s1, s2):
        assert_tiers_clean(s)
    ts = s2.tier_stats()
    assert ts["pages_swapped_out"] == ts["pages_swapped_in"] > 0
    assert ts["peak_used"] > 0


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b"])
def test_swap_bit_identical_moe(arch):
    """MoE: parked rows ride the batched decode as inactive self-copies
    and the routed dispatch stays dispatch-group invariant, so swap
    on/off is bit-identical there too."""
    cfg = get_config(arch, reduced=True).with_(kv_layout="paged",
                                               kv_page_size=PAGE)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    runtime = make_runtime(cfg, params)
    prompts = make_prompts(cfg, [40, 36, 33, 20, 18])
    tight, s1 = run_stream(runtime, prompts, n_pages=13)
    swap, s2 = run_stream(runtime, prompts, n_pages=13, swap_pages=64)
    assert s1.n_preemptions >= 1
    assert s2.n_swap_outs >= 1
    assert tight == swap
    assert_tiers_clean(s2)


def test_preempt_fallback_when_tier_too_small(dense_setup,
                                              paged_runtime):
    """A host tier too small for any victim's footprint falls back to
    preemption — output still bit-identical, both tiers still exact."""
    cfg, _ = dense_setup
    prompts = make_prompts(cfg, [40, 36, 33, 20, 18])
    tight, s1 = run_stream(paged_runtime, prompts, n_pages=13)
    tiny, s2 = run_stream(paged_runtime, prompts, n_pages=13,
                          swap_pages=1)
    assert s2.n_preemptions >= 1           # fallback really fired
    assert tight == tiny
    assert_tiers_clean(s2)


# --------------------------------------------------------------- chaos


def test_swap_under_chaos_bit_identical(dense_setup, paged_runtime):
    """Chaos (forced preempts + synthetic pressure on BOTH tiers) over
    the swap-enabled stream: output bit-identical to the fault-free
    run, every stolen resource returned, both tiers exact at drain."""
    cfg, _ = dense_setup
    prompts = make_prompts(cfg, [40, 36, 33, 20, 18])
    clean, _ = run_stream(paged_runtime, prompts, n_pages=13,
                          swap_pages=8)
    inj = FaultInjector(seed=7, p_preempt=0.1, p_pressure=0.3,
                        p_slow=0.0, pressure_frac=0.9)
    chaos, s = run_stream(paged_runtime, prompts, n_pages=13,
                          swap_pages=8, faults=inj)
    assert inj.n_pressure_events >= 1      # the host tier was squeezed
    assert chaos == clean
    assert inj.stats()["outstanding_stolen"] == 0
    assert_tiers_clean(s)


def test_cancel_parked_request_frees_both_tiers(dense_setup,
                                                paged_runtime):
    """Cancelling a PARKED (swapped-out) request releases its device
    pages AND its host payload — the cross-tier leak case a cancel
    path that only knows `active` would miss."""
    cfg, _ = dense_setup
    prompts = make_prompts(cfg, [40, 36, 33, 20, 18])
    sched = ContinuousBatchingScheduler(
        paged_runtime, n_slots=4, cache_len=96, page_size=PAGE,
        n_pages=13, swap_pages=64)
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new=40))
    while not sched.parked and not sched.drained:
        sched.tick()
    assert sched.parked                     # pressure parked someone
    rid = next(iter(sched.parked.values())).req.rid
    assert sched.host_tier.n_used > 0
    assert sched.cancel(rid, reason="client gone")
    assert sched.host_tier.n_used == 0      # host payload freed now
    sched.run()
    assert sched.finished[rid].status == "cancelled"
    assert len(sched.finished) == len(prompts)
    assert_tiers_clean(sched)


# ------------------------------------------------------ prefix sharing


def test_shared_and_cached_pages_never_swapped(dense_setup,
                                               paged_runtime):
    """Pool-level exclusivity contract: swappable_pages() returns only
    refcount-1 uncached pages — pages mapped by other readers or held
    by the prefix index must be evicted/CoW'd, never swapped."""
    pool = PagedKVPool.create(paged_runtime, n_pages=16, page_size=PAGE,
                              n_slots=2, max_pages=8)
    pool.attach_host_tier(HostKVTier(16))
    s1, s2 = pool.acquire(), pool.acquire()
    assert pool.ensure(s1, 4)
    p0, p1, p2 = (int(pool.page_table[s1, j]) for j in range(3))
    pool.mark_cached(p0)                   # published prefix pages...
    pool.mark_cached(p1)
    pool.share(s2, [p0, p1])               # ...mapped by a second reader
    assert pool.ensure(s2, 4)              # + 2 exclusive pages
    pool.mark_cached(p2)                   # cached but single-reader
    js1 = [j for j, _ in pool.swappable_pages(s1)]
    assert js1 == [3]          # shared (0,1) and cached (2) excluded
    js2 = [j for j, _ in pool.swappable_pages(s2)]
    assert js2 == [2, 3]       # only its exclusive tail
    pool.uncache(p2)
    assert [j for j, _ in pool.swappable_pages(s1)] == [2, 3]
    pool.uncache(p0)
    pool.uncache(p1)
    # still mapped by BOTH slots: refcount alone keeps them unswappable
    assert [j for j, _ in pool.swappable_pages(s1)] == [2, 3]
    pool.release(s1)
    pool.release(s2)
    pool.check_consistency()


def test_prefix_cache_with_swap_bit_identical(dense_setup,
                                              paged_runtime):
    """Prefix sharing + swap under pressure: consumers of a shared
    prefix emit bit-identical tokens with the tier on, cached
    refcount-0 pages leave via EVICTION (never via swap — swap traffic
    carries only exclusive pages), and the drained heap is exact once
    the index lets go."""
    cfg, _ = dense_setup
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab, 32).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab, t).tolist()
               for t in (16, 8, 12, 4)]
    kw = dict(max_new=32, n_pages=14, prefix_cache=True)
    off, s0 = run_stream(paged_runtime, prompts, **kw)
    on, s1 = run_stream(paged_runtime, prompts, swap_pages=64, **kw)
    assert s1.prefix_stats()["hits"] >= 1   # sharing really engaged
    assert off == on
    for s in (s0, s1):
        pool = s.pool
        assert (pool.refcount == 0).all()
        if s.prefix_index is not None:
            s.prefix_index.clear()
        assert pool.total_page_allocs == pool.total_page_frees
        pool.check_consistency()
    assert_tiers_clean(s1)


# ------------------------------------------------------ no recompilation


def test_no_recompilation_with_swap_traffic(dense_setup, paged_runtime):
    """compile_counts stay flat across a stream with real swap-out /
    swap-in traffic: the fixed-width read_pages / write_pages entries
    (warmed at warmup) serve every swap width via padding."""
    cfg, _ = dense_setup
    sched = ContinuousBatchingScheduler(
        paged_runtime, n_slots=4, cache_len=96, page_size=PAGE,
        n_pages=13, swap_pages=64)
    counts = sched.warmup()
    # >= 1: the shared module runtime may carry entries from other
    # pool SHAPES; flatness below is what the contract demands
    assert counts["read_pages"] >= 1
    assert counts["write_pages"] >= 1
    prompts = make_prompts(cfg, [40, 36, 33, 20, 18])
    for rid, p in enumerate(prompts):
        sched.submit(Request(rid=rid, prompt=p, max_new=40))
    sched.run()
    assert sched.n_swap_outs >= 1 and sched.n_swap_ins >= 1
    assert paged_runtime.compile_counts() == counts
    assert_tiers_clean(sched)
