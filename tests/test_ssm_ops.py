"""SSM machinery: chunked forms vs step-by-step recurrent references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm_ops import (
    ssd_chunked, ssd_step, mlstm_chunked, mlstm_recurrent_ref,
    slstm_scan, causal_conv1d, conv_step, segsum)


def test_segsum_semantics():
    a = jnp.asarray([1.0, 2.0, 3.0])
    s = segsum(a)
    np.testing.assert_allclose(float(s[2, 0]), 5.0)   # a2 + a3
    np.testing.assert_allclose(float(s[1, 1]), 0.0)
    assert float(s[0, 2]) < -1e20


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (64, 64)])
def test_ssd_chunked_vs_step(T, chunk):
    Bb, H, P, G, N = 2, 4, 8, 1, 16
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (Bb, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (Bb, T, G, N))
    Cm = jax.random.normal(ks[4], (Bb, T, G, N))
    xdt, dA = x * dt[..., None], dt * A[None, None]
    y, st = ssd_chunked(xdt, dA, Bm, Cm, chunk)
    s = jnp.zeros((Bb, H, P, N))
    ys = []
    for t in range(T):
        yt, s = ssd_step(s, xdt[:, t], dA[:, t], Bm[:, t], Cm[:, t])
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(s),
                               rtol=1e-3, atol=1e-4)


def test_ssd_state_carry():
    """Two chunked halves with carried state == one full pass."""
    Bb, T, H, P, G, N = 1, 64, 2, 4, 1, 8
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (Bb, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (Bb, T, G, N))
    Cm = jax.random.normal(ks[4], (Bb, T, G, N))
    xdt, dA = x * dt[..., None], dt * A[None, None]
    y_full, st_full = ssd_chunked(xdt, dA, Bm, Cm, 16)
    y1, s1 = ssd_chunked(xdt[:, :32], dA[:, :32], Bm[:, :32], Cm[:, :32], 16)
    y2, s2 = ssd_chunked(xdt[:, 32:], dA[:, 32:], Bm[:, 32:], Cm[:, 32:],
                         16, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mlstm_chunked_vs_recurrent(chunk):
    Bb, T, H, dk, dv = 2, 64, 4, 8, 8
    ks = jax.random.split(jax.random.key(2), 5)
    q = jax.random.normal(ks[0], (Bb, T, H, dk))
    k = jax.random.normal(ks[1], (Bb, T, H, dk))
    v = jax.random.normal(ks[2], (Bb, T, H, dv))
    ig = jax.random.normal(ks[3], (Bb, T, H)) * 2
    fg = jax.random.normal(ks[4], (Bb, T, H)) * 2 + 2
    h_ref, st_ref = mlstm_recurrent_ref(q, k, v, ig, fg)
    h_c, st_c = mlstm_chunked(q, k, v, ig, fg, chunk)
    # f32 cancellation in the normalizer bounds absolute precision
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_ref),
                               rtol=2e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(st_c[0]), np.asarray(st_ref[0]),
                               rtol=1e-4, atol=1e-4)


def test_slstm_normalizer_bounds():
    """sLSTM hidden state magnitude is bounded by |z| (n normalizes)."""
    Bb, T, H, dh = 2, 32, 2, 4
    ks = jax.random.split(jax.random.key(3), 4)
    zg, ig, fg, og = [jax.random.normal(k, (Bb, T, H, dh)) * 3 for k in ks]
    r = jax.random.normal(jax.random.key(4), (H, dh, 4 * dh)) * 0.1
    hs, state = slstm_scan(zg, ig, fg, og, r)
    assert not bool(jnp.isnan(hs).any())
    assert float(jnp.abs(hs).max()) <= 1.5  # |o|<=1, |c/n|<=max|tanh|=1


def test_conv_step_matches_batch():
    Bb, T, Cc, K = 2, 16, 6, 4
    x = jax.random.normal(jax.random.key(5), (Bb, T, Cc))
    w = jax.random.normal(jax.random.key(6), (K, Cc))
    b = jnp.full((Cc,), 0.3)
    y_batch = causal_conv1d(x, w, b)
    st = jnp.zeros((Bb, K - 1, Cc))
    outs = []
    for t in range(T):
        o, st = conv_step(st, x[:, t], w, b)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(y_batch), rtol=1e-5, atol=1e-6)
