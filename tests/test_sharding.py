"""Sharding rules + a subprocess dry-run on a small host mesh.

The 512-device production dry-run is exercised by launch/dryrun.py; here
we verify (a) rule resolution incl. divisibility fallbacks, (b) the
shard_map flash-decode numerics, and (c) that a REDUCED arch lowers &
compiles on an 8-device mesh in a fresh subprocess (device count must be
set before jax init, so it cannot run in-process)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.nn.param import ParamSpec
from repro.distributed import sharding as SHD


class FakeMesh:
    """Duck-typed mesh for rule resolution (no jax devices touched)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_rule_resolution_divisibility():
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible -> sharded
    assert SHD.pspec_for(("embed", "mlp"), (2048, 5632), mesh) == \
        P(None, "model")
    # 6 heads not divisible by 16 -> replicated
    assert SHD.pspec_for(("embed", "heads", "head_dim"), (384, 6, 64),
                         mesh) == P(None, None, None)
    # batch resolves to all data axes
    assert SHD.pspec_for(("batch", "seq"), (256, 4096), mesh) == \
        P(("data",), None)


def test_rule_resolution_multipod():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert SHD.pspec_for(("batch", "seq"), (256, 4096), mesh) == \
        P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard 32-ways -> replicated
    assert SHD.pspec_for(("batch", "seq"), (1, 1), mesh) == P(None, None)


def test_no_duplicate_mesh_axes():
    mesh = FakeMesh({"data": 4, "model": 4})
    # two logical axes mapping to "model": second occurrence dropped
    spec = SHD.pspec_for(("mlp", "vocab"), (64, 64), mesh)
    flat = [e for e in spec if e is not None]
    assert len(flat) == len(set(flat)) == 1


def test_param_shardings_tree():
    mesh = FakeMesh({"data": 2, "model": 4})
    specs = {"w": ParamSpec((64, 128), ("embed", "mlp"))}
    # param_shardings needs a real Mesh for NamedSharding; just check
    # pspec resolution here
    assert SHD.pspec_for(("embed", "mlp"), (64, 128), mesh) == \
        P(None, "model")


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn import param as PM
    from repro.distributed.sharding import param_shardings
    from repro.launch.mesh import make_host_mesh
    from repro.training.train import make_loss_fn
    from repro import compat

    cfg = get_config("{arch}", reduced=True)
    mesh = make_host_mesh(2, 4)
    model = get_model(cfg)
    specs = model.specs(cfg)
    shard = param_shardings(specs, mesh)
    aparams = PM.abstract_params(specs, shard)
    loss_fn = make_loss_fn(cfg)
    batch = {{
        "tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
        "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32),
    }}
    with compat.use_mesh(mesh):
        lowered = jax.jit(lambda p, b: loss_fn(p, b)[0]).lower(
            aparams, batch)
        compiled = lowered.compile()
    ca = compat.cost_analysis(compiled)
    print(json.dumps({{"flops": ca["flops"],
                       "devices": len(jax.devices())}}))
""")


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2-moe-a2.7b"])
def test_subprocess_dryrun_host_mesh(arch):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["flops"] > 0
