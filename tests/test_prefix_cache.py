"""Refcounted prefix-sharing paged KV: bit-identity of greedy output
with sharing on vs off (dense + MoE, through preemption, eviction and
chaos), plan isolation (different SparsityPlans never share), the
copy-on-write partial-tail path, unshared-footprint shedding, and the
zero-recompilation invariant with the cache on."""
import dataclasses

import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.core.fastforward import resolve_plan
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, FaultInjector,
                           PrefixIndex, Request)
from repro.serving.runtime import make_runtime

PAGE = 8                       # divides the reduced block size (32)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def paged_runtime(dense_setup):
    cfg, params = dense_setup
    return make_runtime(cfg.with_(kv_layout="paged", kv_page_size=PAGE),
                        params)


def shared_prompts(cfg, prefix_len, tails, seed=0, groups=1):
    """`groups` families, each sharing one `prefix_len`-token prefix
    with per-request unique tails of the given lengths."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(groups):
        prefix = rng.integers(0, cfg.vocab, prefix_len).tolist()
        out.append([prefix + rng.integers(0, cfg.vocab, int(t)).tolist()
                    for t in tails])
    return out


def run_waves(runtime, waves, prefix_cache, max_new=6, **kw):
    """Submit each wave, drain it fully (so earlier waves' blocks are
    published before later waves look them up), return (tokens, sched)."""
    sched = ContinuousBatchingScheduler(runtime,
                                        prefix_cache=prefix_cache, **kw)
    rid = 0
    for wave in waves:
        for prompt in wave:
            sched.submit(Request(rid=rid, prompt=prompt, max_new=max_new))
            rid += 1
        sched.run()
    return {r: o.tokens for r, o in sched.finished.items()}, sched


def assert_drained_clean(sched):
    """Leak accounting with sharing on: after drain every refcount is
    zero, free + reclaimable covers the whole heap, and once the index
    lets go allocs == frees exactly."""
    pool = sched.pool
    pool.check_consistency()
    assert (pool.refcount == 0).all()
    assert (pool.page_table == 0).all()
    assert pool.n_available_pages == pool.n_pages - 1
    if sched.prefix_index is not None:
        sched.prefix_index.clear()
        pool.check_consistency()
    assert pool.n_free_pages == pool.n_pages - 1
    assert pool.total_page_allocs == pool.total_page_frees


# ------------------------------------------------------- bit-equivalence


def test_sharing_bit_identical_dense(dense_setup, paged_runtime):
    """Publisher wave then consumer wave over a shared 2-block prefix:
    consumers skip the shared blocks yet emit bit-identical tokens."""
    cfg, _ = dense_setup
    [group] = shared_prompts(cfg, 64, [16, 6, 32, 1], seed=1)
    waves = [group[:1], group[1:]]
    kw = dict(n_slots=4, cache_len=128)
    on, s_on = run_waves(paged_runtime, waves, True, **kw)
    off, s_off = run_waves(paged_runtime, waves, False, **kw)
    assert on == off
    assert s_on.n_prefix_hits == 3
    assert s_on.n_shared_blocks == 6          # 3 consumers x 2 blocks
    assert s_on.n_prefill_blocks == s_off.n_prefill_blocks - 6
    assert s_on.prefix_stats()["hit_rate"] == 0.75
    assert s_off.prefix_index is None and s_off.prefix_stats() is None
    assert_drained_clean(s_on)


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b"])
def test_sharing_bit_identical_moe(arch):
    """Dropless MoE dispatch is dispatch-group invariant, so shared
    prefix KV stays bit-identical for MoE blocks too."""
    cfg = get_config(arch, reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    runtime = make_runtime(cfg.with_(kv_layout="paged", kv_page_size=PAGE),
                           params)
    [group] = shared_prompts(cfg, 64, [10, 20, 3], seed=2)
    waves = [group[:1], group[1:]]
    kw = dict(n_slots=3, cache_len=128)
    on, s_on = run_waves(runtime, waves, True, **kw)
    off, _ = run_waves(runtime, waves, False, **kw)
    assert on == off
    assert s_on.n_shared_blocks == 4          # 2 consumers x 2 blocks
    assert_drained_clean(s_on)


# --------------------------------------------------------- plan isolation


def test_different_plans_never_share(dense_setup):
    """The trie is rooted per SparsityPlan name: a consumer under a
    different effort tier misses a cached prefix entirely (sparse plans
    change the KV bytes, so cross-plan sharing must be impossible)."""
    cfg, params = dense_setup
    plans = tuple(
        dataclasses.replace(resolve_plan(cfg, effort=e), name=e)
        for e in ("balanced", "turbo"))
    runtime = make_runtime(cfg.with_(kv_layout="paged", kv_page_size=PAGE),
                           params, plans=plans)
    [group] = shared_prompts(cfg, 64, [12, 12, 12], seed=3)
    sched = ContinuousBatchingScheduler(runtime, n_slots=2, cache_len=128,
                                        prefix_cache=True)
    sched.submit(Request(rid=0, prompt=group[0], max_new=4,
                         effort="balanced"))
    sched.run()
    sched.submit(Request(rid=1, prompt=group[1], max_new=4,
                         effort="turbo"))
    sched.run()
    assert sched.n_prefix_hits == 0           # cross-plan lookup missed
    sched.submit(Request(rid=2, prompt=group[2], max_new=4,
                         effort="balanced"))
    sched.run()
    assert sched.n_prefix_hits == 1           # same plan hits
    assert sched.n_shared_blocks == 2
    # both roots now cache the SAME token keys — under DISJOINT
    # physical pages (turbo KV bytes differ from balanced KV bytes)
    idx = sched.prefix_index
    keys = PrefixIndex.page_keys(group[1], PAGE, 8)
    bal = idx.lookup("balanced", keys, record=False)
    tur = idx.lookup("turbo", keys, record=False)
    assert len(bal) == len(tur) == 8
    assert not set(bal) & set(tur)
    assert_drained_clean(sched)


# ----------------------------------------- eviction / preemption / chaos


def test_eviction_reclaims_cold_prefixes(dense_setup, paged_runtime):
    """A dry heap evicts cached-but-unreferenced prefixes (LRU, whole
    subtrees) before preempting live work; outputs stay bit-identical
    and the heap accounts to zero."""
    cfg, _ = dense_setup
    groups = shared_prompts(cfg, 64, [16, 8], seed=4, groups=3)
    waves = [[g[0]] for g in groups] + [[g[1]] for g in groups]
    # 14 usable pages: one 80-token request peaks at ~11 pages, so each
    # new publisher must evict the previous group's 8 cached pages
    kw = dict(n_slots=2, cache_len=96, n_pages=15)
    on, s_on = run_waves(paged_runtime, waves, True, **kw)
    off, _ = run_waves(paged_runtime, waves, False, **kw)
    assert on == off
    assert s_on.prefix_index.n_evictions > 0
    assert_drained_clean(s_on)


def test_preemption_with_sharing_bit_identical(dense_setup, paged_runtime):
    """Concurrent consumers on an oversubscribed heap: decode growth
    preempts the youngest mid-flight; re-admission re-maps the cached
    prefix and the greedy output is unchanged."""
    cfg, _ = dense_setup
    [group] = shared_prompts(cfg, 32, [4, 2, 6], seed=5)

    def run(n_pages, prefix_cache):
        sched = ContinuousBatchingScheduler(
            paged_runtime, n_slots=3, cache_len=96, n_pages=n_pages,
            prefix_cache=prefix_cache)
        sched.submit(Request(rid=0, prompt=group[0], max_new=40))
        sched.run()
        for i, p in enumerate(group[1:], start=1):
            sched.submit(Request(rid=i, prompt=p, max_new=40))
        sched.run()
        return {r: o.tokens for r, o in sched.finished.items()}, sched

    ample, _ = run(None, False)
    # 13 usable pages: two consumers decoding to ~10 pages each (4 of
    # them shared) need 16 -> the youngest is preempted mid-decode
    tight, s1 = run(14, True)
    assert ample == tight
    assert s1.n_preemptions >= 1
    assert s1.n_prefix_hits >= 1
    assert_drained_clean(s1)


@pytest.mark.parametrize("seed", [0, 3])
def test_chaos_with_sharing_bit_identical(dense_setup, paged_runtime,
                                          seed):
    """Deterministic fault injection (forced preemptions + synthetic
    pressure) over a shared-prefix stream with the cache on: every
    output matches the fault-free sharing-off run, nothing leaks."""
    cfg, _ = dense_setup
    groups = shared_prompts(cfg, 64, [16, 6, 24], seed=6, groups=2)
    prompts = [p for g in groups for p in g]

    def run(prefix_cache, faults):
        sched = ContinuousBatchingScheduler(
            paged_runtime, n_slots=3, cache_len=128, n_pages=40,
            prefix_cache=prefix_cache, faults=faults)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new=8))
        sched.run()
        return {r: o.tokens for r, o in sched.finished.items()}, sched

    base, _ = run(False, None)
    chaos, s1 = run(True, FaultInjector(seed=seed, p_preempt=0.3,
                                        p_pressure=0.3))
    assert base == chaos
    assert s1.faults.n_forced_preempts + s1.faults.n_pressure_events > 0
    assert_drained_clean(s1)


# ------------------------------------------------------ COW partial tail


def test_cow_partial_tail(dense_setup, paged_runtime):
    """A chain that ends mid-block (producible only by partial subtree
    eviction — publishes are whole-block): the consumer COW-detaches
    the tail pages, re-prefills the restart block over private copies,
    and the output is bit-identical to a cold run."""
    cfg, _ = dense_setup
    [group] = shared_prompts(cfg, 80, [0], seed=7)
    prompt = group[0]                          # 80 tokens = 3 blocks
    sched = ContinuousBatchingScheduler(paged_runtime, n_slots=2,
                                        cache_len=96, prefix_cache=True)
    sched.submit(Request(rid=0, prompt=prompt, max_new=5))
    sched.run()
    idx = sched.prefix_index
    keys = PrefixIndex.page_keys(prompt, PAGE, 8)
    chain = idx.lookup(sched._plan_name(0), keys, record=False)
    assert len(chain) == 8                     # blocks 0,1 published
    # evict the subtree below chain position 6 -> a 6-page cached chain
    # (1 whole block + a 2-page partial tail)
    assert idx.drop_page(chain[6]) == 2
    sched.submit(Request(rid=1, prompt=list(prompt), max_new=5))
    sched.run()
    assert sched.pool.n_cow_pages == 2         # the tail detached
    assert sched.n_shared_blocks == 1          # only block 0 skipped
    cold, _ = run_waves(paged_runtime, [[prompt]], False, max_new=5,
                        n_slots=2, cache_len=96)
    assert sched.finished[1].tokens == cold[0]
    assert sched.finished[1].tokens == sched.finished[0].tokens
    assert_drained_clean(sched)


# -------------------------------------------- shedding / compile counts


def test_shed_charges_unshared_blocks_only(dense_setup, paged_runtime):
    """The predictive deadline shed charges the UNSHARED block count:
    a cached prefix turns a provably-late request into a feasible one,
    while an uncached stranger with the same deadline still sheds."""
    cfg, _ = dense_setup
    [group] = shared_prompts(cfg, 160, [0, 0], seed=8)
    rng = np.random.default_rng(9)
    stranger = rng.integers(0, cfg.vocab, 160).tolist()
    sched = ContinuousBatchingScheduler(paged_runtime, n_slots=2,
                                        cache_len=192, prefix_cache=True)
    sched.submit(Request(rid=0, prompt=group[0], max_new=2))
    sched.run()                                # blocks 0-3 cached
    # pretend prefill ticks cost 10s: 5 blocks can never meet 15s, but
    # the consumer's single unshared block can
    sched._min_prefill_tick_s = 10.0
    sched.submit(Request(rid=1, prompt=stranger, max_new=2,
                         deadline_ms=15_000))
    sched.submit(Request(rid=2, prompt=group[1], max_new=2,
                         deadline_ms=15_000))
    sched.run()
    assert sched.finished[1].status == "shed"
    assert "cannot meet" in sched.finished[1].reason
    assert sched.finished[2].status == "ok"
    assert sched.n_shared_blocks >= 4


def test_no_recompilation_with_prefix_cache(dense_setup):
    """compile_counts stay flat across shared-prefix traffic including
    a COW admission — copy_pages is one fixed-width executable warmed
    by warmup(), shared page tables are traced values like any other."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg.with_(kv_layout="paged", kv_page_size=PAGE),
                           params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=3,
                                        cache_len=128, prefix_cache=True)
    counts = sched.warmup()
    assert counts["copy_pages"] == 1
    [group] = shared_prompts(cfg, 80, [0, 8, 16], seed=10)
    sched.submit(Request(rid=0, prompt=group[0], max_new=4))
    sched.run()
    keys = PrefixIndex.page_keys(group[0], PAGE, 8)
    chain = sched.prefix_index.lookup(sched._plan_name(0), keys,
                                      record=False)
    sched.prefix_index.drop_page(chain[6])     # force a COW tail
    for i, p in enumerate(group[1:], start=1):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    sched.run()
    assert sched.pool.n_cow_pages >= 2
    assert sched.n_prefix_hits >= 2
    assert runtime.compile_counts() == counts
    assert_drained_clean(sched)


def test_prefix_cache_requires_paged(dense_setup):
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)        # slot layout
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingScheduler(runtime, n_slots=2, cache_len=64,
                                    prefix_cache=True)
