"""Flash-attention Pallas kernel (interpret mode) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import mha_flash
from repro.nn.attention import dot_attention, causal_mask, sliding_mask


def qkv(T, S, dh, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(ks[0], (T, dh)).astype(dtype),
            jax.random.normal(ks[1], (S, dh)).astype(dtype),
            jax.random.normal(ks[2], (S, dh)).astype(dtype))


@pytest.mark.parametrize("T,S,dh,bq,bk", [
    (128, 128, 64, 64, 64),
    (256, 256, 128, 128, 64),
    (64, 256, 64, 64, 64),      # q block at offset into larger cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal(T, S, dh, bq, bk, dtype):
    q, k, v = qkv(T, S, dh, dtype)
    off = S - T
    o_k = flash_attention(q, k, v, block_q=bq, block_k=bk, causal=True,
                          q_offset=off, interpret=True)
    o_r = attention_ref(q, k, v, causal=True, q_offset=off)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [64, 128])
def test_flash_sliding_window(window):
    q, k, v = qkv(256, 256, 64)
    o_k = flash_attention(q, k, v, block_q=64, block_k=64, causal=True,
                          window=window, interpret=True)
    o_r = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = qkv(128, 128, 64)
    o_k = flash_attention(q, k, v, block_q=64, block_k=64, causal=False,
                          interpret=True)
    o_r = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


def test_mha_flash_gqa_matches_dot_attention():
    B, T, H, Kv, dh = 2, 128, 8, 2, 64
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, Kv, dh))
    v = jax.random.normal(ks[2], (B, T, Kv, dh))
    o_f = mha_flash(q, k, v, causal=True, use_kernel=True, interpret=True)
    o_d = dot_attention(q, k, v, causal_mask(T, T))
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_d),
                               rtol=2e-5, atol=2e-5)
