"""Performance-variant equivalence: every §Perf optimization must be
semantics-preserving against its paper-faithful baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import dense, whisper
from repro.nn.param import init_params
from repro.nn.attention import attention_spec, attend_full


def test_fused_prefill_bit_exact_vs_blockwise():
    """prefill_fused (beyond-paper, parallel blocks) must reproduce the
    paper's sequential blockwise scan exactly — logits AND cache."""
    cfg = get_config("granite-8b", reduced=True)
    params = init_params(dense.specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 128), 0, cfg.vocab)
    c1 = dense.init_cache(cfg, 2, 128)
    c1, l1 = dense.prefill(params, cfg, {"tokens": toks}, c1)
    c2 = dense.init_cache(cfg, 2, 128)
    c2, l2 = dense.prefill_fused(params, cfg, {"tokens": toks}, c2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c1["k"]), np.asarray(c2["k"]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("window", [None, 48])
def test_chunked_attention_matches_full(window):
    p = init_params(attention_spec(64, 8, 2, 32), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 128, 64))
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    o1 = attend_full(p, x, pos, window=window)
    o2 = attend_full(p, x, pos, window=window, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-3, atol=1e-3)


def test_chunked_forward_matches_baseline():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(dense.specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
    l0, _ = dense.forward(params, cfg, {"tokens": toks})
    l1, _ = dense.forward(params, cfg.with_(attn_chunk=32),
                          {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-3, atol=1e-3)


def test_whisper_decode_matches_forward():
    """Enc-dec decode continuation equals the teacher-forced forward."""
    cfg = get_config("whisper-tiny", reduced=True).with_ff(enabled=False)
    params = init_params(whisper.specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
    audio = jax.random.normal(jax.random.key(2),
                              (2, cfg.n_audio_frames, cfg.d_model))
    cache = whisper.init_cache(cfg, 2, 80)
    cache, pl = whisper.prefill(
        params, cfg, {"tokens": toks, "audio_embed": audio}, cache)
    nt = jnp.argmax(pl, -1).astype(jnp.int32)
    dl, cache = whisper.decode_step(params, cfg, nt, cache, jnp.int32(64))
    toks2 = jnp.concatenate([toks, nt[:, None]], 1)
    l2, _ = whisper.forward(params, cfg,
                            {"tokens": toks2, "audio_embed": audio})
    np.testing.assert_allclose(np.asarray(dl), np.asarray(l2[:, -1]),
                               rtol=2e-3, atol=2e-4)


def test_sliding_window_decode_ring_buffer():
    """Windowed decode over a ring buffer matches windowed full forward."""
    cfg = get_config("llava-next-mistral-7b", reduced=True).with_ff(
        enabled=False).with_(sliding_window=32, n_patches=0)
    params = init_params(dense.specs(cfg), jax.random.key(0))
    T = 64
    toks = jax.random.randint(jax.random.key(1), (2, T), 0, cfg.vocab)
    logits, _ = dense.forward(params, cfg, {"tokens": toks})
    # decode token-by-token through a window-sized ring buffer
    W = cfg.sliding_window
    cache = dense.init_cache(cfg, 2, W)
    out = None
    for t in range(T):
        out, cache = dense.decode_step(params, cfg, toks[:, t], cache,
                                       jnp.int32(t), window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-4)
