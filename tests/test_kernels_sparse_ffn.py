"""Per-kernel validation: sparse/dense gated FFN Pallas kernels
(interpret mode) vs pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import cost_analysis
from repro.kernels.sparse_ffn.kernel import (sparse_ffn, sparse_ffn_batched,
                                             dense_ffn)
from repro.kernels.sparse_ffn.ref import (sparse_ffn_ref,
                                          sparse_ffn_batched_ref,
                                          dense_ffn_ref)
from repro.kernels.sparse_ffn.ops import sparse_ffn_batched_op, sparse_ffn_op


def make_inputs(N, D, F, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = (jax.random.normal(ks[0], (N, D)) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (D, F)) / np.sqrt(D)).astype(dtype)
    wu = (jax.random.normal(ks[2], (D, F)) / np.sqrt(D)).astype(dtype)
    wd = (jax.random.normal(ks[3], (F, D)) / np.sqrt(F)).astype(dtype)
    return x, wg, wu, wd


@pytest.mark.parametrize("N,D,F,tile,k", [
    (128, 128, 512, 128, 2),
    (128, 256, 1024, 128, 5),
    (256, 128, 1024, 128, 8),    # k == all tiles -> dense equivalence
    (128, 384, 768, 128, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_kernel_matches_ref(N, D, F, tile, k, dtype):
    x, wg, wu, wd = make_inputs(N, D, F, dtype)
    n_tiles = F // tile
    ids = jnp.asarray(
        np.random.default_rng(1).choice(n_tiles, size=k, replace=False),
        jnp.int32)
    y_k = sparse_ffn(x, wg, wu, wd, ids, tile=tile, interpret=True)
    y_r = sparse_ffn_ref(x, wg, wu, wd, ids, tile)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=tol, atol=tol)


def test_sparse_all_tiles_equals_dense():
    x, wg, wu, wd = make_inputs(128, 128, 512, jnp.float32)
    ids = jnp.arange(4, dtype=jnp.int32)
    y_s = sparse_ffn(x, wg, wu, wd, ids, tile=128, interpret=True)
    y_d = dense_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("tile", [128, 256])
def test_dense_kernel_matches_ref(tile):
    x, wg, wu, wd = make_inputs(128, 256, 512, jnp.float32)
    y_k = dense_ffn(x, wg, wu, wd, tile=tile, interpret=True)
    y_r = dense_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-5, atol=1e-5)


def make_batched_ids(B, n_tiles, k, seed=1):
    """Per-row DISTINCT tile selections (no two rows share a set)."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.stack([rng.choice(n_tiles, size=k, replace=False)
                  for _ in range(B)]), jnp.int32)


@pytest.mark.parametrize("B,N,D,F,tile,k", [
    (2, 128, 128, 512, 128, 2),
    (4, 128, 256, 1024, 128, 5),
    (3, 32, 128, 512, 64, 3),      # reduced-config-like small block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_kernel_matches_gather_and_mask(B, N, D, F, tile, k, dtype):
    """Interpret-mode batched Pallas kernel (per-b scalar-prefetched
    tile ids) vs the XLA gather path vs the mask path, with DISTINCT
    tile ids per block — the serving multi-request prefill contract."""
    from repro.core import sparse_ffn as S
    x, wg, wu, wd = make_inputs(N, D, F, dtype)
    xb = jnp.stack([jnp.roll(x, b, axis=0) * (1.0 + 0.25 * b)
                    for b in range(B)]).astype(dtype)
    ids = make_batched_ids(B, F // tile, k)
    assert len({tuple(np.asarray(r)) for r in ids}) == B

    y_kernel = sparse_ffn_batched(xb, wg, wu, wd, ids, tile=tile,
                                  block_n=min(N, 128), interpret=True)
    y_gather = sparse_ffn_batched_ref(xb, wg, wu, wd, ids, tile)
    params = {"wg": wg, "wu": wu, "wd": wd}
    mask = S.mask_from_tile_ids(ids, F // tile, tile)      # [B, F]
    y_mask = S.ffn_masked(params, xb, mask[:, None, :])

    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_gather),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(y_mask).astype(np.float32),
                               np.asarray(y_gather), rtol=tol, atol=tol)


def test_batched_kernel_rows_are_independent():
    """Row b of the batched kernel equals the single-block kernel run
    on (x[b], ids[b]) — no cross-row leakage through the grid."""
    x, wg, wu, wd = make_inputs(128, 128, 512, jnp.float32)
    B = 3
    xb = jnp.stack([x * (b + 1) for b in range(B)])
    ids = make_batched_ids(B, 4, 2, seed=3)
    y_b = sparse_ffn_batched(xb, wg, wu, wd, ids, tile=128, interpret=True)
    for b in range(B):
        y_1 = sparse_ffn(xb[b], wg, wu, wd, ids[b], tile=128,
                         interpret=True)
        np.testing.assert_allclose(np.asarray(y_b[b]), np.asarray(y_1),
                                   rtol=1e-5, atol=1e-5)


def test_batched_op_cpu_path_matches_interpret_kernel():
    """ops dispatch: the CPU fused-gather path and the forced
    interpret-mode batched kernel agree (the cross-check the serving
    path relies on when validating off-TPU)."""
    x, wg, wu, wd = make_inputs(128, 128, 512, jnp.float32)
    xb = jnp.stack([x, x * 0.5])
    ids = make_batched_ids(2, 4, 2, seed=5)
    y_cpu = sparse_ffn_batched_op(xb, wg, wu, wd, ids, tile=128,
                                  use_kernel=False)
    y_int = sparse_ffn_batched_op(xb, wg, wu, wd, ids, tile=128,
                                  use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_cpu), np.asarray(y_int),
                               rtol=1e-5, atol=1e-5)


def test_ops_dispatch_batched():
    x, wg, wu, wd = make_inputs(128, 128, 512, jnp.float32)
    xb = jnp.stack([x, x * 0.5])
    ids = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    y = sparse_ffn_op(xb, wg, wu, wd, ids, tile=128, use_kernel=False)
    y0 = sparse_ffn_ref(x, wg, wu, wd, ids[0], 128)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y0),
                               rtol=1e-6, atol=1e-6)


def test_k_valid_dead_slot_ids_never_read():
    """DMA-skip contract: dead selection slots (k >= k_valid) are
    index-map-clamped to the last LIVE tile, so their ids are never
    dereferenced — two selections differing ONLY in dead-slot ids must
    produce bit-identical interpret output (single and batched)."""
    x, wg, wu, wd = make_inputs(128, 128, 512, jnp.float32)
    ids_a = jnp.asarray([0, 2, 1, 3], jnp.int32)
    ids_b = jnp.asarray([0, 2, 3, 1], jnp.int32)      # dead tail differs
    y_a = sparse_ffn(x, wg, wu, wd, ids_a, k_valid=jnp.int32(2),
                     tile=128, interpret=True)
    y_b = sparse_ffn(x, wg, wu, wd, ids_b, k_valid=jnp.int32(2),
                     tile=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_b))
    # and the clamped index map changes nothing vs the live prefix alone
    y_live = sparse_ffn(x, wg, wu, wd, ids_a[:2], tile=128,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_live),
                               rtol=1e-6, atol=1e-6)

    xb = jnp.stack([x, x * 0.5, x * 2.0])
    idsb_a = jnp.asarray([[0, 1, 2, 3], [1, 2, 0, 3], [2, 3, 0, 1]],
                         jnp.int32)
    idsb_b = jnp.asarray([[0, 3, 1, 2], [1, 2, 3, 0], [2, 3, 0, 1]],
                         jnp.int32)                   # same live prefixes
    counts = jnp.asarray([1, 2, 4], jnp.int32)
    yb_a = sparse_ffn_batched(xb, wg, wu, wd, idsb_a, k_valid=counts,
                              tile=128, interpret=True)
    yb_b = sparse_ffn_batched(xb, wg, wu, wd, idsb_b, k_valid=counts,
                              tile=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(yb_a), np.asarray(yb_b))


def test_kernel_flop_scaling():
    """The kernel's HLO cost must scale with K (the point of the paper)."""
    x, wg, wu, wd = make_inputs(128, 256, 2048, jnp.float32)
    ids2 = jnp.arange(2, dtype=jnp.int32)
    ids8 = jnp.arange(8, dtype=jnp.int32)
    # interpret-mode pallas doesn't expose cost; compare against the
    # analytical count through the ref path lowering instead.
    c2 = cost_analysis(jax.jit(lambda *a: sparse_ffn_ref(*a, 128)).lower(
        x, wg, wu, wd, ids2).compile())
    c8 = cost_analysis(jax.jit(lambda *a: sparse_ffn_ref(*a, 128)).lower(
        x, wg, wu, wd, ids8).compile())
    assert c8["flops"] > 3.5 * c2["flops"]
