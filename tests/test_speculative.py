"""Self-speculative decode: sparse-draft / dense-verify on the
registered SparsityPlan executables.

The contract under test: greedy output is BIT-identical with
speculation on vs off — dense + MoE, slot + paged KV layouts, mixed
effort tiers, per-request draft caps, EOS stops, temperature rows,
deadline expiry, forced preemption and seeded chaos — the draft plan
buys latency only. Plus the pure acceptance rule (longest agreeing
prefix + bonus token), KV rollback leak regressions (acquires ==
releases, free lists whole, published prefix pages never truncated),
flat compile counts after warmup, and the k=0 degeneration to the
non-speculative tick."""
import dataclasses

import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.core.fastforward import resolve_plan
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, FaultInjector,
                           Request, SpeculativeConfig, accept_drafts,
                           parse_speculate_arg)
from repro.serving.runtime import make_runtime

PAGE = 8                       # divides the reduced block size (32)
SPEC = SpeculativeConfig(k=3, draft="turbo")


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def make_plans(cfg, efforts=("balanced", "turbo")):
    return tuple(
        dataclasses.replace(resolve_plan(cfg, effort=e), name=e)
        for e in efforts)


@pytest.fixture(scope="module")
def slot_runtime(dense_setup):
    cfg, params = dense_setup
    return make_runtime(cfg, params, plans=make_plans(cfg))


@pytest.fixture(scope="module")
def paged_runtime(dense_setup):
    cfg, params = dense_setup
    cfg = cfg.with_(kv_layout="paged", kv_page_size=PAGE)
    return make_runtime(cfg, params, plans=make_plans(cfg))


def make_requests(cfg, seed=1):
    """Mixed stream: ragged prompts, per-request effort tiers, a
    per-request draft cap, one speculation-off row, one EOS row, one
    sampled (temperature) row — the composition the bit-identity
    contract must be independent of."""
    rng = np.random.default_rng(seed)
    lengths = [40, 70, 33, 90, 64, 50, 25]
    efforts = [None, "turbo", "balanced", "turbo", None, "balanced", None]
    speculate = [None, None, 2, 0, None, 1, None]
    reqs = []
    for i, n in enumerate(lengths):
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, n).tolist(),
            max_new=10, effort=efforts[i], speculate=speculate[i],
            eos_id=3 if i == 1 else None,
            temperature=0.7 if i == 4 else 0.0))
    return reqs


def drive(runtime, requests, speculative, **kw):
    kw.setdefault("cache_len", 160)
    kw.setdefault("n_slots", 3)
    kw.setdefault("prefill_batch", 2)
    sched = ContinuousBatchingScheduler(runtime, speculative=speculative,
                                        **kw)
    counts0 = sched.warmup()
    for r in requests:
        sched.submit(r)
    outs = sched.run()
    if None not in counts0.values():
        assert runtime.compile_counts() == counts0, \
            "recompiled after warmup"
    return outs, sched


def assert_pools_whole(sched):
    pool = sched.pool
    assert pool.total_acquires == pool.total_releases
    assert pool.n_free == sched.n_slots
    if sched.paged:
        assert pool.total_page_allocs == pool.total_page_frees
        assert pool.n_free_pages == pool.n_pages - 1
        assert (pool.page_table == 0).all()
        assert (pool.allocated == 0).all()


# ------------------------------------------------ acceptance rule (pure)


def test_accept_drafts_agreement_prefix():
    # all agree: k drafts + the bonus token
    n, out = accept_drafts(np.array([5, 7, 2]), np.array([5, 7, 2, 9]))
    assert n == 3 and out.tolist() == [5, 7, 2, 9]
    # first disagreement at i=1: emit greedy[0], greedy[1] (the bonus)
    n, out = accept_drafts(np.array([5, 8, 2]), np.array([5, 7, 2, 9]))
    assert n == 1 and out.tolist() == [5, 7]
    # immediate disagreement: exactly the verifier's token
    n, out = accept_drafts(np.array([4, 8, 2]), np.array([5, 7, 2, 9]))
    assert n == 0 and out.tolist() == [5]


def test_accept_drafts_k0_degenerates_to_plain_tick():
    """Zero drafts -> the non-speculative tick: one token, the
    verifier's own argmax at the current position."""
    n, out = accept_drafts(np.array([], np.int64), np.array([5]))
    assert n == 0 and out.tolist() == [5]
    n, out = accept_drafts(np.array([9, 9]), np.array([5, 7, 2]), n_draft=0)
    assert n == 0 and out.tolist() == [5]


def test_accept_drafts_seeded_sweep():
    """Random sweep: n is the longest agreeing prefix, the emission is
    exactly greedy[:n+1] (so every emitted token is verifier-endorsed),
    and n_draft truncation behaves as if the tail was never drafted."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        k = int(rng.integers(0, 6))
        drafts = rng.integers(0, 4, size=k)
        greedy = rng.integers(0, 4, size=k + 1)
        nd = int(rng.integers(0, k + 1))
        n, out = accept_drafts(drafts, greedy, n_draft=nd)
        want = 0
        while want < nd and drafts[want] == greedy[want]:
            want += 1
        assert n == want
        assert out.tolist() == greedy[:n + 1].tolist()
        # truncation == physically shorter draft
        n2, out2 = accept_drafts(drafts[:nd], greedy[:nd + 1])
        assert n2 == n and out2.tolist() == out.tolist()


def test_accept_drafts_validation():
    with pytest.raises(ValueError):
        accept_drafts(np.array([1, 2]), np.array([1, 2]))   # needs k+1
    with pytest.raises(ValueError):
        accept_drafts(np.array([1]), np.array([1, 2]), n_draft=2)
    with pytest.raises(ValueError):
        accept_drafts(np.array([1]), np.array([1, 2]), n_draft=-1)


def test_parse_speculate_arg():
    assert parse_speculate_arg("4") == SpeculativeConfig(k=4,
                                                         draft="turbo")
    assert parse_speculate_arg("2,balanced") == SpeculativeConfig(
        k=2, draft="balanced")
    for bad in ("", "x", "-1", "3,turbo,extra"):
        with pytest.raises(ValueError):
            parse_speculate_arg(bad)
    with pytest.raises(ValueError):
        SpeculativeConfig(k=-1)


# ------------------------------------------------- bit-identity contract


def test_spec_bit_identity_slot_mixed_tiers(dense_setup, slot_runtime):
    """Slot layout, mixed effort tiers, per-request caps, EOS, and a
    sampled row: speculation on == off, bitwise, and the stats line
    proves real drafting happened."""
    cfg, _ = dense_setup
    off, _ = drive(slot_runtime, make_requests(cfg), None)
    on, sched = drive(slot_runtime, make_requests(cfg), SPEC)
    assert sorted(on) == sorted(off)
    for rid in off:
        assert on[rid].tokens == off[rid].tokens, rid
        assert on[rid].status == off[rid].status, rid
    ss = sched.speculative_stats()
    assert ss["spec_ticks"] > 0
    assert sum(r["accepted"] for r in ss["plans"]) > 0
    # a degraded/clamped draft is never denser than its verify plan
    for i, p in enumerate(sched.plans):
        di = int(sched._draft_plan_for[i])
        assert sched.plans[di].flop_frac() <= p.flop_frac() + 1e-9
    assert_pools_whole(sched)


def test_spec_bit_identity_paged(dense_setup, paged_runtime):
    """Paged layout with an oversubscribed heap: speculative page
    growth, rollback of rejected tail pages, and preemption interact —
    outputs stay bitwise equal and the page accounting exact."""
    cfg, _ = dense_setup
    kw = dict(page_size=PAGE, n_pages=60)
    off, s_off = drive(paged_runtime, make_requests(cfg), None, **kw)
    on, sched = drive(paged_runtime, make_requests(cfg), SPEC, **kw)
    for rid in off:
        assert on[rid].tokens == off[rid].tokens, rid
    assert sched.speculative_stats()["spec_ticks"] > 0
    assert_pools_whole(sched)
    assert_pools_whole(s_off)


def test_spec_bit_identity_moe():
    """MoE architecture through the same chunk-scored entries."""
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    runtime = make_runtime(cfg, params, plans=make_plans(cfg))
    reqs = make_requests(cfg, seed=5)[:4]
    off, _ = drive(runtime, reqs, None)
    on, sched = drive(runtime, make_requests(cfg, seed=5)[:4], SPEC)
    for rid in off:
        assert on[rid].tokens == off[rid].tokens, rid
    assert sched.speculative_stats()["spec_ticks"] > 0


def test_spec_k0_is_the_plain_tick(dense_setup, slot_runtime):
    """k=0 degenerates to the non-speculative scheduler: same path,
    same outputs, no speculation stats."""
    cfg, _ = dense_setup
    off, s_off = drive(slot_runtime, make_requests(cfg), None)
    on, sched = drive(slot_runtime, make_requests(cfg),
                      SpeculativeConfig(k=0))
    for rid in off:
        assert on[rid].tokens == off[rid].tokens, rid
    assert sched.speculative_stats() is None
    assert sched.n_spec_ticks == 0
    assert sched.n_decode_steps == s_off.n_decode_steps


def test_spec_batch_composition_independence(dense_setup, slot_runtime):
    """A request's speculative emission is independent of its pad-row /
    neighbor composition: served alone it generates exactly what it
    generates inside a full mixed batch."""
    cfg, _ = dense_setup
    reqs = make_requests(cfg)
    batched, _ = drive(slot_runtime, reqs, SPEC, n_slots=4)
    for proto in make_requests(cfg)[:3]:
        solo, _ = drive(slot_runtime, [proto], SPEC, n_slots=1)
        assert solo[proto.rid].tokens == batched[proto.rid].tokens


def test_spec_fewer_decode_ticks(dense_setup, slot_runtime):
    """The structural win: same tokens from strictly fewer decode ticks
    when the draft tier is sparser than (or equal to) the verify tier."""
    cfg, _ = dense_setup
    reqs = [r for r in make_requests(cfg) if r.speculate != 0
            and r.temperature == 0]
    off, s_off = drive(slot_runtime, reqs, None)
    on, s_on = drive(slot_runtime,
                     [r for r in make_requests(cfg) if r.speculate != 0
                      and r.temperature == 0], SPEC)
    assert s_on.n_decode_steps < s_off.n_decode_steps
    gen = sum(len(o.tokens) for o in on.values())
    assert (gen / s_on.n_decode_steps
            > sum(len(o.tokens) for o in off.values())
            / s_off.n_decode_steps)


# ----------------------------------------------- rollback leak regressions


@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_spec_eos_mid_speculation_no_leak(dense_setup, slot_runtime,
                                          paged_runtime, layout):
    """A request hitting EOS in the middle of an accepted chunk stops
    at the EOS token, frees everything, and leaks nothing."""
    cfg, _ = dense_setup
    runtime = slot_runtime if layout == "slot" else paged_runtime
    kw = {} if layout == "slot" else dict(page_size=PAGE, n_pages=60)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 40).tolist()

    ref, _ = drive(runtime, [Request(rid=0, prompt=prompt, max_new=16)],
                   None, **kw)
    eos = ref[0].tokens[4]          # falls mid-chunk with k=3
    stop = ref[0].tokens.index(eos) + 1

    outs, sched = drive(runtime,
                        [Request(rid=0, prompt=prompt, max_new=16,
                                 eos_id=int(eos))], SPEC, **kw)
    assert outs[0].tokens == ref[0].tokens[:stop]
    assert outs[0].tokens[-1] == eos
    assert sched.n_eos_stops == 1
    assert_pools_whole(sched)


@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_spec_timeout_mid_flight_no_leak(dense_setup, slot_runtime,
                                         paged_runtime, layout):
    """Deadline expiry while a request is mid-speculation frees its
    slot/pages exactly once (fake clock: decode starts, then time jumps
    past the deadline)."""
    cfg, _ = dense_setup
    runtime = slot_runtime if layout == "slot" else paged_runtime
    kw = {} if layout == "slot" else dict(page_size=PAGE, n_pages=60)
    clk = [0.0]
    sched = ContinuousBatchingScheduler(
        runtime, n_slots=2, cache_len=160, prefill_batch=2,
        speculative=SPEC, clock=lambda: clk[0],
        sleep=lambda dt: clk.__setitem__(0, clk[0] + dt), **kw)
    sched.warmup()
    rng = np.random.default_rng(4)
    sched.submit(Request(rid=0,
                         prompt=rng.integers(0, cfg.vocab, 40).tolist(),
                         max_new=32, deadline_ms=500.0))
    while not any(s.phase == "decode" for s in sched.active.values()):
        sched.tick()
    sched.tick()                    # at least one speculative tick ran
    assert sched.n_spec_ticks >= 1
    clk[0] += 10.0                  # blow the deadline mid-generation
    outs = sched.run()
    assert outs[0].status == "timed_out"
    assert sched.n_timed_out == 1
    assert_pools_whole(sched)


@pytest.mark.parametrize("layout", ["slot", "paged"])
@pytest.mark.parametrize("seed", [0, 1])
def test_spec_chaos_preemption_no_leak(dense_setup, slot_runtime,
                                       paged_runtime, layout, seed):
    """Seeded chaos (forced preemptions, pool pressure, aborts) over a
    speculative stream: survivors bit-identical to the fault-free
    NON-speculative run, pools whole, compile counts flat."""
    cfg, _ = dense_setup
    runtime = slot_runtime if layout == "slot" else paged_runtime
    kw = {} if layout == "slot" else dict(page_size=PAGE, n_pages=60)
    ref, _ = drive(runtime, make_requests(cfg), None, **kw)
    inj = FaultInjector(seed=seed, p_preempt=0.4, p_pressure=0.4,
                        p_slow=0.2, p_abort=0.1, max_aborts=1)
    outs, sched = drive(runtime, make_requests(cfg), SPEC,
                        faults=inj, **kw)
    assert sorted(outs) == sorted(ref)
    for rid, out in outs.items():
        assert out.status in ("ok", "cancelled")
        if out.status == "ok":
            assert out.tokens == ref[rid].tokens, rid
    if layout == "paged":
        assert sched.n_preemptions + inj.stats()["forced_preempts"] > 0
    assert_pools_whole(sched)


def test_spec_published_prefix_pages_never_truncated(dense_setup,
                                                     paged_runtime,
                                                     monkeypatch):
    """Speculative rollback only ever drops exclusively-owned uncached
    decode-tail pages — a published (prefix-cached) or shared page is
    never unmapped by a rollback, and followers mapping the cached
    prefix stay bit-identical. The heap is roomy, so every unmap_tail
    during this run IS a speculative rollback (the COW dry-heap
    fallback cannot fire)."""
    cfg, _ = dense_setup
    pool_kw = dict(page_size=PAGE, n_pages=120, prefix_cache=True)
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab, 64).tolist()     # 8 pages
    prompts = [prefix + rng.integers(0, cfg.vocab, 16).tolist()
               for _ in range(3)]

    def run(speculative, guard=None):
        sched = ContinuousBatchingScheduler(
            paged_runtime, n_slots=3, cache_len=160, prefill_batch=2,
            speculative=speculative, **pool_kw)
        sched.warmup()
        if guard is not None:
            guard(sched.pool)
        # leader first (publishes the prefix), then followers
        sched.submit(Request(rid=0, prompt=prompts[0], max_new=12))
        sched.run()
        for i in (1, 2):
            sched.submit(Request(rid=i, prompt=prompts[i], max_new=12))
        sched.run()
        return sched

    rollbacks = []

    def guard(pool):
        orig = pool.unmap_tail

        def checked(slot, n):
            base = int(pool.allocated[slot])
            for j in range(base - n, base):
                pg = int(pool.page_table[slot, j])
                assert not pool.cached[pg], \
                    f"rollback truncated published page {pg}"
                assert pool.refcount[pg] == 1, \
                    f"rollback truncated shared page {pg}"
            rollbacks.append(n)
            return orig(slot, n)

        monkeypatch.setattr(pool, "unmap_tail", checked)

    ref = run(None)
    sched = run(SPEC, guard)
    assert rollbacks, "no speculative rollback exercised"
    for i in range(3):
        assert (sched.finished[i].tokens == ref.finished[i].tokens), i
    ps = sched.prefix_stats()
    assert ps["requests_hit"] >= 2      # followers really mapped it
    sched.prefix_index.clear()
    ref.prefix_index.clear()
    assert_pools_whole(sched)
    assert_pools_whole(ref)
