"""Continuous-batching serving stack: slot pool reuse, mid-flight
admission of ragged requests, bit-equivalence with the legacy static
engine, and the zero-recompilation invariant."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, Engine, KVSlotPool,
                           Request, StaticEngine)
from repro.serving.runtime import DenseRuntime, MoeRuntime, make_runtime


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def make_prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(n)).tolist() for n in lengths]


# ------------------------------------------------------------- slot pool


def test_pool_acquire_release_reuse(dense_setup):
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    pool = KVSlotPool.create(runtime, n_slots=3, cache_len=64)
    a = pool.acquire()
    b = pool.acquire()
    assert {a, b} == {0, 1} and pool.n_free == 1
    pool.release(a)
    c = pool.acquire()
    d = pool.acquire()
    assert d == a          # FIFO reuse: freed slot returns after slot 2
    assert pool.n_free == 0 and pool.acquire() is None
    assert pool.total_acquires == 4 and pool.total_releases == 1


def test_pool_double_release_idempotent(dense_setup):
    """Release is idempotent per request (satellite bugfix): scheduler
    paths that free a slot mid-tick can race a second release — it must
    neither double-count stats nor re-append the slot to the free
    list. Out-of-range slots are still rejected."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    pool = KVSlotPool.create(runtime, n_slots=2, cache_len=64)
    s = pool.acquire()
    pool.release(s)
    pool.release(s)                       # no-op, not an error
    assert pool.total_releases == 1
    assert pool.n_free == 2               # no duplicate free-list entry
    assert pool.acquire() is not None and pool.acquire() is not None
    assert pool.acquire() is None
    with pytest.raises(ValueError):
        pool.release(99)


def test_slot_reuse_after_completion(dense_setup):
    """More requests than slots: every request completes, slots are
    recycled through the free list, and concurrency never exceeds the
    pool capacity."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=2, cache_len=128)
    prompts = make_prompts(cfg, [20, 45, 33, 64, 17])
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    outs = sched.run()
    assert sorted(outs) == list(range(5))
    assert all(len(o.tokens) == 4 for o in outs.values())
    assert sched.pool.total_acquires == 5          # 3 reuses of 2 slots
    assert sched.pool.max_in_use <= 2
    assert sched.pool.n_free == 2                  # all returned


# -------------------------------------------------- mid-flight admission


def test_ragged_midflight_admission(dense_setup):
    """A request submitted while another is mid-decode lands in a slot
    immediately and produces exactly the tokens it would have produced
    alone (per-request math is independent of batch composition)."""
    cfg, params = dense_setup
    cfg = cfg.with_ff(enabled=False)
    runtime = make_runtime(cfg, params)
    prompts = make_prompts(cfg, [50, 37], seed=3)

    # reference: each request alone
    solo = [
        ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=128)
        for _ in prompts]
    ref = []
    for s, p in zip(solo, prompts):
        s.submit(Request(rid=0, prompt=p, max_new=6))
        ref.append(s.run()[0].tokens)

    sched = ContinuousBatchingScheduler(runtime, n_slots=4, cache_len=128)
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=6))
    # drive request 0 into its decode phase...
    for _ in range(3):
        sched.tick()
    assert any(s.phase == "decode" for s in sched.active.values())
    # ...then admit request 1 mid-flight
    sched.submit(Request(rid=1, prompt=prompts[1], max_new=6))
    outs = sched.run()
    assert outs[0].tokens == ref[0]
    assert outs[1].tokens == ref[1]


# ------------------------------------------------------- bit-equivalence


def test_continuous_matches_static_greedy_ragged(dense_setup):
    """Greedy continuous-batched generation must be bit-identical to the
    legacy static-batch engine on the same ragged prompts (FastForward
    off: per-sequence dense-last semantics coincide)."""
    cfg, params = dense_setup
    cfg = cfg.with_ff(enabled=False)
    prompts = make_prompts(cfg, [70, 33, 64, 21], seed=4)
    st = StaticEngine(cfg, params).generate(prompts, max_new=8)
    ct = Engine(cfg, params, n_slots=2).generate(prompts, max_new=8)
    np.testing.assert_array_equal(st.tokens, ct.tokens)


def test_continuous_matches_static_greedy_fastforward(dense_setup):
    """With FastForward ON, equivalence holds when every prompt fills
    the same number of blocks (the static batch's dense-last block then
    coincides with each sequence's own last block)."""
    cfg, params = dense_setup
    N = cfg.ff.block_size
    prompts = make_prompts(cfg, [2 * N, 2 * N], seed=5)
    st = StaticEngine(cfg, params).generate(prompts, max_new=6)
    ct = Engine(cfg, params).generate(prompts, max_new=6)
    np.testing.assert_array_equal(st.tokens, ct.tokens)


def test_sliding_window_decode_semantics(dense_setup):
    """Sliding-window models keep their window during slot-pool decode
    (full-length cache, window as attention mask): continuous matches
    the static engine, and the window demonstrably changes the output
    vs. unwindowed attention."""
    cfg, params = dense_setup
    cfg = cfg.with_ff(enabled=False).with_(sliding_window=16)
    prompts = make_prompts(cfg, [60, 41], seed=11)
    st = StaticEngine(cfg, params).generate(prompts, max_new=8)
    ct = Engine(cfg, params).generate(prompts, max_new=8)
    np.testing.assert_array_equal(st.tokens, ct.tokens)
    full = Engine(cfg.with_(sliding_window=None), params).generate(
        prompts, max_new=8)
    assert not np.array_equal(ct.tokens, full.tokens)


# ------------------------------------------------------ no recompilation


def test_no_recompilation_after_warmup(dense_setup):
    """After one request has compiled the batched prefill-blocks and
    decode executables, any mix of prompt lengths, slots, offsets, pad
    rows, and mid-flight churn reuses them — the pool's shapes and the
    static prefill batch width are the contract."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=3, cache_len=160)
    assert sched.prefill_batch > 1        # batched entry is the default
    counts = sched.warmup()
    assert counts["prefill_block"] == 1 and counts["decode_step"] == 1
    # one executable per batched width bucket (widths 2..P)
    assert counts["prefill_blocks"] == len(sched.prefill_widths) - 1

    prompts = make_prompts(cfg, [10, 70, 64, 31, 100, 5], seed=6)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=5))
    sched.run()
    assert runtime.compile_counts() == counts


def test_no_recompilation_single_block_path(dense_setup):
    """prefill_batch=1 keeps the original one-block-per-tick entry
    compiled once as well (the batched path's baseline)."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=3, cache_len=160,
                                        prefill_batch=1)
    prompts = make_prompts(cfg, [70, 31, 100], seed=6)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    sched.run()
    counts = runtime.compile_counts()
    assert counts["prefill_block"] == 1 and counts["prefill_blocks"] == 0


# ------------------------------------------------- batched prefill ticks


def test_batched_prefill_matches_single_block_loop(dense_setup):
    """The batched prefill_blocks tick (P=4, ragged offsets, pad rows)
    must generate exactly the tokens of the PR-1 one-block-per-tick
    loop on the same workload — FastForward ON, so per-row dense
    first/last forcing and per-row tile selection are both exercised."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    N = runtime.block_size
    prompts = make_prompts(cfg, [3 * N, 2 * N, 17, N + 5, 4 * N], seed=9)

    def run(prefill_batch):
        sched = ContinuousBatchingScheduler(
            runtime, n_slots=4, cache_len=6 * N,
            prefill_batch=prefill_batch)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new=6))
        return sched.run(), sched

    single, s1 = run(1)
    batched, s4 = run(4)
    assert s1.n_prefill_ticks > s4.n_prefill_ticks   # ticks were drained
    assert s1.n_prefill_blocks == s4.n_prefill_blocks
    for rid in single:
        assert single[rid].tokens == batched[rid].tokens


def test_batched_prefill_fewer_ticks(dense_setup):
    """P pending requests advance one block EACH per tick: prefill of
    P single-block prompts completes in one tick, not P."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    N = runtime.block_size
    sched = ContinuousBatchingScheduler(runtime, n_slots=4,
                                        cache_len=2 * N, prefill_batch=4)
    prompts = make_prompts(cfg, [N, N - 3, N // 2, N], seed=10)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=2))
    sched.tick()
    assert sched.n_prefill_blocks == 4               # one tick, 4 blocks
    assert all(s.phase == "decode" for s in sched.active.values())
    sched.run()


# ------------------------------------------------------------- eos stops


def test_eos_frees_slot_early(dense_setup):
    """A request hitting its eos_id mid-generation finishes immediately
    (output truncated at eos), frees its slot for the queue, and the
    scheduler counts the early exit."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    N = runtime.block_size
    prompts = make_prompts(cfg, [40, 25, 33], seed=12)

    # reference run: find what token the first request emits at step 2
    # (greedy decode may revisit it earlier — stop at FIRST occurrence)
    ref = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=4 * N)
    ref.submit(Request(rid=0, prompt=prompts[0], max_new=32))
    ref_tokens = ref.run()[0].tokens
    eos = ref_tokens[2]
    expect_len = ref_tokens.index(eos) + 1

    sched = ContinuousBatchingScheduler(runtime, n_slots=1,
                                        cache_len=4 * N)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=32, eos_id=int(eos)))
    outs = sched.run()
    assert outs[0].tokens[-1] == eos and len(outs[0].tokens) == expect_len
    assert sched.n_eos_stops >= 1
    # early exits recycled the single slot through all three requests
    assert sched.pool.total_acquires == 3
    assert sorted(outs) == [0, 1, 2]


# ------------------------------------------------------------ moe + misc


def test_moe_routing_ignores_masked_tokens():
    """Masked (inactive-slot) tokens must not perturb a live token's
    routed output: it is identical to serving that token alone. The
    fixture makes the capacity hazard deterministic — 32 identical rows
    all route to the same top-k experts, exceeding capacity (C = 24 <
    32) — so under CAPACITY dispatch the mask is what keeps dead rows
    from evicting the live one, while under DROPLESS dispatch no token
    can evict another in the first place (mask or not)."""
    from repro.models.moe import capacity, moe_ffn_spec, routed_experts
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    mp = init_params(moe_ffn_spec(cfg, cfg.dtype), jax.random.key(2))
    B = 32
    assert capacity(B, cfg) < B     # overflow is reachable
    row = jax.random.normal(jax.random.key(3), (1, 1, cfg.d_model))
    x = jnp.tile(row, (B, 1, 1))
    mask = np.zeros((B, 1), bool)
    mask[-1] = True                 # only the last row is live

    cap = cfg.with_(moe_dispatch="capacity")
    y_solo, _ = routed_experts(mp, cap, x[-1:])
    y_masked, _ = routed_experts(mp, cap, x, token_mask=jnp.asarray(mask))
    y_unmasked, _ = routed_experts(mp, cap, x)
    np.testing.assert_allclose(np.asarray(y_masked[-1]),
                               np.asarray(y_solo[0]), rtol=1e-6, atol=1e-6)
    # sanity: without the mask the dead rows really do evict the live
    # row under capacity dispatch (otherwise the mask assertions above
    # would pass vacuously)
    assert not np.allclose(np.asarray(y_unmasked[-1]),
                           np.asarray(y_solo[0]), rtol=1e-3, atol=1e-4)

    # dropless (serving default): the overflow that evicts under
    # capacity dispatch cannot happen — the live row matches solo with
    # and WITHOUT the mask; masked rows get exactly zero routed output
    assert cfg.moe_dispatch == "dropless"
    d_solo, _ = routed_experts(mp, cfg, x[-1:])
    d_masked, _ = routed_experts(mp, cfg, x, token_mask=jnp.asarray(mask))
    d_unmasked, _ = routed_experts(mp, cfg, x)
    np.testing.assert_array_equal(np.asarray(d_masked[-1]),
                                  np.asarray(d_solo[0]))
    np.testing.assert_array_equal(np.asarray(d_unmasked[-1]),
                                  np.asarray(d_solo[0]))
    np.testing.assert_array_equal(np.asarray(d_masked[:-1]), 0.0)


def test_moe_runtime_serves():
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    runtime = make_runtime(cfg, params)
    assert isinstance(runtime, MoeRuntime)
    eng = Engine(cfg, params, n_slots=2)
    prompts = make_prompts(cfg, [40, 25, 33], seed=7)
    res = eng.generate(prompts, max_new=4)
    assert res.tokens.shape == (3, 4)
    assert res.generated_tokens == 12
    assert eng.runtime.compile_counts()["decode_step"] == 1


def test_runtime_dispatch(dense_setup):
    cfg, params = dense_setup
    assert isinstance(make_runtime(cfg, params), DenseRuntime)
    with pytest.raises(ValueError):
        make_runtime(cfg.with_(arch="ssm"), params)


def test_scheduler_sheds_oversized_request(dense_setup):
    """A well-formed request the pool can NEVER hold is shed at submit
    (status="shed" with a reason, zero device work) instead of raising
    — one oversized record no longer kills a whole trace replay.
    Malformed requests (caller bugs) still raise."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=64)
    sched.submit(Request(rid=0, prompt=list(range(1, 61)), max_new=32))
    out = sched.finished[0]
    assert out.status == "shed" and out.tokens == []
    assert "cache positions" in out.reason
    assert sched.n_shed == 1 and not sched.queue
    with pytest.raises(ValueError):
        sched.submit(Request(rid=1, prompt=[]))
    with pytest.raises(ValueError):
        sched.submit(Request(rid=2, prompt=[1, 2], max_new=0))


def test_temperature_sampling_stays_in_vocab(dense_setup):
    cfg, params = dense_setup
    eng = Engine(cfg, params)
    prompts = make_prompts(cfg, [30, 40], seed=8)
    res = eng.generate(prompts, max_new=5, temperature=0.8, seed=1)
    assert res.tokens.shape == (2, 5)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab).all()
