"""Paged KV-cache subsystem: page heap accounting, paged-vs-slot greedy
bit-equivalence (dense + both MoE archs), fragmentation/reuse churn,
preemption-and-re-prefill correctness, trace replay, and the
zero-recompilation invariant across page-table shapes."""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, Engine,
                           PagedKVPool, Request, drive_stream, load_trace)
from repro.serving.runtime import make_runtime

PAGE = 8                       # divides the reduced block size (32)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def make_prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(n)).tolist() for n in lengths]


def paged(cfg, page=PAGE):
    return cfg.with_(kv_layout="paged", kv_page_size=page)


# ------------------------------------------------------------- page pool


def test_page_pool_lazy_alloc_and_release(dense_setup):
    cfg, params = dense_setup
    runtime = make_runtime(paged(cfg), params)
    pool = PagedKVPool.create(runtime, n_pages=9, page_size=PAGE,
                              n_slots=2, max_pages=6)
    s = pool.acquire()
    assert pool.n_free_pages == 8          # page 0 reserved, none claimed
    assert pool.ensure(s, 3)
    assert pool.n_free_pages == 5 and pool.n_pages_in_use == 3
    assert pool.ensure(s, 3)               # idempotent growth
    assert pool.total_page_allocs == 3
    assert list(pool.page_table[s, :3]) == [1, 2, 3]
    assert pool.covers(s, 3 * PAGE - 1) and not pool.covers(s, 3 * PAGE)
    s2 = pool.acquire()
    assert pool.ensure(s2, 5)
    assert not pool.ensure(s, 5)           # 0 free left, delta 2 denied
    assert pool.allocated[s] == 3          # denied growth allocated NOTHING
    pool.release(s2)
    assert pool.n_free_pages == 5
    pool.release(s2)                       # idempotent: no double-free
    assert pool.n_free_pages == 5 and pool.total_releases == 1
    assert (pool.page_table[s2] == 0).all()
    assert pool.total_page_frees == 5


def test_page_pool_fits(dense_setup):
    cfg, params = dense_setup
    runtime = make_runtime(paged(cfg), params)
    pool = PagedKVPool.create(runtime, n_pages=5, page_size=PAGE,
                              n_slots=2, max_pages=8)
    assert pool.fits(4 * PAGE)             # 4 usable pages
    assert not pool.fits(5 * PAGE)         # heap can never back 5
    big = PagedKVPool.create(runtime, n_pages=64, page_size=PAGE,
                             n_slots=2, max_pages=4)
    assert not big.fits(5 * PAGE)          # table can never map 5


# ------------------------------------------------------- bit-equivalence


def test_paged_matches_slot_greedy_dense(dense_setup):
    """Greedy paged-engine output is bit-identical to the slot engine —
    FastForward ON, ragged lengths, slot churn (B > n_slots)."""
    cfg, params = dense_setup
    prompts = make_prompts(cfg, [70, 33, 64, 21, 90], seed=4)
    st = Engine(cfg, params, n_slots=2).generate(prompts, max_new=8)
    pg = Engine(paged(cfg), params, n_slots=2).generate(prompts, max_new=8)
    np.testing.assert_array_equal(st.tokens, pg.tokens)


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "kimi-k2-1t-a32b"])
def test_paged_matches_slot_greedy_moe(arch):
    """Both MoE architectures: the dropless dispatch stays dispatch-
    group invariant under the paged layout, so paged == slot bit-wise."""
    cfg = get_config(arch, reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    prompts = make_prompts(cfg, [40, 25, 33], seed=7)
    st = Engine(cfg, params, n_slots=2).generate(prompts, max_new=8)
    pg = Engine(paged(cfg), params, n_slots=2).generate(prompts, max_new=8)
    np.testing.assert_array_equal(st.tokens, pg.tokens)


# --------------------------------------------------- churn / page reuse


def test_page_reuse_under_churn(dense_setup):
    """A long stream through a small heap: pages recycle through many
    owners, the heap never leaks, and table hygiene holds at drain."""
    cfg, params = dense_setup
    runtime = make_runtime(paged(cfg), params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=3, cache_len=128,
                                        n_pages=25)
    prompts = make_prompts(cfg, [20, 45, 33, 64, 17, 80, 51, 9], seed=5)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    outs = sched.run()
    assert sorted(outs) == list(range(8))
    assert all(len(o.tokens) == 4 for o in outs.values())
    pool = sched.pool
    assert pool.n_free_pages == pool.n_pages - 1        # no page leaked
    assert (pool.page_table == 0).all()                 # tables reset
    assert pool.total_page_allocs == pool.total_page_frees
    assert pool.total_page_allocs > pool.n_pages - 1    # pages re-owned
    assert pool.total_acquires == pool.total_releases == 8
    assert pool.max_pages_in_use <= pool.n_pages - 1


def test_fragmentation_stranding_slot_vs_paged(dense_setup):
    """The headline memory claim, in miniature: short requests through
    a long-cache pool strand most of each slot but only a page-tail in
    the paged layout."""
    cfg, params = dense_setup
    prompts = make_prompts(cfg, [9, 17, 12], seed=6)

    def peak_stranded(run_cfg):
        runtime = make_runtime(run_cfg, params)
        sched = ContinuousBatchingScheduler(runtime, n_slots=3,
                                            cache_len=256)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new=2))
        sched.run()
        return sched.pool.stranded_tokens_at_peak

    assert peak_stranded(paged(cfg)) < peak_stranded(cfg) / 4


# ------------------------------------------------------------ preemption


def test_preemption_and_reprefill_correctness(dense_setup):
    """An oversubscribed heap preempts the youngest request when decode
    needs a page and the pool is dry; the evicted request re-prefills
    from scratch and still produces bit-identical greedy output."""
    cfg, params = dense_setup
    runtime = make_runtime(paged(cfg), params)
    # single-block prompts (4 pages each at admission) whose decode
    # growth reaches 6 pages: two admit side by side into 9 usable
    # pages, then their unreserved decode growth (12 pages total)
    # overflows the heap mid-generation — the decode-side preemption
    prompts = make_prompts(cfg, [30, 30, 28, 26], seed=3)

    def run(n_pages):
        sched = ContinuousBatchingScheduler(runtime, n_slots=4,
                                            cache_len=64, n_pages=n_pages)
        for i, p in enumerate(prompts):
            # request 1 samples: preemption must be output-transparent
            # for temperature > 0 too (per-request RNG streams replay
            # identically on re-prefill)
            sched.submit(Request(rid=i, prompt=p, max_new=16,
                                 temperature=0.8 if i == 1 else 0.0))
        return sched.run(), sched

    ample, s0 = run(None)                  # full backing: no pressure
    tight, s1 = run(10)                    # 9 usable pages = 72 tokens
    assert s0.n_preemptions == 0
    assert s1.n_preemptions >= 1
    for rid in ample:
        assert ample[rid].tokens == tight[rid].tokens
    assert s1.pool.n_free_pages == s1.pool.n_pages - 1
    assert s1.pool.total_acquires == s1.pool.total_releases


def test_oldest_request_never_preempted(dense_setup):
    """Only strictly-younger requests are evicted, so the stream always
    drains — even when every request fights for a minimal heap."""
    cfg, params = dense_setup
    runtime = make_runtime(paged(cfg), params)
    prompts = make_prompts(cfg, [64] * 4, seed=8)
    # 9 usable pages: one 64-tok prompt + 8 new tokens needs 9 pages,
    # so requests must run essentially one at a time via preemption
    sched = ContinuousBatchingScheduler(runtime, n_slots=4, cache_len=96,
                                        n_pages=10)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=8))
    outs = sched.run()
    assert sorted(outs) == [0, 1, 2, 3]
    assert all(len(o.tokens) == 8 for o in outs.values())


# ------------------------------------------------------ no recompilation


def test_no_recompilation_paged_churn(dense_setup):
    """compile_counts stay flat across a churny paged stream — varied
    prompt lengths, lazy page growth, preemption, EOS early exits: page
    tables and positions are traced values, so one executable per width
    bucket (incl. width 1) plus one paged decode serves everything."""
    cfg, params = dense_setup
    runtime = make_runtime(paged(cfg), params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=3, cache_len=160,
                                        n_pages=26)
    assert sched.prefill_batch > 1
    counts = sched.warmup()
    assert counts["decode_step_paged"] == 1
    assert counts["prefill_blocks_paged"] == len(sched.prefill_widths)
    assert counts["prefill_block"] == 0     # slot entries never compiled
    assert counts["decode_step"] == 0

    prompts = make_prompts(cfg, [10, 70, 64, 31, 100, 5, 120], seed=6)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=24, eos_id=7))
    sched.run()
    assert len(sched.finished) == 7
    assert sched.n_preemptions >= 1         # the stream really churned
    assert runtime.compile_counts() == counts


# ----------------------------------------------------------- trace replay


def test_trace_replay_deterministic(dense_setup, tmp_path):
    """load_trace: schema parsing, deterministic prompt synthesis, and
    end-to-end replay equivalence between slot and paged engines on the
    same trace."""
    cfg, params = dense_setup
    path = tmp_path / "t.jsonl"
    path.write_text(
        '# comment line\n'
        '{"arrival_s": 0.0, "prompt_len": 40, "gen_len": 4}\n'
        '{"arrival_s": 0.01, "prompt_len": 70, "gen_len": 6,'
        ' "extra_key": 1}\n'
        '{"arrival_s": 0.02, "prompt": [5, 6, 7], "gen_len": 3}\n')
    reqs = load_trace(str(path), cfg.vocab, seed=0)
    reqs2 = load_trace(str(path), cfg.vocab, seed=0)
    assert [r.prompt for r in reqs] == [r.prompt for r in reqs2]
    assert reqs[2].prompt == [5, 6, 7]
    assert [r.max_new for r in reqs] == [4, 6, 3]

    def serve(run_cfg):
        runtime = make_runtime(run_cfg, params)
        sched = ContinuousBatchingScheduler(runtime, n_slots=2,
                                            cache_len=104)
        drive_stream(sched, load_trace(str(path), cfg.vocab, seed=0))
        return {r: o.tokens for r, o in sched.finished.items()}

    assert serve(cfg) == serve(paged(cfg))


def test_sample_trace_loads():
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "traces", "sample_trace.jsonl")
    reqs = load_trace(path, vocab=512)
    assert len(reqs) == 24
    assert all(r.max_new >= 1 and len(r.prompt) >= 1 for r in reqs)
    arr = [r.arrival_time for r in reqs]
    assert arr == sorted(arr)


# ------------------------------------------------- release idempotency


def test_release_stats_balanced_after_eos_churn(dense_setup):
    """Regression (satellite bugfix): release is idempotent per request,
    so total_releases == total_acquires after a churny EOS-early-stop
    stream — for BOTH pool layouts."""
    cfg, params = dense_setup
    prompts = make_prompts(cfg, [40, 25, 33, 51, 18, 60], seed=12)

    for run_cfg in (cfg, paged(cfg)):
        runtime = make_runtime(run_cfg, params)
        sched = ContinuousBatchingScheduler(runtime, n_slots=2,
                                            cache_len=128)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new=16, eos_id=7))
        outs = sched.run()
        assert len(outs) == 6
        pool = sched.pool
        assert pool.total_acquires == pool.total_releases == 6
        assert pool.n_free == 2
        # double releases are silently absorbed, never double-counted
        pool.release(0)
        assert pool.total_releases == 6
