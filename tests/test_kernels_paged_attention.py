"""Paged-attention kernel package: gather-based page-table path vs the
masked dense oracle vs the interpret-mode Pallas kernel, and the
bit-level contract the paged serving engine relies on — the gathered
page view attends identically to a contiguous slot cache."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel as K
from repro.kernels.paged_attention import ops as O
from repro.kernels.paged_attention import ref as R
from repro.nn import attention as A


def _setup(seed=0, B=3, H=4, Kv=2, dh=8, psz=4, max_pages=5,
           positions=(9, 5, 18)):
    """A pool where each row owns disjoint pages covering its positions
    and the tails point at the null page 0."""
    rng = np.random.default_rng(seed)
    positions = np.asarray(positions, np.int32)
    n_pages = 1 + int(sum(p // psz + 1 for p in positions))
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    k_pages = jnp.asarray(rng.standard_normal((n_pages, psz, Kv, dh)),
                          jnp.float32)
    v_pages = jnp.asarray(rng.standard_normal((n_pages, psz, Kv, dh)),
                          jnp.float32)
    table = np.zeros((B, max_pages), np.int32)
    nxt = 1
    for b, p in enumerate(positions):
        n = p // psz + 1
        table[b, :n] = np.arange(nxt, nxt + n)
        nxt += n
    return q, k_pages, v_pages, jnp.asarray(table), jnp.asarray(positions)


def test_gather_ref_matches_dense_oracle():
    q, kp, vp, tbl, pos = _setup()
    got = R.paged_attention_ref(q, kp, vp, tbl, pos)
    want = R.paged_attention_dense_ref(q, kp, vp, tbl, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kernel_interpret_matches_both_oracles():
    q, kp, vp, tbl, pos = _setup(seed=1)
    kern = O.paged_attention_op(q, kp, vp, tbl, pos, use_kernel=True)
    ref = O.paged_attention_op(q, kp, vp, tbl, pos, use_kernel=False)
    dense = R.paged_attention_dense_ref(q, kp, vp, tbl, pos)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_kernel_sliding_window():
    q, kp, vp, tbl, pos = _setup(seed=2, positions=(11, 6, 19))
    for window in (4, 7):
        kern = K.paged_decode_attention(q, kp, vp, tbl, pos,
                                        window=window, interpret=True)
        ref = R.paged_attention_ref(q, kp, vp, tbl, pos, window=window)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
    # the window demonstrably changes the answer
    full = R.paged_attention_ref(q, kp, vp, tbl, pos)
    win = R.paged_attention_ref(q, kp, vp, tbl, pos, window=4)
    assert not np.allclose(np.asarray(full), np.asarray(win))


def test_dead_page_bytes_never_read():
    """DMA-skip contract: grid steps for DEAD pages (past the decode
    position, or fully behind the sliding window) clamp their K/V
    index map onto a live page, so dead table entries' pages are never
    fetched and their BYTES cannot influence the output. Poison a page
    with NaN/garbage, point every dead table entry at it, and the
    kernel output is unchanged; poisoning a LIVE entry changes it
    (the poison is potent, so the invariance is meaningful)."""
    q, kp, vp, tbl, pos = _setup(seed=6, max_pages=6,
                                 positions=(9, 5, 18))
    psz = kp.shape[1]
    poison = kp.shape[0]                   # append one poisoned page
    kp_p = jnp.concatenate(
        [kp, jnp.full((1,) + kp.shape[1:], jnp.nan, kp.dtype)])
    vp_p = jnp.concatenate(
        [vp, jnp.full((1,) + vp.shape[1:], 1e30, vp.dtype)])
    tbl_clean = np.asarray(tbl).copy()
    tbl = tbl_clean.copy()
    for b, p in enumerate(np.asarray(pos)):
        tbl[b, int(p) // psz + 1:] = poison   # dead null tail -> poison
    want = R.paged_attention_ref(q, kp, vp, jnp.asarray(tbl_clean), pos)

    got = K.paged_decode_attention(q, kp_p, vp_p, jnp.asarray(tbl), pos,
                                   interpret=True)
    clean = K.paged_decode_attention(q, kp, vp, jnp.asarray(tbl_clean),
                                     pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(clean))
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    # sliding window: pages fully behind the window are dead too
    window = psz + 1
    tbl_w = tbl.copy()
    for b, p in enumerate(np.asarray(pos)):
        lo = max(0, (int(p) - window + 1) // psz)
        tbl_w[b, :lo] = poison             # behind-window pages -> poison
    got_w = K.paged_decode_attention(q, kp_p, vp_p, jnp.asarray(tbl_w),
                                     pos, window=window, interpret=True)
    ref_w = R.paged_attention_ref(q, kp, vp, jnp.asarray(tbl_clean), pos,
                                  window=window)
    assert np.isfinite(np.asarray(got_w)).all()
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(ref_w),
                               rtol=1e-5, atol=1e-5)

    # sanity: the same poison on a LIVE entry corrupts the output —
    # the invariance above is not vacuous
    tbl_live = tbl.copy()
    tbl_live[0, 0] = poison
    bad = K.paged_decode_attention(q, kp_p, vp_p, jnp.asarray(tbl_live),
                                   pos, interpret=True)
    assert not np.allclose(np.asarray(bad), np.asarray(clean),
                           equal_nan=False)


def test_gathered_pages_bit_match_contiguous_cache():
    """The serving contract: writing KV through page tables and
    attending the gathered view is BIT-identical to the slot layout's
    contiguous cache — not merely allclose."""
    rng = np.random.default_rng(3)
    B, S, Kv, dh, psz = 2, 24, 2, 8, 4
    mp = S // psz
    kc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    # scatter the contiguous rows into a shuffled page pool
    n_pages = 1 + B * mp
    perm = rng.permutation(np.arange(1, n_pages))
    table = np.zeros((B, mp), np.int32)
    k_pool = np.zeros((n_pages, psz, Kv, dh), np.float32)
    v_pool = np.zeros((n_pages, psz, Kv, dh), np.float32)
    for b in range(B):
        for j in range(mp):
            pid = int(perm[b * mp + j])
            table[b, j] = pid
            k_pool[pid] = np.asarray(kc[b, j * psz:(j + 1) * psz])
            v_pool[pid] = np.asarray(vc[b, j * psz:(j + 1) * psz])
    gk, gv = A.gather_kv_pages(jnp.asarray(k_pool), jnp.asarray(v_pool),
                               jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(kc))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(vc))


def test_paged_write_then_gather_roundtrip():
    """write_kv_rows_paged / write_kv_tok_paged land block and token
    writes exactly where the slot-layout writers would, including the
    active-mask self-copy for pad rows."""
    rng = np.random.default_rng(4)
    B, N, Kv, dh, psz, mp = 3, 8, 2, 4, 4, 6
    n_pages = 1 + B * mp
    k_pool = jnp.zeros((n_pages, psz, Kv, dh), jnp.float32)
    v_pool = jnp.zeros((n_pages, psz, Kv, dh), jnp.float32)
    table = np.zeros((B, mp), np.int32)
    table[0, :mp] = np.arange(1, mp + 1)
    table[1, :mp] = np.arange(mp + 1, 2 * mp + 1)
    # row 2 is an inactive pad row: all-null table
    pos0s = jnp.asarray([0, 8, 0], jnp.int32)
    active = jnp.asarray([True, True, False])
    k_new = jnp.asarray(rng.standard_normal((B, N, Kv, dh)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, N, Kv, dh)), jnp.float32)
    k_pool, v_pool = A.write_kv_rows_paged(
        k_pool, v_pool, k_new, v_new, jnp.asarray(table), pos0s,
        active=active)
    gk, gv = A.gather_kv_pages(k_pool, v_pool, jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(gk[0, :N]),
                                  np.asarray(k_new[0]))
    np.testing.assert_array_equal(np.asarray(gv[1, 8:8 + N]),
                                  np.asarray(v_new[1]))
    # the pad row wrote nothing: the null page is still zeros
    np.testing.assert_array_equal(np.asarray(k_pool[0]), 0.0)

    # the single-request wrapper lands the identical block write
    k2, v2 = A.write_kv_block_paged(
        jnp.zeros_like(k_pool), jnp.zeros_like(v_pool),
        k_new[:1], v_new[:1], jnp.asarray(table[0]), jnp.int32(0))
    gk1, _ = A.gather_kv_pages(k2, v2, jnp.asarray(table[:1]))
    np.testing.assert_array_equal(np.asarray(gk1[0, :N]),
                                  np.asarray(k_new[0]))

    # single-token decode write at position 11 of row 1
    tok_k = jnp.asarray(rng.standard_normal((B, 1, Kv, dh)), jnp.float32)
    tok_v = jnp.asarray(rng.standard_normal((B, 1, Kv, dh)), jnp.float32)
    positions = jnp.asarray([3, 11, 0], jnp.int32)
    k_pool, v_pool = A.write_kv_tok_paged(
        k_pool, v_pool, tok_k, tok_v, jnp.asarray(table), positions,
        active=jnp.asarray([False, True, False]))
    gk2, _ = A.gather_kv_pages(k_pool, v_pool, jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(gk2[1, 11]),
                                  np.asarray(tok_k[1, 0]))
    # inactive row 0's cache is untouched at its masked position
    np.testing.assert_array_equal(np.asarray(gk2[0, 3]),
                                  np.asarray(gk[0, 3]))


def test_attend_decode_ragged_paged_bit_matches_slot():
    """attend_decode_ragged_paged (XLA gather dispatch) is bit-identical
    to attend_decode_ragged over the equivalent contiguous cache."""
    rng = np.random.default_rng(5)
    B, S, Kv, H, dh, psz = 2, 16, 2, 4, 8, 4
    mp = S // psz
    params = {
        "wq": jnp.asarray(rng.standard_normal((16, H, dh)) * 0.1,
                          jnp.float32),
        "wk": jnp.asarray(rng.standard_normal((16, Kv, dh)) * 0.1,
                          jnp.float32),
        "wv": jnp.asarray(rng.standard_normal((16, Kv, dh)) * 0.1,
                          jnp.float32),
        "wo": jnp.asarray(rng.standard_normal((H, dh, 16)) * 0.1,
                          jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((B, 1, 16)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    positions = jnp.asarray([13, 6], jnp.int32)

    n_pages = 1 + B * mp
    table = np.zeros((B, mp), np.int32)
    k_pool = np.zeros((n_pages, psz, Kv, dh), np.float32)
    v_pool = np.zeros((n_pages, psz, Kv, dh), np.float32)
    nxt = 1
    for b in range(B):
        for j in range(mp):
            table[b, j] = nxt
            k_pool[nxt] = np.asarray(kc[b, j * psz:(j + 1) * psz])
            v_pool[nxt] = np.asarray(vc[b, j * psz:(j + 1) * psz])
            nxt += 1

    want = A.attend_decode_ragged(params, x, kc, vc, positions)
    got = A.attend_decode_ragged_paged(
        params, x, jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(table), positions, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the interpret-mode kernel agrees numerically
    kern = A.attend_decode_ragged_paged(
        params, x, jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(table), positions, use_kernel=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
