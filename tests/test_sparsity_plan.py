"""SparsityPlan: Algorithm 1 on the FLOP-reducing path.

Covers the plan object itself (largest-remainder rounding, effort
tiers, width re-derivation), the per-layer/per-row `k_valid` masking
on the gather path and the batched Pallas kernel (interpret mode) vs
the mask-path oracle, the backward-compat shim (cfg-only configs are
bit-identical to an explicit uniform plan), and the serving contract:
mixed-effort streams keep compile_counts flat and every request's
greedy output depends only on its OWN plan.
"""
import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import fastforward as FF
from repro.core import predictor as P
from repro.core import scheduler as SCHED
from repro.core import sparse_ffn as S
from repro.core.scheduler import SparsityPlan
from repro.models.base import ModelConfig, FastForwardConfig
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, Engine, Request,
                           StaticEngine, load_trace)
from repro.serving.runtime import make_runtime
from repro.serving.trace import trace_stats


CFG = ModelConfig(name="t", arch="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab=101,
                  remat=False,
                  ff=FastForwardConfig(enabled=True, tile=64,
                                       block_size=32))


@pytest.fixture(scope="module")
def ffn_params():
    return init_params(FF.fastforward_ffn_spec(CFG), jax.random.key(0))


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def make_prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(n)).tolist() for n in lengths]


# --------------------------------------------- budgets_to_tiles (satellite)


def test_budgets_to_tiles_largest_remainder_regression():
    """Independent per-layer round() drifts the realized total away
    from the global budget; largest-remainder pins it exactly."""
    budgets = np.array([0.3, 0.55, 0.55, 0.6])
    n_tiles = 8
    target = int(round(budgets.sum() * n_tiles))          # 16
    old = np.maximum(1, np.round(budgets * n_tiles)).astype(int)
    assert old.sum() != target                            # the bug
    counts = SCHED.budgets_to_tiles(budgets, n_tiles)
    assert counts.sum() == target
    assert counts.min() >= 1 and counts.max() <= n_tiles


def test_budgets_to_tiles_total_exact_random():
    rng = np.random.default_rng(0)
    for n_tiles in (2, 4, 8, 16):
        for L in (1, 3, 7, 22):
            b = rng.uniform(0.0, 1.0, size=L)
            counts = SCHED.budgets_to_tiles(b, n_tiles)
            target = int(np.clip(round(b.sum() * n_tiles), L, L * n_tiles))
            assert counts.sum() == target
            assert counts.min() >= 1 and counts.max() <= n_tiles


def test_budgets_to_tiles_respects_floor_and_cap():
    # all-zero budgets still keep one tile per layer
    counts = SCHED.budgets_to_tiles(np.zeros(5), 8)
    assert np.all(counts == 1)
    # all-one budgets cap at n_tiles
    counts = SCHED.budgets_to_tiles(np.ones(5), 8)
    assert np.all(counts == 8)


# ---------------------------------------- allocate_budgets (satellite)


def test_allocate_budgets_all_zero_importance_is_uniform():
    b = SCHED.allocate_budgets(np.zeros(6), 0.4)
    np.testing.assert_allclose(b, 0.4, atol=1e-9)


def test_allocate_budgets_single_spike_clips_and_redistributes():
    s = np.zeros(4)
    s[2] = 7.0
    b = SCHED.allocate_budgets(s, 0.5)
    assert b[2] == 1.0                       # the spike is clipped dense
    # the remaining budget is spread over the zero-importance layers
    np.testing.assert_allclose(b.sum(), 0.5 * 4, atol=1e-9)
    others = np.delete(b, 2)
    np.testing.assert_allclose(others, others[0], atol=1e-9)


# ------------------------------------------------- plan construction


def test_uniform_plan_matches_k_tiles_for():
    """The compat shim: cfg-only resolution == the legacy scalar."""
    for sparsity in (0.25, 0.5, 0.75):
        for shards in (1, 2):
            cfg = CFG.with_ff(sparsity=sparsity)
            plan = FF.resolve_plan(cfg, shards=shards)
            assert plan.is_uniform
            assert plan.k_max == FF.k_tiles_for(cfg, shards=shards)


def test_effort_tiers():
    bal = FF.resolve_plan(CFG, effort="balanced")
    dense = FF.resolve_plan(CFG, effort="dense")
    turbo = FF.resolve_plan(CFG, effort="turbo")
    assert dense.k_max == CFG.d_ff // CFG.ff.tile        # all tiles
    assert turbo.k_max < bal.k_max <= dense.k_max
    assert turbo.flop_frac() < bal.flop_frac() < dense.flop_frac()
    with pytest.raises(ValueError):
        FF.resolve_plan(CFG, effort="warp")


def test_layerwise_plan_from_importance():
    importance = np.array([1.0, 1.0, 1.0, 5.0])
    plan = FF.resolve_plan(CFG, importance=importance)
    assert not plan.is_uniform
    assert plan.tile_counts[3] > plan.tile_counts[0]
    # equal global budget: total tiles match the uniform budget exactly
    n_tiles = CFG.d_ff // CFG.ff.tile
    assert sum(plan.tile_counts) == round(0.5 * CFG.n_layers * n_tiles)


def test_with_tiles_rederivation():
    plan = SparsityPlan.from_budgets([0.25, 0.5, 0.5, 0.75], 8, 64)
    small = plan.with_tiles(4)
    assert small.n_tiles == 4 and small.n_layers == 4
    assert sum(small.tile_counts) == round(np.sum(plan.keep_fracs) * 4)
    # uniform plans reapply the legacy ceil rule (MoE shared expert)
    uni = SparsityPlan.uniform(4, 8, 64, keep=0.55)
    assert uni.with_tiles(4).k_max == int(np.ceil(0.55 * 4))
    assert plan.with_tiles(8) is plan


def test_plan_is_hashable_static_key():
    a = FF.resolve_plan(CFG, effort="balanced")
    b = FF.resolve_plan(CFG, effort="balanced")
    c = FF.resolve_plan(CFG, effort="turbo")
    assert a == b and hash(a) == hash(b) and a != c


# ---------------------------- k_valid: gather + kernel vs mask oracle


def test_k_valid_gather_matches_mask_oracle(ffn_params):
    """Masked top-k_max prefix == the top-k mask path, per count."""
    x = jax.random.normal(jax.random.key(5), (2, 32, 64))
    scores = jax.nn.sigmoid(P.neuron_scores(ffn_params["pred"], x))
    n_tiles = 8
    ids = S.balanced_topk_tiles(scores, n_tiles, 64)      # [2, 8]
    for k in (1, 3, 5, 8):
        y_g = S.ffn_sparse_batched(ffn_params, x, ids, 64, "silu",
                                   k_valid=jnp.int32(k))
        mask = S.mask_from_tile_ids(ids[:, :k], n_tiles, 64)
        y_m = S.ffn_masked(ffn_params, x, mask[:, None, :], "silu")
        np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_m),
                                   rtol=1e-4, atol=1e-5)


def test_k_valid_batched_kernel_interpret_cross_check():
    """Per-row counts on the batched Pallas kernel (interpret mode) vs
    the XLA gather path vs per-row prefix gathers — distinct counts
    per row, the mixed-effort decode contract."""
    from repro.kernels.sparse_ffn.ops import sparse_ffn_batched_op
    from repro.kernels.sparse_ffn.ref import sparse_ffn_batched_ref
    rng = np.random.default_rng(7)
    B, N, D, F, tile = 3, 32, 64, 512, 64
    x = jnp.asarray(rng.normal(size=(B, N, D)), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(F, D)) * 0.1, jnp.float32)
    ids = jnp.asarray(
        np.stack([rng.choice(F // tile, size=5, replace=False)
                  for _ in range(B)]), jnp.int32)
    counts = jnp.asarray([1, 3, 5], jnp.int32)            # distinct rows
    y_int = sparse_ffn_batched_op(x, wg, wu, wd, ids, tile=tile,
                                  use_kernel=True, k_valid=counts)
    y_cpu = sparse_ffn_batched_op(x, wg, wu, wd, ids, tile=tile,
                                  use_kernel=False, k_valid=counts)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_cpu),
                               rtol=1e-5, atol=1e-5)
    for b in range(B):
        y_row = sparse_ffn_batched_ref(x[b:b + 1], wg, wu, wd,
                                       ids[b:b + 1, :int(counts[b])],
                                       tile)
        np.testing.assert_allclose(np.asarray(y_cpu[b]),
                                   np.asarray(y_row[0]),
                                   rtol=1e-5, atol=1e-5)


def test_k_valid_full_count_is_noop(ffn_params):
    """k_valid == K must be bit-identical to no masking (the uniform
    fast path and the masked path agree exactly at full width)."""
    x = jax.random.normal(jax.random.key(9), (2, 32, 64))
    scores = jax.nn.sigmoid(P.neuron_scores(ffn_params["pred"], x))
    ids = S.balanced_topk_tiles(scores, 4, 64)
    y0 = S.ffn_sparse_batched(ffn_params, x, ids, 64, "silu")
    y1 = S.ffn_sparse_batched(ffn_params, x, ids, 64, "silu",
                              k_valid=jnp.int32(4))
    assert np.array_equal(np.asarray(y0), np.asarray(y1))


# ------------------------------------- model level: layer-wise plans


def test_dense_prefill_layerwise_plan_matches_mask_forward(dense_setup):
    """Non-uniform per-layer counts on the gather path (blockwise
    prefill) vs the mask-path forward oracle carrying the SAME exact
    counts — the paper's scheduler x kernel composition."""
    cfg, params = dense_setup
    model = get_model(cfg)
    n_tiles = cfg.d_ff // cfg.ff.tile                     # 4
    plan = SparsityPlan(name="lw", tile_counts=(1, 3), n_tiles=n_tiles,
                        tile=cfg.ff.tile, keep=0.5)
    assert not plan.is_uniform
    rng = np.random.default_rng(3)
    T = 4 * cfg.ff.block_size
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, T)), jnp.int32)
    batch = {"tokens": tokens}
    logits_mask, _ = model.forward(params, cfg, batch, plan=plan)
    cache = model.init_cache(cfg, 2, T)
    _, logits_gather = model.prefill(params, cfg, batch, cache, plan=plan)
    np.testing.assert_allclose(np.asarray(logits_gather),
                               np.asarray(logits_mask[:, -1]),
                               rtol=2e-3, atol=2e-4)
    # the plan must actually bite: a uniform plan at the same k_max
    # gives a different answer
    _, logits_uni = model.prefill(
        params, cfg, batch, model.init_cache(cfg, 2, T),
        plan=SparsityPlan.uniform_counts(cfg.n_layers, n_tiles,
                                         cfg.ff.tile, plan.k_max))
    assert np.abs(np.asarray(logits_gather)
                  - np.asarray(logits_uni)).max() > 1e-4


def test_moe_forward_plan_without_shared_expert():
    """A pure-routed MoE (no shared expert — nothing for FastForward to
    sparsify) must tolerate forward(plan=...): shared_plan resolves to
    None and the routed path runs dense (code-review regression)."""
    from repro.models import moe
    cfg = ModelConfig(name="m", arch="moe", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab=64,
                      n_experts=4, top_k=2, n_shared_experts=0,
                      d_ff_expert=64, remat=False,
                      ff=FastForwardConfig(enabled=True, tile=16,
                                           block_size=8))
    params = init_params(moe.specs(cfg), jax.random.key(0))
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (1, 16)),
        jnp.int32)
    # an explicit plan (e.g. a serving tier resolved for another model
    # width): shared_plan() maps it to None — must not dereference it
    plan = SparsityPlan.uniform(cfg.n_layers, 4, cfg.ff.tile, keep=0.5)
    logits, _ = moe.forward(params, cfg, {"tokens": tokens}, plan=plan)
    assert logits.shape == (1, 16, cfg.vocab)


# --------------------------------------------- serving: compat shim


def test_engine_shim_bit_identical_to_explicit_uniform_plan(dense_setup):
    """Configs that only set cfg.ff.sparsity (no plan anywhere) must
    produce bit-identical greedy output to an explicitly-constructed
    uniform SparsityPlan — and both match the pre-redesign static
    engine path."""
    cfg, params = dense_setup
    prompts = make_prompts(cfg, [40, 70, 33])
    implicit = Engine(cfg, params).generate(prompts, max_new=8)
    explicit = Engine(cfg, params,
                      plans=(FF.resolve_plan(cfg),)).generate(
                          prompts, max_new=8)
    static = StaticEngine(cfg, params).generate(prompts, max_new=8)
    assert np.array_equal(implicit.tokens, explicit.tokens)
    assert np.array_equal(implicit.tokens, static.tokens)


# --------------------------------- serving: mixed-effort invariants


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_mixed_effort_stream_compile_flat(dense_setup, kv_layout):
    """A stream mixing two effort tiers never recompiles after warmup:
    every (plan, width bucket) prefill executable is pre-compiled and
    decode rides traced plan_ids through ONE executable."""
    cfg, params = dense_setup
    cfg = cfg.with_(kv_layout=kv_layout)
    plans = (FF.resolve_plan(cfg, effort="balanced"),
             FF.resolve_plan(cfg, effort="turbo"))
    runtime = make_runtime(cfg, params, plans=plans)
    sched = ContinuousBatchingScheduler(runtime, n_slots=3,
                                        cache_len=160, prefill_batch=2)
    counts0 = sched.warmup()
    prompts = make_prompts(cfg, [40, 70, 33, 90, 64, 50])
    for i, prompt in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=prompt, max_new=6,
                             effort=("turbo" if i % 2 else "balanced")))
    outs = sched.run()
    assert len(outs) == len(prompts)
    counts1 = runtime.compile_counts()
    if None not in counts0.values():
        assert counts1 == counts0, (counts0, counts1)
    stats = sched.sparsity_stats()
    assert [p["name"] for p in stats["plans"]] == ["balanced", "turbo"]
    assert all(p["prefill_blocks"] > 0 for p in stats["plans"])
    frac = stats["aggregate_ffn_flop_frac"]
    assert plans[1].flop_frac() < frac < plans[0].flop_frac()


def test_effort_output_independent_of_batch_mix(dense_setup):
    """A request's greedy output depends only on its OWN plan: turbo
    requests in a mixed balanced/turbo stream emit exactly what they
    emit in a pure-turbo engine (per-row decode counts + plan-
    homogeneous prefill batching keep rows independent)."""
    cfg, params = dense_setup
    bal = FF.resolve_plan(cfg, effort="balanced")
    tur = FF.resolve_plan(cfg, effort="turbo")
    prompts = make_prompts(cfg, [40, 70, 33, 90])
    mixed = Engine(cfg, params, plans=(bal, tur))
    sched = mixed.scheduler(n_slots=4, cache_len=160)
    for i, prompt in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=prompt, max_new=8,
                             effort=("turbo" if i % 2 else None)))
    outs = sched.run()

    pure_bal = Engine(cfg, params, plans=(bal,)).generate(
        [prompts[0], prompts[2]], max_new=8)
    pure_tur = Engine(cfg, params, plans=(tur,)).generate(
        [prompts[1], prompts[3]], max_new=8)
    assert outs[0].tokens == pure_bal.tokens[0].tolist()
    assert outs[2].tokens == pure_bal.tokens[1].tolist()
    assert outs[1].tokens == pure_tur.tokens[0].tolist()
    assert outs[3].tokens == pure_tur.tokens[1].tolist()
    # and the tiers genuinely differ
    assert outs[0].tokens != outs[1].tokens


def test_layerwise_plan_serves(dense_setup):
    """A NON-uniform plan drives the whole continuous-batching stack
    (batched prefill + ragged decode) with flat compile counts."""
    cfg, params = dense_setup
    n_tiles = cfg.d_ff // cfg.ff.tile
    plan = SparsityPlan(name="lw", tile_counts=(1, 3), n_tiles=n_tiles,
                        tile=cfg.ff.tile, keep=0.5)
    runtime = make_runtime(cfg, params, plans=(plan,))
    sched = ContinuousBatchingScheduler(runtime, n_slots=2,
                                        cache_len=160, prefill_batch=2)
    counts0 = sched.warmup()
    for i, prompt in enumerate(make_prompts(cfg, [70, 40, 90])):
        sched.submit(Request(rid=i, prompt=prompt, max_new=5))
    outs = sched.run()
    assert all(len(o.tokens) == 5 for o in outs.values())
    if None not in counts0.values():
        assert runtime.compile_counts() == counts0


def test_unknown_effort_rejected(dense_setup):
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=2, cache_len=96)
    with pytest.raises(ValueError, match="effort"):
        sched.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2,
                             effort="turbo"))


# ------------------------------------- dual-budget attention (tentpole)


def test_dual_budget_plan_construction():
    """with_attention attaches the attention-block budget: counts in
    [1, attn_tiles], fields survive hashing/equality, the dense tier
    no-ops, and with_tiles (MoE shared-expert re-derivation) carries
    the attention budget across the FFN width change."""
    cfg = CFG.with_ff(attn_sparsity=0.5, attn_tiles=8)
    bal = FF.resolve_plan(cfg, effort="balanced")
    tur = FF.resolve_plan(cfg, effort="turbo")
    dense = FF.resolve_plan(cfg, effort="dense")
    assert bal.has_attn and tur.has_attn
    assert not dense.has_attn               # attn_keep 1.0 -> no-op
    assert len(bal.attn_counts) == cfg.n_layers
    assert all(1 <= c <= 8 for c in bal.attn_counts)
    assert bal.attn_k_max == 4 and tur.attn_k_max == 2
    assert tur.attn_flop_frac() < bal.attn_flop_frac() < 1.0
    # attention budget joins the plan identity (jit static key)
    bal2 = FF.resolve_plan(cfg, effort="balanced")
    assert bal == bal2 and hash(bal) == hash(bal2)
    assert bal != FF.resolve_plan(CFG.with_ff(attn_sparsity=0.0),
                                  effort="balanced")
    # width re-derivation keeps the attention budget untouched
    small = bal.with_tiles(4)
    assert small.attn_counts == bal.attn_counts
    assert small.attn_tiles == bal.attn_tiles
    np.testing.assert_allclose(np.asarray(bal.attn_keep_fracs), 0.5)
    np.testing.assert_array_equal(np.asarray(bal.attn_counts_array()),
                                  np.asarray(bal.attn_counts))


def test_dual_budget_layerwise_importance():
    importance = np.array([1.0, 1.0, 1.0, 5.0])
    cfg = CFG.with_ff(attn_sparsity=0.5, attn_tiles=8)
    plan = FF.resolve_plan(cfg, importance=importance)
    assert plan.has_attn
    assert plan.attn_counts[3] > plan.attn_counts[0]
    assert sum(plan.attn_counts) == round(0.5 * cfg.n_layers * 8)


def test_attn_budget_full_keep_bit_identical_prefill(dense_setup):
    """A hand-built FULL attention budget (every virtual slot kept) on
    the blockwise prefill path must be BIT-identical to the plan
    without one — the masked XLA path keeps every causally-valid key."""
    import dataclasses
    cfg, params = dense_setup
    model = get_model(cfg)
    base = FF.resolve_plan(cfg)
    full = dataclasses.replace(base, attn_counts=(8,) * cfg.n_layers,
                               attn_tiles=8, attn_keep=1.0)
    rng = np.random.default_rng(11)
    T = 4 * cfg.ff.block_size
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, T)), jnp.int32)
    cache = model.init_cache(cfg, 2, T)
    _, logits_base = model.prefill(params, cfg, {"tokens": tokens},
                                   cache, plan=base)
    _, logits_full = model.prefill(params, cfg, {"tokens": tokens},
                                   model.init_cache(cfg, 2, T),
                                   plan=full)
    np.testing.assert_array_equal(np.asarray(logits_full),
                                  np.asarray(logits_base))
    # half budget changes the answer (the budget actually bites)
    half = dataclasses.replace(base, attn_counts=(4,) * cfg.n_layers,
                               attn_tiles=8, attn_keep=0.5)
    _, logits_half = model.prefill(params, cfg, {"tokens": tokens},
                                   model.init_cache(cfg, 2, T),
                                   plan=half)
    assert not np.array_equal(np.asarray(logits_half),
                              np.asarray(logits_base))


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_long_context_mixed_tier_greedy_equivalence(dense_setup,
                                                    kv_layout):
    """Long-context (>= 4K tokens, reduced config) batch-composition
    invariance under DUAL budgets: a mixed balanced/turbo stream with
    block-sparse attention on emits, per request, exactly what a
    pure-tier engine emits — and compile counts stay flat across the
    mixed stream (zero recompilation with attention budgets riding the
    scan)."""
    cfg, params = dense_setup
    cfg = cfg.with_(kv_layout=kv_layout).with_ff(attn_sparsity=0.5,
                                                 attn_tiles=8)
    bal = FF.resolve_plan(cfg, effort="balanced")
    tur = FF.resolve_plan(cfg, effort="turbo")
    assert bal.has_attn and tur.has_attn
    N = cfg.ff.block_size
    prompts = make_prompts(cfg, [4096 + N, 4096], seed=13)
    cache_len = -(-max(len(p) for p in prompts) // N) * N + 8
    mixed = Engine(cfg, params, plans=(bal, tur), prefill_batch=2)
    sched = mixed.scheduler(n_slots=2, cache_len=cache_len)
    counts0 = sched.warmup()
    sched.submit(Request(rid=0, prompt=prompts[0], max_new=4))
    sched.submit(Request(rid=1, prompt=prompts[1], max_new=4,
                         effort="turbo"))
    outs = sched.run()
    if None not in counts0.values():
        assert sched.runtime.compile_counts() == counts0

    pure_bal = Engine(cfg, params, plans=(bal,)).generate(
        [prompts[0]], max_new=4)
    pure_tur = Engine(cfg, params, plans=(tur,)).generate(
        [prompts[1]], max_new=4)
    assert outs[0].tokens == pure_bal.tokens[0].tolist()
    assert outs[1].tokens == pure_tur.tokens[0].tolist()


# ----------------------------------------------------- trace effort


def test_trace_effort_field(tmp_path):
    path = tmp_path / "t.jsonl"
    recs = [
        {"arrival_s": 0.0, "prompt_len": 8, "gen_len": 2,
         "effort": "turbo"},
        {"arrival_s": 0.1, "prompt_len": 4, "gen_len": 2},
    ]
    path.write_text("\n".join(json.dumps(r) for r in recs) + "\n")
    reqs = load_trace(str(path), vocab=100)
    assert reqs[0].effort == "turbo" and reqs[1].effort is None
    stats = trace_stats(reqs)
    assert stats["efforts"] == ["default", "turbo"]
    # loader-level default effort applies only to records without one
    reqs = load_trace(str(path), vocab=100, effort="balanced")
    assert reqs[0].effort == "turbo" and reqs[1].effort == "balanced"
