"""Overload-resilience layer: admission watermarks + hysteresis,
deadline-aware shedding, graceful effort degradation, mid-flight
cancellation and timeout enforcement — the scheduler-side half of the
robustness contract (the chaos half lives in test_faults.py)."""
import numpy as np
import pytest
import jax

import repro.core.fastforward as FF
from repro.configs import get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.serving import (AdmissionConfig, AdmissionController,
                           ContinuousBatchingScheduler, Request,
                           drive_stream)
from repro.serving.runtime import make_runtime


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def make_prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(n)).tolist() for n in lengths]


class FakeClock:
    """Manually-advanced clock + matching sleep (drive_stream routes
    idle waits through it)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += dt


# ------------------------------------------------- controller unit tests


def test_admission_config_validates():
    with pytest.raises(ValueError):
        AdmissionConfig(queue_high=2, queue_low=5)
    with pytest.raises(ValueError):
        AdmissionConfig(free_low=0.8, free_high=0.2)


def test_ladder_orders_densest_to_sparsest(dense_setup):
    cfg, _ = dense_setup
    plans = tuple(FF.resolve_plan(cfg, effort=e)
                  for e in ("turbo", "dense", "balanced"))
    ctl = AdmissionController(plans)
    names = [plans[i].name for i in ctl.ladder]
    assert names == ["dense", "balanced", "turbo"]
    fracs = [plans[i].flop_frac() for i in ctl.ladder]
    assert fracs == sorted(fracs, reverse=True)


def test_degraded_plan_never_denser_than_requested(dense_setup):
    cfg, _ = dense_setup
    plans = tuple(FF.resolve_plan(cfg, effort=e)
                  for e in ("dense", "balanced", "turbo"))
    ctl = AdmissionController(plans)          # ladder == registration
    assert ctl.degraded_plan(0) == 0          # level 0: everything as-is
    assert ctl.degraded_plan(2) == 2
    ctl.level = 1
    assert ctl.degraded_plan(0) == 1          # dense -> balanced
    assert ctl.degraded_plan(2) == 2          # turbo stays turbo
    ctl.level = 2
    assert ctl.degraded_plan(0) == 2          # dense -> turbo
    assert ctl.degraded_plan(1) == 2


def test_hysteresis_dwell_and_watermarks(dense_setup):
    cfg, _ = dense_setup
    plans = tuple(FF.resolve_plan(cfg, effort=e)
                  for e in ("dense", "balanced", "turbo"))
    ctl = AdmissionController(plans, AdmissionConfig(
        queue_high=4, queue_low=1, free_low=0.1, free_high=0.5,
        dwell_ticks=3))
    ctl.observe(queue_depth=10, free_frac=1.0)   # pressured -> level 1
    assert ctl.level == 1
    ctl.observe(10, 1.0)                          # inside dwell: held
    ctl.observe(10, 1.0)
    assert ctl.level == 1
    ctl.observe(10, 1.0)                          # dwell over -> level 2
    assert ctl.level == 2 == ctl.max_level
    ctl.observe(10, 1.0)
    ctl.observe(10, 1.0)
    ctl.observe(10, 1.0)
    assert ctl.level == 2                         # saturates at the top
    # free-page watermark alone also pressures (OR semantics)
    ctl2 = AdmissionController(plans, AdmissionConfig(dwell_ticks=0))
    ctl2.observe(queue_depth=0, free_frac=0.05)
    assert ctl2.level == 1
    # recovery needs BOTH low watermarks (AND semantics)
    ctl2.observe(queue_depth=0, free_frac=0.3)    # free still < free_high
    assert ctl2.level == 1
    ctl2.observe(queue_depth=0, free_frac=0.9)
    assert ctl2.level == 0
    assert ctl2.n_escalations == 1 and ctl2.n_deescalations == 1
    assert ctl2.peak_level == 1
    # degrade=False freezes the ladder entirely
    off = AdmissionController(plans, AdmissionConfig(degrade=False))
    off.observe(queue_depth=100, free_frac=0.0)
    assert off.level == 0 and off.degraded_plan(0) == 0


def test_shed_reason_provability():
    req = Request(rid=0, prompt=[1] * 64, deadline_ms=100.0,
                  arrival_time=10.0)
    # expired at submit
    assert "expired" in AdmissionController.shed_reason(
        req, now=10.2, n_blocks=2, min_block_s=None)
    # unmeasured system: nothing is provable
    assert AdmissionController.shed_reason(
        req, now=10.0, n_blocks=2, min_block_s=None) is None
    assert AdmissionController.shed_reason(
        req, now=10.0, n_blocks=2, min_block_s=0.0) is None
    # 2 blocks x 0.08s lower bound > 0.1s remaining: provably late
    assert "cannot meet" in AdmissionController.shed_reason(
        req, now=10.0, n_blocks=2, min_block_s=0.08)
    # but 2 x 0.04 = 0.08 < 0.1: could still make it
    assert AdmissionController.shed_reason(
        req, now=10.0, n_blocks=2, min_block_s=0.04) is None
    # ttft deadline proves the same way
    treq = Request(rid=1, prompt=[1] * 64, ttft_deadline_ms=50.0,
                   arrival_time=0.0)
    assert "ttft" in AdmissionController.shed_reason(
        treq, now=0.0, n_blocks=2, min_block_s=0.03)
    # no deadlines -> never shed
    free = Request(rid=2, prompt=[1] * 64, arrival_time=0.0)
    assert AdmissionController.shed_reason(
        free, now=99.0, n_blocks=9, min_block_s=9.0) is None


# ------------------------------------------- scheduler integration tests


def test_expired_deadline_sheds_at_submit(dense_setup):
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    clk = FakeClock()
    sched = ContinuousBatchingScheduler(runtime, n_slots=2, cache_len=128,
                                        clock=clk, sleep=clk.sleep)
    clk.t = 5.0
    sched.submit(Request(rid=0, prompt=[1] * 40, max_new=4,
                         deadline_ms=100.0, arrival_time=4.0))
    out = sched.finished[0]
    assert out.status == "shed" and "expired" in out.reason
    assert sched.n_shed == 1 and sched.drained


def test_deadline_timeout_mid_flight_frees_slot(dense_setup):
    """An e2e deadline expiring mid-decode finishes the request with
    status="timed_out", keeps the partial tokens, and frees the slot
    for the next queued request on the same tick."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    clk = FakeClock()
    sched = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=128,
                                        clock=clk, sleep=clk.sleep)
    sched.submit(Request(rid=0, prompt=[1] * 40, max_new=64,
                         deadline_ms=1000.0))
    sched.submit(Request(rid=1, prompt=[2] * 40, max_new=2))
    for _ in range(4):
        sched.tick()                       # prefill + a few decode steps
    assert sched.active and sched.finished == {}
    clk.t = 2.0                            # past the 1s deadline
    sched.tick()
    out = sched.finished[0]
    assert out.status == "timed_out" and "deadline" in out.reason
    assert 0 < len(out.tokens) < 64        # partial output kept
    assert out.ttft_seconds is not None
    # rid 1 seated in the freed slot on that same tick
    assert any(st.req.rid == 1 for st in sched.active.values())
    sched.run()
    assert sched.finished[1].status == "ok"
    assert len(sched.finished[1].tokens) == 2
    assert sched.pool.total_acquires == sched.pool.total_releases == 2
    assert sched.n_timed_out == 1


def test_ttft_deadline_expires_queued_request(dense_setup):
    """A ttft deadline only binds before the first token: it expires a
    QUEUED request but never an actively decoding one."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    clk = FakeClock()
    sched = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=128,
                                        clock=clk, sleep=clk.sleep)
    sched.submit(Request(rid=0, prompt=[1] * 40, max_new=8,
                         ttft_deadline_ms=10_000.0))
    sched.submit(Request(rid=1, prompt=[2] * 40, max_new=8,
                         ttft_deadline_ms=500.0))
    sched.tick()                           # rid 0 seated, rid 1 queued
    clk.t = 1.0                            # rid 1's ttft window gone
    sched.tick()
    assert sched.finished[1].status == "timed_out"
    assert sched.finished[1].tokens == []
    assert sched.finished[1].ttft_seconds is None
    sched.run()
    assert sched.finished[0].status == "ok"   # its own window: 10s


def test_cancel_queued_and_active(dense_setup):
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=128)
    sched.submit(Request(rid=0, prompt=[1] * 40, max_new=32))
    sched.submit(Request(rid=1, prompt=[2] * 40, max_new=4))
    sched.tick()                           # rid 0 active, rid 1 queued
    assert sched.cancel(1)                 # queued: zero work done
    assert sched.finished[1].status == "cancelled"
    assert sched.finished[1].tokens == []
    sched.tick()
    assert sched.cancel(0, reason="client went away")   # active
    out = sched.finished[0]
    assert out.status == "cancelled" and out.reason == "client went away"
    assert out.tokens                      # partial decode kept
    assert not sched.cancel(0)             # cancelling twice: no-op
    assert not sched.cancel(99)            # unknown rid
    assert sched.drained
    assert sched.pool.n_free == 1
    assert sched.pool.total_acquires == sched.pool.total_releases == 1
    assert sched.n_cancelled == 2


def test_drive_stream_cancel_after_s(dense_setup):
    """Trace replay of a client disconnect: drive_stream cancels the
    request `cancel_after_s` seconds after its arrival."""
    cfg, params = dense_setup
    runtime = make_runtime(cfg, params)
    clk = FakeClock()
    sched = ContinuousBatchingScheduler(runtime, n_slots=2, cache_len=256,
                                        clock=clk, sleep=clk.sleep)

    def advance(_):
        clk.t += 0.1                       # 10 ticks/simulated second

    reqs = [Request(rid=0, prompt=[1] * 40, max_new=200,
                    cancel_after_s=1.0),
            Request(rid=1, prompt=[2] * 40, max_new=4)]
    drive_stream(sched, reqs, after_tick=advance)
    assert sched.finished[0].status == "cancelled"
    assert "cancel_after_s" in sched.finished[0].reason
    assert len(sched.finished[0].tokens) < 200
    assert sched.finished[1].status == "ok"
    assert sched.pool.total_acquires == sched.pool.total_releases


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_overload_degrades_new_admissions(dense_setup, kv_layout):
    """Sustained overload walks the hysteretic ladder and routes new
    admissions to sparser tiers with ZERO recompilation; the realized
    tier is reported on RequestOutput.effort. When load drains the
    controller de-escalates back toward dense."""
    cfg, params = dense_setup
    cfg = cfg.with_(kv_layout=kv_layout)
    plans = tuple(FF.resolve_plan(cfg, effort=e)
                  for e in ("dense", "balanced", "turbo"))
    runtime = make_runtime(cfg, params, plans=plans)
    ctl = AdmissionController(plans, AdmissionConfig(
        queue_high=3, queue_low=0, dwell_ticks=1))
    sched = ContinuousBatchingScheduler(runtime, n_slots=2, cache_len=128,
                                        prefill_batch=1, admission=ctl)
    counts0 = sched.warmup()
    assert ctl.level == 0                  # warmup reset the controller
    prompts = make_prompts(cfg, [40] * 10)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=4))
    outs = sched.run()
    assert len(outs) == 10
    assert all(o.status == "ok" for o in outs.values())
    assert sched.n_degraded > 0
    efforts = {o.effort for o in outs.values()}
    assert efforts - {"dense"}             # some ran sparser than asked
    assert ctl.peak_level > 0
    assert ctl.level < ctl.peak_level      # drained: de-escalated
    counts1 = runtime.compile_counts()
    if None not in counts0.values():
        assert counts1 == counts0, (counts0, counts1)


def test_explicit_turbo_not_upgraded_under_load(dense_setup):
    """Degradation is one-way: a request explicitly asking for turbo
    keeps turbo at every level, and the pinned tier survives."""
    cfg, params = dense_setup
    plans = tuple(FF.resolve_plan(cfg, effort=e)
                  for e in ("dense", "balanced", "turbo"))
    runtime = make_runtime(cfg, params, plans=plans)
    ctl = AdmissionController(plans, AdmissionConfig(
        queue_high=1, queue_low=0, dwell_ticks=0))
    sched = ContinuousBatchingScheduler(runtime, n_slots=1, cache_len=128,
                                        prefill_batch=1, admission=ctl)
    for i in range(4):
        sched.submit(Request(rid=i, prompt=[1] * 40, max_new=2,
                             effort="turbo"))
    outs = sched.run()
    assert all(o.effort == "turbo" for o in outs.values())
    assert sched.n_degraded == 0           # turbo -> turbo is no change
