"""Dropless MoE routed-expert dispatch: dispatch-group invariance.

The serving stack's correctness story is that the 128-token FastForward
prefill block is semantically identical to the full-sequence forward.
Capacity-based routing broke that for MoE models (capacity is computed
per dispatch group, so chunking changed who drops); the dropless
sort-based grouped dispatch restores it. This suite pins:

  * the grouped-matmul kernel package (Pallas interpret == ragged_dot
    == masked-einsum oracle);
  * bit-level dispatch-group invariance of `routed_experts` under
    dropless mode, and the capacity mode's group DEPENDENCE (the A/B
    that the old xfail documented);
  * blockwise prefill == forward (de-xfailed in test_models_smoke),
    batched prefill_blocks == single-block loop, ragged decode ==
    forward, and continuous == static greedy generation on the MoE
    runtime — with flat compile counts across width buckets;
  * the load-balance aux loss excluding masked pad tokens.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.registry import get_model
from repro.models.moe import capacity, moe_ffn_spec, routed_experts
from repro.nn.param import init_params
from repro.serving import (ContinuousBatchingScheduler, Engine, Request,
                           StaticEngine)
from repro.serving.runtime import MoeRuntime, make_runtime

MOE_ARCHS = ["qwen2-moe-a2.7b", "kimi-k2-1t-a32b"]


def make_prompts(cfg, lengths, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, int(n)).tolist() for n in lengths]


# ------------------------------------------------- grouped-matmul kernel


@pytest.mark.parametrize("M,sizes", [
    (40, [10, 0, 25, 3]),       # empty group + leftover (masked) rows
    (128, [30, 40, 30, 28]),    # exact fit, one full row tile
    (6, [2, 1, 1, 1]),          # smaller than one tile + leftover row
])
def test_grouped_matmul_kernel_matches_oracles(M, sizes):
    """Interpret-mode Pallas kernel == masked-einsum oracle ==
    jax.lax.ragged_dot (the XLA serving path), including zeroed rows
    past sum(group_sizes)."""
    from repro.kernels.grouped_matmul import ops, ref
    rng = np.random.default_rng(0)
    E, D, F = 4, 64, 96
    lhs = jnp.asarray(rng.standard_normal((M, D)), jnp.float32)
    rhs = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    y_ref = np.asarray(ref.grouped_matmul_ref(lhs, rhs, gs))
    y_xla = np.asarray(ops.grouped_matmul_op(lhs, rhs, gs))
    y_ker = np.asarray(ops.grouped_matmul_op(lhs, rhs, gs,
                                             use_kernel=True))
    np.testing.assert_allclose(y_xla, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y_ker, y_ref, rtol=1e-5, atol=1e-5)
    left = int(np.sum(sizes))
    np.testing.assert_array_equal(y_ker[left:], 0.0)


# --------------------------------------------- dispatch-group invariance


def test_dropless_routed_output_is_dispatch_group_invariant():
    """The tentpole invariant at its sharpest: routing a [1, T] sequence
    in ONE dispatch group is bit-identical to routing each half in its
    own group — a token's routed output depends only on that token."""
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    assert cfg.moe_dispatch == "dropless"
    mp = init_params(moe_ffn_spec(cfg, cfg.dtype), jax.random.key(2))
    x = jax.random.normal(jax.random.key(5), (1, 64, cfg.d_model))
    y_full, _ = routed_experts(mp, cfg, x)
    y_a, _ = routed_experts(mp, cfg, x[:, :32])
    y_b, _ = routed_experts(mp, cfg, x[:, 32:])
    np.testing.assert_array_equal(
        np.asarray(y_full), np.asarray(jnp.concatenate([y_a, y_b], 1)))


def test_capacity_mode_still_drops_dropless_does_not():
    """A/B the two dispatch modes on an engineered overflow: 32
    identical rows all route to the same experts, so one 32-token
    dispatch group (capacity 24) drops rows that two 16-token groups
    (capacity 16 each) keep — capacity routing is dispatch-group
    DEPENDENT, which is exactly why it is demoted to an opt-in
    training mode."""
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    cap = cfg.with_(moe_dispatch="capacity")
    assert capacity(32, cfg) < 32 <= 2 * capacity(16, cfg)
    mp = init_params(moe_ffn_spec(cfg, cfg.dtype), jax.random.key(2))
    row = jax.random.normal(jax.random.key(3), (1, 1, cfg.d_model))
    x = jnp.tile(row, (1, 32, 1))

    c_full, _ = routed_experts(mp, cap, x)
    c_a, _ = routed_experts(mp, cap, x[:, :16])
    c_b, _ = routed_experts(mp, cap, x[:, 16:])
    c_blocks = np.asarray(jnp.concatenate([c_a, c_b], 1))
    assert not np.allclose(np.asarray(c_full), c_blocks,
                           rtol=1e-3, atol=1e-4)

    d_full, _ = routed_experts(mp, cfg, x)
    d_a, _ = routed_experts(mp, cfg, x[:, :16])
    d_b, _ = routed_experts(mp, cfg, x[:, 16:])
    np.testing.assert_array_equal(
        np.asarray(d_full), np.asarray(jnp.concatenate([d_a, d_b], 1)))


def test_unknown_dispatch_mode_rejected():
    cfg = get_config("qwen2-moe-a2.7b", reduced=True).with_(
        moe_dispatch="typo")
    mp = init_params(moe_ffn_spec(cfg, cfg.dtype), jax.random.key(2))
    x = jnp.zeros((1, 4, cfg.d_model))
    with pytest.raises(ValueError, match="moe_dispatch"):
        routed_experts(mp, cfg, x)


# ----------------------------------------------------- aux loss masking


def test_aux_loss_excludes_masked_tokens():
    """The Switch-style load-balance statistics (me/ce) must be computed
    over live tokens only: the aux loss of a masked batch equals the
    aux loss of the live subset served alone, and differs from the
    unmasked batch (dead rows would otherwise skew the statistics)."""
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    mp = init_params(moe_ffn_spec(cfg, cfg.dtype), jax.random.key(2))
    key_live, key_dead = jax.random.split(jax.random.key(7))
    live = jax.random.normal(key_live, (1, 8, cfg.d_model))
    dead = 5.0 * jax.random.normal(key_dead, (1, 8, cfg.d_model))
    x = jnp.concatenate([live, dead], axis=1)
    mask = jnp.asarray([[True] * 8 + [False] * 8])

    _, aux_masked = routed_experts(mp, cfg, x, token_mask=mask)
    _, aux_solo = routed_experts(mp, cfg, live)
    _, aux_unmasked = routed_experts(mp, cfg, x)
    np.testing.assert_allclose(float(aux_masked), float(aux_solo),
                               rtol=1e-6)
    assert not np.isclose(float(aux_masked), float(aux_unmasked),
                          rtol=1e-3)
    # capacity mode shares the same router head / statistics fix
    cap = cfg.with_(moe_dispatch="capacity")
    _, aux_cap = routed_experts(mp, cap, x, token_mask=mask)
    np.testing.assert_allclose(float(aux_cap), float(aux_solo), rtol=1e-6)


# ------------------------------------------------- serving equivalences


@pytest.fixture(scope="module", params=MOE_ARCHS)
def moe_setup(request):
    cfg = get_config(request.param, reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def test_moe_ragged_decode_matches_forward(moe_setup):
    """Prefill T tokens blockwise, then one ragged decode step of token
    T: the decode logits must match the full-sequence forward's logits
    at the same position (FastForward off isolates the dispatch)."""
    cfg, params = moe_setup
    cfg = cfg.with_ff(enabled=False)
    model = get_model(cfg)
    T = 2 * cfg.ff.block_size
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, T + 1)), jnp.int32)
    logits, _ = model.forward(params, cfg, {"tokens": toks})

    cache = model.init_cache(cfg, 2, T + 8)
    cache, _ = model.prefill(params, cfg, {"tokens": toks[:, :T]}, cache)
    dec, _ = model.decode_step(
        params, cfg, toks[:, T], cache,
        jnp.full((2,), T, jnp.int32),                 # ragged [B] path
        active=jnp.ones((2,), bool))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-4)


def test_moe_batched_prefill_matches_single_block_loop(moe_setup):
    """The batched prefill_blocks tick (P=4, ragged offsets, pad rows)
    must generate exactly the tokens of the one-block-per-tick loop —
    under capacity dispatch the shared per-tick dispatch group broke
    this, under dropless dispatch every row routes independently. Width
    buckets must stay on their warmup executables (compile_counts
    flat)."""
    cfg, params = moe_setup
    runtime = make_runtime(cfg, params)
    assert isinstance(runtime, MoeRuntime)
    N = runtime.block_size
    prompts = make_prompts(cfg, [3 * N, 2 * N, 17, N + 5, 4 * N], seed=9)

    def run(prefill_batch, warm):
        sched = ContinuousBatchingScheduler(
            runtime, n_slots=4, cache_len=6 * N,
            prefill_batch=prefill_batch)
        counts = sched.warmup() if warm else None
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new=6))
        outs = sched.run()
        if warm:
            assert runtime.compile_counts() == counts
        return outs

    single = run(1, warm=False)
    batched = run(4, warm=True)
    for rid in single:
        assert single[rid].tokens == batched[rid].tokens


def test_moe_continuous_matches_static_greedy(moe_setup):
    """Greedy continuous-batched MoE generation is bit-identical to the
    legacy static-batch engine on ragged prompts: the static engine
    routes the whole right-padded batch in one dispatch group per
    block, the continuous engine routes per-request blocks — dropless
    dispatch makes both identical (FastForward off: per-sequence
    dense-last semantics coincide)."""
    cfg, params = moe_setup
    cfg = cfg.with_ff(enabled=False)
    prompts = make_prompts(cfg, [70, 33, 64, 21], seed=4)
    st = StaticEngine(cfg, params).generate(prompts, max_new=8)
    ct = Engine(cfg, params, n_slots=2).generate(prompts, max_new=8)
    np.testing.assert_array_equal(st.tokens, ct.tokens)
