"""Unit tests for the HLO analyzer that powers §Roofline (trip-count
scaling, dot FLOPs from the shape table, collective payload bytes)."""
import textwrap

from repro.launch.hlo_analysis import (
    analyze_hlo, parse_hlo, _parse_op_line, _shape_bytes)

HLO = textwrap.dedent("""\
    HloModule test, num_partitions=4

    %add.clone (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %add = f32[] add(%x, %y)
    }

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add.clone
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ip, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (x: f32[8,16]) -> f32[8,16] {
      %x = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %t0 = (s32[], f32[8,16]{1,0}) tuple(%zero, %x)
      %while.1 = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
    }
""")


def test_parse_op_line_tuple_type_with_index_comments():
    line = ('%while.15 = (s32[], bf16[8,1,2048]{2,1,0}, '
            '/*index=5*/f32[22,8]{1,0}) while(%tuple.21), '
            'condition=%c, body=%b')
    name, rtype, opcode = _parse_op_line(line)
    assert name == "while.15"
    assert opcode == "while"
    assert "/*index=5*/" in rtype


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("bf16[4,4]") == 32
    assert _shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16


def test_trip_count_scaling():
    m = analyze_hlo(HLO)
    # one dot per iteration: 2*8*16*16 flops, 5 iterations
    assert m.flops == 2 * 8 * 16 * 16 * 5
    # one all-reduce of f32[8,16] per iteration
    assert m.collective_bytes["all-reduce"] == 8 * 16 * 4 * 5
    assert m.collective_counts["all-reduce"] == 5


def test_parse_computations():
    comps = parse_hlo(HLO)
    assert comps["__entry_name__"] == "main"
    assert "body" in comps and "cond" in comps
    opcodes = [o.opcode for o in comps["body"]]
    assert "dot" in opcodes and "all-reduce" in opcodes
