"""Per-architecture smoke tests (assignment requirement): REDUCED
variants of all 10 assigned archs run one forward and one train step on
CPU, asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.registry import get_model
from repro.nn.param import init_params
from repro.training.train import make_train_step


def make_batch(cfg, B=2, T=64, train=True, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, T)), jnp.int32)}
    if train:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    if cfg.arch == "audio":
        batch["audio_embed"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    if cfg.arch == "vlm":
        batch["patch_embed"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)) * 0.02,
            jnp.float32)
        if train:
            img = -np.ones((B, cfg.n_patches), np.int32)
            batch["labels"] = jnp.concatenate(
                [jnp.asarray(img), batch["labels"]], axis=1)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.key(0))
    batch = make_batch(cfg, train=False)
    logits, aux = model.forward(params, cfg, batch)
    T_out = 64 + (cfg.n_patches if cfg.arch == "vlm" else 0)
    assert logits.shape == (2, T_out, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.key(0))
    init_state, train_step = make_train_step(cfg, lr=1e-3)
    state = init_state(params)
    batch = make_batch(cfg)
    state, metrics = jax.jit(train_step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.abs(b).sum()),
        jax.tree.map(lambda a, b: a - b, state["params"], params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_smoke(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.is_encdec:
        pytest.skip("decode covered in enc-dec consistency test")
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.key(0))
    cache = model.init_cache(cfg, 2, 96)
    tok = jnp.asarray([1, 2], jnp.int32)
    logits, cache = model.decode_step(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b", "zamba2-2.7b",
    # MoE archs run dropless routed dispatch (cfg.moe_dispatch), which
    # is dispatch-group invariant — the per-block prefill routes every
    # token exactly as the full-sequence forward does. (Under the
    # opt-in "capacity" training mode this equivalence does NOT hold:
    # capacity = ceil(group_tokens*K*cf/E) differs per dispatch group,
    # so overflow tokens drop differently — see test_moe_dispatch.py.)
    "qwen2-moe-a2.7b", "kimi-k2-1t-a32b",
])
def test_prefill_matches_forward(arch):
    """Blockwise-cached prefill must reproduce the fused forward exactly
    when FastForward is disabled."""
    cfg = get_config(arch, reduced=True).with_ff(enabled=False)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.key(0))
    batch = make_batch(cfg, train=False)
    logits, _ = model.forward(params, cfg, batch)
    cache = model.init_cache(cfg, 2, 64)
    cache, pl = model.prefill(params, cfg, batch, cache)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-4)


def test_loss_decreases_tinyllama():
    """Training on a sharply-structured Markov corpus must cut loss."""
    from repro.data.synthetic import batches
    cfg = get_config("tinyllama-1.1b", reduced=True)
    model = get_model(cfg)
    params = init_params(model.specs(cfg), jax.random.key(0))
    init_state, train_step = make_train_step(cfg, lr=3e-3)
    state = init_state(params)
    step_fn = jax.jit(train_step, donate_argnums=0)
    # low-entropy chain (256 states, zipf-8 fan-out): learnable fast
    data = batches(256, 8, 64, seed=0, branch=8, alpha=1.5)
    losses = []
    for i in range(100):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = step_fn(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, \
        (np.mean(losses[:10]), np.mean(losses[-10:]))
