import numpy as np
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
