"""int8-quantized KV page heap (kernels/kv_quant + the quant-aware
paged attention paths): quantization scheme properties (idempotence,
error bound, zero-page exactness), Pallas-kernel-vs-oracle bit
equivalence in interpret mode, quant-heap attention kernels vs the
dequantized-heap reference, the dict-leaf gather/write plumbing in
nn/attention, and the end-to-end serving contract — quant logits
allclose to f32 within the documented tolerance, page accounting and
compile counts unchanged with the quantized heap on.

Tolerance note: per kernels/kv_quant/ref.py each dequantized K/V
element differs from the source by <= 0.5 * absmax / 127 (~0.4% of a
page's per-head dynamic range). Attention and the FFN stack amplify
that, so end-to-end logits comparisons use a deliberately generous
tolerance (see E2E_*); greedy TOKENS may legitimately diverge on
near-flat logits (random-init weights), which is why the end-to-end
contract is at the logits level, not token level.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.kernels.kv_quant import kernel as KQK
from repro.kernels.kv_quant import ops as KQ
from repro.kernels.kv_quant import ref as KQR
from repro.kernels.paged_attention import kernel as PK
from repro.kernels.paged_attention import ref as PR
from repro.models.registry import get_model
from repro.nn import attention as A
from repro.nn.param import init_params
from repro.serving import ContinuousBatchingScheduler, Request
from repro.serving.runtime import make_runtime

PAGE = 8                       # divides the reduced block size (32)

# end-to-end logits tolerance for the 2-layer reduced model (see the
# module docstring): ~0.4% per-element KV error through attention +
# FFN + unembed stays well inside this
E2E_RTOL, E2E_ATOL = 0.05, 0.25


def _pages(rng, P=6, psz=4, Kv=2, dh=8, scale=3.0):
    x = rng.standard_normal((P, psz, Kv, dh)) * scale
    x[0] = 0.0                              # the reserved null page
    return jnp.asarray(x, jnp.float32)


# --------------------------------------------------------- quant scheme


def test_quant_roundtrip_error_bound_and_zero_pages():
    rng = np.random.default_rng(0)
    x = _pages(rng)
    q, s = KQR.quantize_pages_ref(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    y = KQR.dequantize_pages_ref(q, s)
    # documented bound: 0.5 * absmax / 127 per (page, kv-head)
    absmax = np.max(np.abs(np.asarray(x)), axis=(1, 3))
    bound = 0.5 * absmax / 127.0
    err = np.max(np.abs(np.asarray(y - x)), axis=(1, 3))
    assert (err <= bound + 1e-7).all()
    # all-zero pages: scale 0, dequant EXACTLY zero (null-page contract)
    assert float(s[0].max()) == 0.0
    np.testing.assert_array_equal(np.asarray(y[0]), 0.0)


def test_quantize_dequantize_roundtrip_stable():
    """Requantizing a dequantized page reproduces q bit-exactly and s
    to within one f32 ulp — the decode token write path (dequantize ->
    modify -> requantize) relies on this bound for already-written
    tokens (see ref.py round-trip stability note)."""
    rng = np.random.default_rng(1)
    q, s = KQR.quantize_pages_ref(_pages(rng))
    y = KQR.dequantize_pages_ref(q, s)
    q2, s2 = KQR.quantize_pages_ref(y)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s),
                               rtol=2 ** -23, atol=0)


def test_kernel_interpret_bit_matches_oracle():
    rng = np.random.default_rng(2)
    x = _pages(rng, P=5, psz=8, Kv=3, dh=16)
    qk, sk = KQK.quantize_pages(x, interpret=True)
    qr, sr = KQR.quantize_pages_ref(x)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    yk = KQK.dequantize_pages(qk, sk, interpret=True)
    yr = KQR.dequantize_pages_ref(qr, sr)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yr))
    # the op-layer dispatch reaches both paths
    qo, so = KQ.quantize_pages_op(x, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(qo), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(so), np.asarray(sr))
    np.testing.assert_array_equal(
        np.asarray(KQ.dequantize_pages_op(qo, so, use_kernel=False)),
        np.asarray(yr))


# ------------------------------------------- quant attention kernels


def _decode_setup(seed=0, B=3, H=4, Kv=2, dh=8, psz=4, max_pages=6,
                  positions=(9, 5, 18)):
    rng = np.random.default_rng(seed)
    positions = np.asarray(positions, np.int32)
    n_pages = 1 + int(sum(p // psz + 1 for p in positions))
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    kp = _pages(rng, P=n_pages, psz=psz, Kv=Kv, dh=dh)
    vp = _pages(rng, P=n_pages, psz=psz, Kv=Kv, dh=dh)
    table = np.zeros((B, max_pages), np.int32)
    nxt = 1
    for b, p in enumerate(positions):
        n = p // psz + 1
        table[b, :n] = np.arange(nxt, nxt + n)
        nxt += n
    return q, kp, vp, jnp.asarray(table), jnp.asarray(positions)


def test_quant_decode_kernel_matches_dequant_reference():
    """paged_decode_attention_quant over the int8 heap == the f32
    kernel over the DEQUANTIZED heap (same bytes reach the math)."""
    q, kp, vp, tbl, pos = _decode_setup(seed=3)
    kq, ks = KQR.quantize_pages_ref(kp)
    vq, vs = KQR.quantize_pages_ref(vp)
    kd = KQR.dequantize_pages_ref(kq, ks)
    vd = KQR.dequantize_pages_ref(vq, vs)
    for window in (None, 7):
        got = PK.paged_decode_attention_quant(
            q, kq, ks, vq, vs, tbl, pos, window=window, interpret=True)
        want = PK.paged_decode_attention(q, kd, vd, tbl, pos,
                                         window=window, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        ref = PR.paged_attention_ref(q, kd, vd, tbl, pos, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_quant_bsa_kernel_matches_dequant_reference():
    """block_sparse_prefill_quant over int8 slabs == block_sparse_prefill
    over the dequantized slabs (selection indices held identical)."""
    from repro.kernels.block_sparse_attention import kernel as BK
    rng = np.random.default_rng(4)
    B, N, H, Kv, dh, blk, P, K = 2, 8, 4, 2, 8, 4, 9, 3
    q = jnp.asarray(rng.standard_normal((B, N, H, dh)), jnp.float32)
    kb = _pages(rng, P=P, psz=blk, Kv=Kv, dh=dh)
    vb = _pages(rng, P=P, psz=blk, Kv=Kv, dh=dh)
    pool_ids = jnp.asarray([[1, 2, 3], [4, 5, 0]], jnp.int32)
    blk_pos = jnp.asarray([[0, 1, 2], [0, 1, 0]], jnp.int32)
    counts = jnp.asarray([3, 2], jnp.int32)
    pos0s = jnp.asarray([8, 4], jnp.int32)
    lengths = jnp.asarray([16, 12], jnp.int32)
    kq, ks = KQR.quantize_pages_ref(kb)
    vq, vs = KQR.quantize_pages_ref(vb)
    got = BK.block_sparse_prefill_quant(
        q, kq, ks, vq, vs, pool_ids, blk_pos, counts, pos0s, lengths,
        interpret=True)
    want = BK.block_sparse_prefill(
        q, KQR.dequantize_pages_ref(kq, ks),
        KQR.dequantize_pages_ref(vq, vs), pool_ids, blk_pos, counts,
        pos0s, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------- dict-leaf gather/write plumbing


def test_gather_pages_quant_dequantizes_exactly():
    rng = np.random.default_rng(5)
    x = _pages(rng, P=7, psz=PAGE)
    q, s = KQR.quantize_pages_ref(x)
    tbl = jnp.asarray([[1, 3, 0], [2, 6, 5]], jnp.int32)
    got = A.gather_pages({"q": q, "s": s}, tbl)
    want = A.gather_pages(KQR.dequantize_pages_ref(q, s), tbl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert A.kv_page_size({"q": q, "s": s}) == PAGE
    assert A.kv_dtype({"q": q, "s": s}) == jnp.float32


def test_quant_block_write_roundtrips():
    """write_kv_rows_paged on dict leaves lands exactly the quantized
    bytes of the written rows (fresh pages -> one clean quantization,
    no rescale drift)."""
    rng = np.random.default_rng(6)
    B, N, Kv, dh, mp = 2, 16, 2, 4, 4
    psz, n_pages = PAGE, 1 + 2 * mp
    zero = jnp.zeros((n_pages, psz, Kv, dh), jnp.float32)
    pool = {"q": jnp.zeros(zero.shape, jnp.int8),
            "s": jnp.zeros((n_pages, Kv), jnp.float32)}
    table = np.zeros((B, mp), np.int32)
    table[0, :2] = [1, 2]
    table[1, 2:4] = [3, 4]                  # row 1 writes its 3rd block
    k_new = jnp.asarray(rng.standard_normal((B, N, Kv, dh)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, N, Kv, dh)), jnp.float32)
    pool2, _ = A.write_kv_rows_paged(
        dict(pool), {"q": pool["q"], "s": pool["s"]}, k_new, v_new,
        jnp.asarray(table), jnp.asarray([0, 16], jnp.int32),
        active=jnp.asarray([True, True]))
    got = A.gather_pages(pool2, jnp.asarray(table))
    qx, sx = KQR.quantize_pages_ref(
        k_new.reshape(B * 2, psz, Kv, dh))
    want_rows = KQR.dequantize_pages_ref(qx, sx).reshape(B, N, Kv, dh)
    np.testing.assert_array_equal(np.asarray(got[0, :N]),
                                  np.asarray(want_rows[0]))
    np.testing.assert_array_equal(np.asarray(got[1, 16:16 + N]),
                                  np.asarray(want_rows[1]))
    # untouched pages (incl. the null page) still dequantize to zero
    np.testing.assert_array_equal(np.asarray(got[1, :16]), 0.0)


def test_quant_token_write_zeroes_stale_tail():
    """The decode token write dequantizes the page, inserts the token,
    ZEROES every slot past the write offset, and requantizes — so stale
    bytes beyond the logical end can never poison the page's absmax.
    Writing a small token after a large one must not inherit the large
    token's scale on the untouched tail."""
    rng = np.random.default_rng(7)
    B, Kv, dh, mp = 1, 2, 4, 2
    n_pages = 3
    pool = {"q": jnp.zeros((n_pages, PAGE, Kv, dh), jnp.int8),
            "s": jnp.zeros((n_pages, Kv), jnp.float32)}
    table = jnp.asarray([[1, 0]], jnp.int32)
    big = jnp.asarray(rng.standard_normal((B, 1, Kv, dh)) * 50,
                      jnp.float32)
    small = jnp.asarray(rng.standard_normal((B, 1, Kv, dh)) * 0.1,
                        jnp.float32)

    def write(pool, tok, pos):
        k2, _ = A.write_kv_tok_paged(
            pool, {"q": pool["q"], "s": pool["s"]}, tok, tok, table,
            jnp.asarray([pos], jnp.int32), active=jnp.asarray([True]))
        return k2

    # position 3 first (slots 0..2 stay zero), then REWRITE pos 0 small:
    # the rewrite's zeroed tail drops slot 3's big value from the page,
    # so the fresh scale reflects only the small token
    pool = write(pool, big, 3)
    s_big = float(np.max(np.asarray(pool["s"][1])))
    pool = write(pool, small, 0)
    s_small = float(np.max(np.asarray(pool["s"][1])))
    assert s_small < s_big / 10
    got = A.gather_pages(pool, table)
    np.testing.assert_allclose(np.asarray(got[0, 0]),
                               np.asarray(small[0, 0]),
                               rtol=0.02, atol=1e-3)
    # slots past the write offset are exact zeros
    np.testing.assert_array_equal(np.asarray(got[0, 1:]), 0.0)


# ----------------------------------------------------------- end to end


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    return cfg, params


def _paged(cfg, quant=False):
    return cfg.with_(kv_layout="paged", kv_page_size=PAGE,
                     kv_quant=quant)


def test_quant_prefill_decode_logits_allclose(dense_setup):
    """End-to-end contract: the quantized paged heap's prefill and
    decode logits match the f32 paged heap within the documented
    (generous) tolerance — same runtime stack the scheduler drives."""
    cfg, params = dense_setup
    rng = np.random.default_rng(8)
    N = cfg.ff.block_size
    toks = rng.integers(0, cfg.vocab, (1, N)).astype(np.int32)
    mp = N // PAGE + 1                      # + 1 page of decode headroom

    def run(quant):
        runtime = make_runtime(_paged(cfg, quant), params)
        cache = runtime.init_cache_paged(1 + mp, PAGE)
        table = np.zeros((1, mp), np.int32)
        table[0, :] = np.arange(1, mp + 1)
        cache, logits_p = runtime.prefill_blocks_paged(
            cache, toks, table, [0], [True], [N], [True])
        logits_d, greedy, _ = runtime.decode_step_paged(
            cache, np.asarray(logits_p).argmax(-1).astype(np.int32),
            table, [N], [True])
        return np.asarray(logits_p), np.asarray(logits_d)

    lp32, ld32 = run(False)
    lpq, ldq = run(True)
    assert not np.array_equal(lpq, lp32)    # quantization really engaged
    np.testing.assert_allclose(lpq, lp32, rtol=E2E_RTOL, atol=E2E_ATOL)
    np.testing.assert_allclose(ldq, ld32, rtol=E2E_RTOL, atol=E2E_ATOL)


def test_quant_scheduler_accounting_and_compile_flat(dense_setup):
    """A churny quant-heap stream (tight heap -> preemptions): page
    accounting stays exact, tables reset at drain, and compile counts
    stay flat — the quantized heap changes BYTES, not executables."""
    cfg, params = dense_setup
    runtime = make_runtime(_paged(cfg, quant=True), params)
    sched = ContinuousBatchingScheduler(runtime, n_slots=3, cache_len=96,
                                        n_pages=14)
    counts = sched.warmup()
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, int(n)).tolist()
               for n in (40, 36, 33, 20, 18)]
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, prompt=p, max_new=24))
    outs = sched.run()
    assert sorted(outs) == list(range(5))
    assert all(len(o.tokens) == 24 for o in outs.values())
    pool = sched.pool
    assert pool.n_free_pages == pool.n_pages - 1
    assert (pool.page_table == 0).all()
    assert pool.total_page_allocs == pool.total_page_frees
    assert runtime.compile_counts() == counts
    # the int8 heap really is the storage: dict leaves, int8 q
    leaf = next(iter(pool.cache.values()))
    assert isinstance(leaf, dict) and leaf["q"].dtype == jnp.int8
