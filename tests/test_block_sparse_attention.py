"""Block-sparse prefill attention: pooled-QK selection, the Pallas
kernel (interpret mode) vs its online-softmax twin (BITWISE) vs the
masked serving path vs the dense oracle, the paged dispatch, the
full-budget bit-identity contract at the attention-op level, and the
per-row flash kernel behind the dense TPU routing (satellite 6)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attention import kernel as K
from repro.kernels.block_sparse_attention import ops as BSA
from repro.kernels.block_sparse_attention import ref as R
from repro.kernels.flash_attention import ops as FA
from repro.nn import attention as A
from repro.nn.attention import attn_sel_width


def _setup(seed=0, B=3, N=8, H=4, Kv=2, dh=16, S=40,
           pos0s=(0, 16, 32), lengths=(8, 24, 40)):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, N, H, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, Kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, Kv, dh)), jnp.float32)
    return (q, kc, vc, jnp.asarray(pos0s, jnp.int32),
            jnp.asarray(lengths, jnp.int32))


def _select(q, kc, pos0s, lengths, blk, attn_tiles, a_l, window=None,
            threshold=None):
    nc = -(-kc.shape[1] // blk)
    return BSA.select_kv_blocks(
        q, BSA.pooled_block_keys(kc, blk), pos0s, lengths, blk=blk,
        k_sel=attn_sel_width((int(a_l), attn_tiles, None), nc),
        attn_tiles=attn_tiles, a_l=jnp.int32(a_l), window=window,
        threshold=threshold)


# ------------------------------------------------ selection properties


def test_selection_forced_blocks_and_ascending_prefix():
    q, kc, vc, pos0s, lengths = _setup()
    blk = 8
    ids, cnts = _select(q, kc, pos0s, lengths, blk, attn_tiles=8, a_l=4)
    ids, cnts = np.asarray(ids), np.asarray(cnts)
    cur = (np.asarray(pos0s) + q.shape[1] - 1) // blk
    nv = cur + 1
    # per-row kept count: budget fraction scaled onto the causal ramp
    want = np.clip(-(-4 * nv // 8), np.minimum(2, nv), nv)
    np.testing.assert_array_equal(cnts, want)
    for b in range(ids.shape[0]):
        live = ids[b, :cnts[b]]
        assert 0 in live, "sink block must be force-included"
        assert cur[b] in live, "diagonal block must be force-included"
        assert np.all(np.diff(live) > 0), "live prefix must ascend"
        assert np.all(live <= cur[b]), "no acausal blocks"


def test_selection_full_budget_keeps_every_valid_block():
    q, kc, vc, pos0s, lengths = _setup()
    ids, cnts = _select(q, kc, pos0s, lengths, 8, attn_tiles=8, a_l=8)
    cur = (np.asarray(pos0s) + q.shape[1] - 1) // 8
    np.testing.assert_array_equal(np.asarray(cnts), cur + 1)
    for b in range(ids.shape[0]):
        np.testing.assert_array_equal(
            np.sort(np.asarray(ids)[b, :cnts[b]]), np.arange(cur[b] + 1))


def test_threshold_one_keeps_all_and_stays_dense_bit_identical():
    """The opt-in adaptive-count contract at its boundary: threshold=1.0
    keeps every candidate (the inclusive proxy-softmax mass only
    reaches 1.0 at the LAST valid block, extreme score gaps included),
    so counts equal the fixed-budget counts and — at a full budget —
    the masked path stays BITWISE equal to dense attention."""
    q, kc, vc, pos0s, lengths = _setup(seed=3)
    ids_f, cnts_f = _select(q, kc, pos0s, lengths, 8, 8, 8)
    ids_t, cnts_t = _select(q, kc, pos0s, lengths, 8, 8, 8, threshold=1.0)
    np.testing.assert_array_equal(np.asarray(cnts_t), np.asarray(cnts_f))
    np.testing.assert_array_equal(np.asarray(ids_t), np.asarray(ids_f))
    got = R.block_sparse_attention_masked(q, kc, vc, ids_t, cnts_t, pos0s,
                                          lengths, blk=8)
    want = R.dense_oracle(q, kc, vc, pos0s, lengths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_threshold_adapts_counts_capped_by_budget():
    """A mid threshold spends LESS than the budget on easy inputs but
    never more: adaptive counts are capped by the plan's per-row budget
    count, floored at the min(2, nv) forcing floor, and the kept set is
    still a valid selection (sink + diagonal forced, ascending,
    causal). A near-zero threshold drives every row to the floor."""
    q, kc, vc, pos0s, lengths = _setup(seed=4)
    blk = 8
    cur = (np.asarray(pos0s) + q.shape[1] - 1) // blk
    nv = cur + 1
    _, cnts_budget = _select(q, kc, pos0s, lengths, blk, 8, 6)
    ids, cnts = _select(q, kc, pos0s, lengths, blk, 8, 6, threshold=0.5)
    ids, cnts = np.asarray(ids), np.asarray(cnts)
    assert np.all(cnts <= np.asarray(cnts_budget))
    assert np.all(cnts >= np.minimum(2, nv))
    for b in range(ids.shape[0]):
        live = ids[b, :cnts[b]]
        assert 0 in live and cur[b] in live
        assert np.all(np.diff(live) > 0) and np.all(live <= cur[b])
    _, cnts_tiny = _select(q, kc, pos0s, lengths, blk, 8, 6,
                           threshold=1e-6)
    np.testing.assert_array_equal(np.asarray(cnts_tiny),
                                  np.minimum(2, nv))


def test_threshold_one_is_inert_through_the_model():
    """End-to-end through the model config: with a LIVE dual-budget
    plan (attn_sparsity > 0, so pooled-QK selection really runs every
    interior block), attn_threshold=1.0 keeps every candidate — counts
    collapse to the fixed-budget counts and generation is bitwise equal
    to attn_threshold=0.0 (off). The opt-in knob is inert at its
    identity point even where selection is active."""
    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.nn.param import init_params
    from repro.serving import Engine
    cfg = get_config("tinyllama-1.1b", reduced=True).with_ff(
        attn_sparsity=0.3)
    params = init_params(get_model(cfg).specs(cfg), jax.random.key(0))
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, 96).tolist(),
               rng.integers(0, cfg.vocab, 64).tolist()]
    off = Engine(cfg, params).generate(prompts, max_new=6)
    on = Engine(cfg.with_ff(attn_threshold=1.0), params).generate(
        prompts, max_new=6)
    np.testing.assert_array_equal(off.tokens, on.tokens)


# ------------------------------------- oracles and kernel cross-checks


def test_full_budget_masked_path_bit_identical_to_dense():
    """The serving contract: at a_l == attn_tiles the membership mask
    keeps every causally-valid key, so the masked XLA path is BITWISE
    equal to dense attention — not merely allclose."""
    q, kc, vc, pos0s, lengths = _setup(seed=1)
    ids, cnts = _select(q, kc, pos0s, lengths, 8, attn_tiles=8, a_l=8)
    got = R.block_sparse_attention_masked(q, kc, vc, ids, cnts, pos0s,
                                          lengths, blk=8)
    want = R.dense_oracle(q, kc, vc, pos0s, lengths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_interpret_bitwise_matches_twin():
    """Interpret kernel == online-softmax twin BITWISE, with per-row
    DISTINCT block ids and counts (the causal ramp guarantees rows
    differ; we also scatter rows across a shared slab pool)."""
    q, kc, vc, pos0s, lengths = _setup(seed=2)
    B, N = q.shape[:2]
    S, Kv, dh = kc.shape[1:]
    blk, nc = N, S // N
    for a_l in (3, 8):
        ids, cnts = _select(q, kc, pos0s, lengths, blk, 8, a_l)
        assert len({tuple(np.asarray(ids)[b, :int(cnts[b])])
                    for b in range(B)}) > 1          # rows truly differ
        kb = kc.reshape(B * nc, blk, Kv, dh)
        vb = vc.reshape(B * nc, blk, Kv, dh)
        pool_ids = ids + nc * jnp.arange(B, dtype=jnp.int32)[:, None]
        blk_pos = ids * blk
        kern = K.block_sparse_prefill(q, kb, vb, pool_ids, blk_pos, cnts,
                                      pos0s, lengths, interpret=True)
        twin = R.block_sparse_attention_twin(q, kb, vb, pool_ids,
                                             blk_pos, cnts, pos0s,
                                             lengths)
        np.testing.assert_array_equal(np.asarray(kern), np.asarray(twin))


def test_kernel_dispatch_full_budget_allclose_dense_oracle():
    q, kc, vc, pos0s, lengths = _setup(seed=3)
    ids, cnts = _select(q, kc, pos0s, lengths, 8, attn_tiles=8, a_l=8)
    kern = BSA.block_sparse_prefill_op(q, kc, vc, ids, cnts, pos0s,
                                       lengths, blk=8, use_kernel=True)
    want = R.dense_oracle(q, kc, vc, pos0s, lengths)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kernel_dispatch_sparse_budget_matches_masked_path():
    q, kc, vc, pos0s, lengths = _setup(seed=4)
    ids, cnts = _select(q, kc, pos0s, lengths, 8, attn_tiles=8, a_l=4)
    kern = BSA.block_sparse_prefill_op(q, kc, vc, ids, cnts, pos0s,
                                       lengths, blk=8, use_kernel=True)
    xla = BSA.block_sparse_prefill_op(q, kc, vc, ids, cnts, pos0s,
                                      lengths, blk=8, use_kernel=False)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(xla),
                               rtol=1e-5, atol=1e-5)
    # the budget genuinely bites vs dense
    dense = R.dense_oracle(q, kc, vc, pos0s, lengths)
    assert np.abs(np.asarray(xla) - np.asarray(dense)).max() > 1e-4


def test_sliding_window_selection_and_attention():
    q, kc, vc, pos0s, lengths = _setup(seed=5)
    win = 12
    ids, cnts = _select(q, kc, pos0s, lengths, 8, 8, 8, window=win)
    kern = BSA.block_sparse_prefill_op(q, kc, vc, ids, cnts, pos0s,
                                       lengths, blk=8, window=win,
                                       use_kernel=True)
    want = R.dense_oracle(q, kc, vc, pos0s, lengths, window=win)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    full = R.dense_oracle(q, kc, vc, pos0s, lengths)
    assert not np.allclose(np.asarray(want), np.asarray(full))


# --------------------------------------------------------- paged twin


def test_paged_dispatch_matches_slot_dispatch():
    """The page-table-aware kernel (slab granularity = page size) and
    the paged XLA gather branch both match the slot-layout answer on a
    shuffled page pool holding the same KV."""
    q, kc, vc, pos0s, lengths = _setup(seed=6)
    B, S, Kv, dh = kc.shape
    psz, blk = 4, 8
    mp = S // psz
    rng = np.random.default_rng(6)
    perm = rng.permutation(np.arange(1, 1 + B * mp))
    table = np.zeros((B, mp), np.int32)
    k_pool = np.zeros((1 + B * mp, psz, Kv, dh), np.float32)
    v_pool = np.zeros((1 + B * mp, psz, Kv, dh), np.float32)
    for b in range(B):
        for j in range(mp):
            pid = int(perm[b * mp + j])
            table[b, j] = pid
            k_pool[pid] = np.asarray(kc[b, j * psz:(j + 1) * psz])
            v_pool[pid] = np.asarray(vc[b, j * psz:(j + 1) * psz])
    k_pool, v_pool = jnp.asarray(k_pool), jnp.asarray(v_pool)
    table = jnp.asarray(table)
    for a_l in (4, 8):
        ids, cnts = _select(q, kc, pos0s, lengths, blk, 8, a_l)
        slot = BSA.block_sparse_prefill_op(q, kc, vc, ids, cnts, pos0s,
                                           lengths, blk=blk,
                                           use_kernel=False)
        paged_x = BSA.block_sparse_prefill_paged_op(
            q, k_pool, v_pool, table, ids, cnts, pos0s, lengths,
            blk=blk, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(paged_x),
                                      np.asarray(slot))
        paged_k = BSA.block_sparse_prefill_paged_op(
            q, k_pool, v_pool, table, ids, cnts, pos0s, lengths,
            blk=blk, use_kernel=True)
        np.testing.assert_allclose(np.asarray(paged_k),
                                   np.asarray(slot), rtol=1e-5,
                                   atol=1e-5)


def test_pooled_block_keys_paged_matches_slot():
    q, kc, vc, pos0s, lengths = _setup(seed=7)
    B, S, Kv, dh = kc.shape
    psz = 4
    mp = S // psz
    table = np.arange(1, 1 + B * mp).reshape(B, mp).astype(np.int32)
    pool = np.zeros((1 + B * mp, psz, Kv, dh), np.float32)
    pool[1:] = np.asarray(kc).reshape(B * mp, psz, Kv, dh)
    want = BSA.pooled_block_keys(kc, 8)
    got = BSA.pooled_block_keys_paged(jnp.asarray(pool),
                                      jnp.asarray(table), 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# -------------------------------- attention-op level (serving wiring)


def _attn_params(rng, D, H, Kv, dh):
    return {
        "wq": jnp.asarray(rng.standard_normal((D, H, dh)) * 0.1,
                          jnp.float32),
        "wk": jnp.asarray(rng.standard_normal((D, Kv, dh)) * 0.1,
                          jnp.float32),
        "wv": jnp.asarray(rng.standard_normal((D, Kv, dh)) * 0.1,
                          jnp.float32),
        "wo": jnp.asarray(rng.standard_normal((H, dh, D)) * 0.1,
                          jnp.float32),
    }


def test_attend_block_rows_full_budget_bit_identical_to_dense():
    """attend_block_rows with a FULL attention budget must return the
    bit-exact dense answer on the XLA path — the zero-regression
    contract the dense effort tier and tier-1 parity rest on."""
    rng = np.random.default_rng(8)
    B, N, D, H, Kv, dh, S = 3, 8, 16, 4, 2, 8, 40
    params = _attn_params(rng, D, H, Kv, dh)
    x = jnp.asarray(rng.standard_normal((B, N, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    pos0s = jnp.asarray([0, 16, 32], jnp.int32)
    lengths = jnp.asarray([8, 24, 40], jnp.int32)
    dense = A.attend_block_rows(params, x, kc, vc, pos0s,
                                lengths=lengths)
    full = A.attend_block_rows(params, x, kc, vc, pos0s,
                               lengths=lengths,
                               attn_sel=(8, 8, jnp.int32(8)))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(dense))
    # a sparse budget gives a different (but finite) answer
    sparse = A.attend_block_rows(params, x, kc, vc, pos0s,
                                 lengths=lengths,
                                 attn_sel=(4, 8, jnp.int32(4)))
    assert np.all(np.isfinite(np.asarray(sparse)))
    assert not np.array_equal(np.asarray(sparse), np.asarray(dense))
    # attend_block_cached delegates to the same path (broadcast pos0)
    cached = A.attend_block_cached(params, x[:1], kc[:1], vc[:1], 32,
                                   lengths=lengths[2:],
                                   attn_sel=(8, 8, jnp.int32(8)))
    plain = A.attend_block_cached(params, x[:1], kc[:1], vc[:1], 32,
                                  lengths=lengths[2:])
    np.testing.assert_array_equal(np.asarray(cached), np.asarray(plain))


def test_attend_block_rows_paged_full_budget_matches_slot():
    rng = np.random.default_rng(9)
    B, N, D, H, Kv, dh, S = 2, 8, 16, 4, 2, 8, 32
    psz, mp = 4, 8
    params = _attn_params(rng, D, H, Kv, dh)
    x = jnp.asarray(rng.standard_normal((B, N, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, Kv, dh)), jnp.float32)
    table = np.arange(1, 1 + B * mp).reshape(B, mp).astype(np.int32)
    k_pool = np.zeros((1 + B * mp, psz, Kv, dh), np.float32)
    v_pool = np.zeros((1 + B * mp, psz, Kv, dh), np.float32)
    k_pool[1:] = np.asarray(kc).reshape(B * mp, psz, Kv, dh)
    v_pool[1:] = np.asarray(vc).reshape(B * mp, psz, Kv, dh)
    pos0s = jnp.asarray([8, 24], jnp.int32)
    lengths = jnp.asarray([16, 32], jnp.int32)
    for sel in ((8, 8, jnp.int32(8)), (4, 8, jnp.int32(4))):
        slot = A.attend_block_rows(params, x, kc, vc, pos0s,
                                   lengths=lengths, attn_sel=sel)
        paged = A.attend_block_rows_paged(
            params, x, jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(table), pos0s, lengths=lengths, attn_sel=sel)
        np.testing.assert_array_equal(np.asarray(paged),
                                      np.asarray(slot))


def test_attn_sel_width_static_bounds():
    assert attn_sel_width((8, 8, None), 5) == 5       # full budget
    assert attn_sel_width((4, 8, None), 16) == 8      # half budget
    assert attn_sel_width((1, 16, None), 4) == 2      # floor: sink+diag
    assert attn_sel_width((16, 16, None), 1) == 1     # single block


# ------------------------------------ satellite 6: per-row flash rows


def test_flash_rows_kernel_matches_fallback_and_oracle():
    """flash_attention_rows (the dense TPU routing behind
    attend_block_rows) interpret-mode vs the XLA fallback vs the dense
    oracle, per-row offsets and ragged lengths."""
    q, kc, vc, pos0s, lengths = _setup(seed=10)
    kern = FA.mha_flash_rows(q, kc, vc, pos0s, lengths,
                             use_kernel=True, interpret=True)
    xla = FA.mha_flash_rows(q, kc, vc, pos0s, lengths, use_kernel=False)
    want = R.dense_oracle(q, kc, vc, pos0s, lengths)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(xla),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_rows_window_and_ragged_padding():
    """S not a block_k multiple exercises the pad-and-mask path; the
    sliding window must agree with the oracle."""
    q, kc, vc, pos0s, lengths = _setup(seed=11, S=36,
                                       lengths=(8, 24, 36))
    for win in (None, 12):
        kern = FA.mha_flash_rows(q, kc, vc, pos0s, lengths, window=win,
                                 use_kernel=True, interpret=True)
        want = R.dense_oracle(q, kc, vc, pos0s, lengths, window=win)
        np.testing.assert_allclose(np.asarray(kern), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
